"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts.  Run after both sweeps:

    PYTHONPATH=src python -m benchmarks.gen_experiments > /tmp/tables.md
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "artifacts", "dryrun")
HBM = 16 * 2**30

ARCHS = [
    "granite-moe-1b-a400m", "deepseek-moe-16b", "nemotron-4-15b",
    "stablelm-12b", "minitron-4b", "codeqwen1.5-7b", "internvl2-26b",
    "seamless-m4t-medium", "mamba2-1.3b", "zamba2-1.2b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
SUBQ = {"mamba2-1.3b", "zamba2-1.2b"}


def load(arch, shape, mesh):
    p = os.path.join(ART, f"{arch}--{shape}--{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def gib(x):
    return x / 2**30


def dryrun_table():
    print("### Dry-run matrix (lower + compile; per-device memory analysis)\n")
    print("Cells marked SKIP(rule): `long_500k` requires sub-quadratic "
          "attention and runs only for the SSM/hybrid archs per the "
          "assignment.\n")
    print("| arch | shape | 16x16 | 2x16x16 | args GiB/dev | temp GiB/dev "
          "| peak(donation-adj) | fits 16 GiB |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in SUBQ:
                print(f"| {arch} | {shape} | SKIP(rule) | SKIP(rule) "
                      f"| — | — | — | — |")
                continue
            s = load(arch, shape, "16_16")
            m = load(arch, shape, "2_16_16")
            if s is None:
                print(f"| {arch} | {shape} | MISSING | | | | | |")
                continue
            mem = s["memory"]
            peak = mem["argument_bytes_per_dev"] + mem["temp_bytes_per_dev"]
            print(f"| {arch} | {shape} "
                  f"| OK ({s['compile_s']:.0f}s) "
                  f"| {'OK (%.0fs)' % m['compile_s'] if m else 'MISSING'} "
                  f"| {gib(mem['argument_bytes_per_dev']):.2f} "
                  f"| {gib(mem['temp_bytes_per_dev']):.2f} "
                  f"| {gib(peak):.2f} "
                  f"| {'Y' if peak <= HBM else 'over'} |")
    print()


def roofline_table():
    print("### Roofline (single-pod 16x16, 256 chips; terms in ms/step)\n")
    print("compute = dot-FLOPs/dev ÷ 197 TF/s;  memory = (args+out+temp)/dev "
          "÷ 819 GB/s;  collective = per-dev collective operand bytes ÷ 50 "
          "GB/s/link.  `useful` = MODEL_FLOPS ÷ (HLO_FLOPs x 256) with "
          "MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active "
          "params.\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful | one-line diagnosis |")
    print("|---|---|---|---|---|---|---|---|")
    notes = {
        ("granite-moe-1b-a400m", "train_4k"):
            "a2a dispatch + activation ARs dominate; tiny active params",
        ("granite-moe-1b-a400m", "prefill_32k"):
            "S^2 attention dominates a 400M-active model at 32k",
        ("granite-moe-1b-a400m", "decode_32k"): "KV-cache streaming",
        ("deepseek-moe-16b", "train_4k"):
            "fwd TP partial-sum all-reduces (f32 wire)",
        ("deepseek-moe-16b", "prefill_32k"): "a2a + attention ARs",
        ("deepseek-moe-16b", "decode_32k"): "KV + expert weight streaming",
        ("nemotron-4-15b", "train_4k"): "row-parallel AR f32 wire",
        ("stablelm-12b", "train_4k"): "row-parallel AR f32 wire",
        ("internvl2-26b", "train_4k"):
            "largest model: ARs + remat; needs 2-pod mesh for 16 GiB",
        ("mamba2-1.3b", "long_500k"): "state-cache streaming, O(1) decode",
        ("zamba2-1.2b", "long_500k"): "shared-attn KV over 512k seq",
    }
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in SUBQ:
                continue
            s = load(arch, shape, "16_16")
            if s is None:
                continue
            rl = s["roofline"]
            note = notes.get((arch, shape), "")
            print(f"| {arch} | {shape} "
                  f"| {rl['compute_s']*1e3:.1f} "
                  f"| {rl['memory_s']*1e3:.1f} "
                  f"| {rl['collective_s']*1e3:.1f} "
                  f"| {rl['dominant']} "
                  f"| {rl['useful_ratio']:.2f} | {note} |")
    print()
    # summary picks
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            s = load(arch, shape, "16_16")
            if s:
                rows.append(s)
    if rows:
        worst = min((r for r in rows if r["shape"] != "decode_32k"
                     and r["shape"] != "long_500k"),
                    key=lambda r: r["roofline"]["useful_ratio"])
        collb = max(rows, key=lambda r: r["roofline"]["collective_s"])
        print(f"**Hillclimb picks** — worst useful-ratio (non-decode): "
              f"`{worst['arch']} x {worst['shape']}` "
              f"({worst['roofline']['useful_ratio']:.2f}); "
              f"most collective-bound: `{collb['arch']} x {collb['shape']}`; "
              f"most paper-representative: `deepseek-moe-16b x train_4k` "
              f"(sparse-FFNN dispatch is the paper's own regime).\n")


def main():
    dryrun_table()
    roofline_table()


if __name__ == "__main__":
    main()
