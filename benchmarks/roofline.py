"""Roofline table from the dry-run artifacts (single-pod per the assignment).

    PYTHONPATH=src python -m benchmarks.roofline [--mesh 16_16] [--md]

Reads benchmarks/artifacts/dryrun/*.json produced by repro.launch.dryrun and
prints per-cell: the three roofline terms (seconds), the dominant term,
MODEL_FLOPS/HLO_FLOPS, and memory feasibility vs the 16 GB/chip budget.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 * 2**30

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "artifacts", "dryrun")


def load(mesh: str, tag: str = ""):
    rows = []
    suffix = f"-{tag}.json" if tag else ".json"
    for path in sorted(glob.glob(os.path.join(ART, f"*--{mesh}{suffix}"))):
        base = os.path.basename(path)
        if not tag and base.count("--") > 2:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    if not tag:
        rows = [r for r in rows if "--" + mesh + ".json" in "--" + os.path.basename(
            f"{r['arch']}--{r['shape']}--{mesh}.json")]
    return rows


def fmt_row(r):
    rl = r["roofline"]
    mem = r["memory"]
    peak = mem["peak_est_bytes_per_dev"]
    fits = "Y" if peak <= HBM_PER_CHIP else "OVER"
    terms = {"compute": rl["compute_s"], "memory": rl["memory_s"],
             "collective": rl["collective_s"]}
    dom = rl["dominant"]
    frac = terms[dom] / max(1e-12, sum(terms.values()))
    return (f"{r['arch']:22s} {r['shape']:12s} "
            f"{rl['compute_s']*1e3:10.2f} {rl['memory_s']*1e3:10.2f} "
            f"{rl['collective_s']*1e3:12.2f} {dom:10s} {frac:5.2f} "
            f"{rl['useful_ratio']:6.2f} {peak/2**30:7.2f} {fits:>4s}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16_16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = load(args.mesh, args.tag)
    print(f"# roofline ({args.mesh}, {len(rows)} cells) — terms in ms/step, "
          f"peak in GiB/dev vs 16 GiB budget")
    print(f"{'arch':22s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
          f"{'collective':>12s} {'dominant':10s} {'share':>5s} "
          f"{'useful':>6s} {'peak':>7s} {'fits':>4s}")
    for r in rows:
        print(fmt_row(r))
    if rows:
        worst = min(rows, key=lambda r: r["roofline"]["useful_ratio"])
        collb = max(rows, key=lambda r: r["roofline"]["collective_s"]
                    / max(1e-12, r["roofline"]["compute_s"]))
        print(f"\nworst useful-ratio: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline']['useful_ratio']:.2f})")
        print(f"most collective-bound: {collb['arch']} x {collb['shape']}")


if __name__ == "__main__":
    main()
