"""Serving-runtime benchmark: cold vs warm compile + bucketed vs fixed batching.

    PYTHONPATH=src python benchmarks/bench_serving.py [--max-batch 32]

Measures the two amortizations the serving subsystem adds on top of the
engine:

  * **plan persistence** — the same network compiled cold (Theorem-1
    schedule + Connection Reordering + lowering, then persisted) and warm
    (content-addressed plan-store hit: rebuilt from the stored connection
    order with ZERO annealer iterations).  Outputs are checked bit-identical
    across the two plans;
  * **bucketed plans** — a mixed-batch-size request trace served through
    power-of-two buckets (pad only up to the smallest bucket that fits)
    vs the old fixed-batch policy (every batch padded to ``max_batch``).
    Per-batch latency p50/p99 for both; small batches dominate real traces,
    so bucketed p50 must beat fixed p50;
  * **async vs step-driven serving** — the same request stream through the
    step-driven caller loop (submission and execution interleaved in one
    thread) and through the background scheduler thread with 4 concurrent
    submitters.  Async must not lose throughput, and typically wins by
    overlapping submission with batch execution;
  * **pipelined execution** — an open-loop (fixed-RPS) request sweep
    through the staged pipeline (formation -> per-bucket dispatch lanes ->
    executor pool) with 1 vs N workers.  Device time is SIMULATED: every
    batch call runs the real plan (outputs stay bit-identical and are
    checked against single-row references) and then sleeps out a fixed
    ``--sim-device-ms`` budget — modelling the paper's regime, where batch
    latency is dominated by I/O-bound accelerator streaming while the host
    sits idle.  The sleep releases the GIL, so worker overlap is real even
    on a single-core CI host; with N workers, different-bucket batches
    overlap and the saturated throughput must reach >= 1.3x the 1-worker
    pipeline (p99 latency recorded for both);
  * **tracer overhead** — the same step-driven stream with request tracing
    disabled and enabled.  A disabled tracer is asserted within noise of
    serving with no tracer at all (the hot path pays one attribute read per
    instrumentation site); the enabled-tracer throughput is recorded so the
    observability tax stays visible across PRs.

Results are printed AND written to machine-readable ``BENCH_serving.json``
(committed + uploaded as a CI artifact) so the serving perf trajectory is
tracked across PRs.  On CPU hosts the latency comparison runs on the ``jnp``
backend; on TPU pass ``--backend pallas``.
"""

from __future__ import annotations

import argparse
import json
import platform
import shutil
import tempfile
import threading
import time

import jax
import numpy as np

from repro.engine import Engine, Mesh
from repro.serving import BucketedPlanSet, PlanStore, SparseServer
from repro.serving.metrics import percentile
from repro.sparse import prune_dense_stack


def make_layers(sizes, density, block, seed=0):
    rng = np.random.default_rng(seed)
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.03
          for i in range(len(sizes) - 1)]
    bs = [np.zeros(s, np.float32) for s in sizes[1:]]
    return prune_dense_stack(ws, bs, density=density,
                             block_m=block, block_n=block)


def make_engine(args):
    return Engine(backend=args.backend, activation="gelu", reorder=True,
                  reorder_iters=args.reorder_iters)


def mixed_trace(rng, n_batches, max_batch):
    """Batch sizes of a bursty request trace: mostly small, some full."""
    sizes = [s for s in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32) if s <= max_batch]
    probs = np.array([0.22, 0.18, 0.12, 0.12, 0.08, 0.08, 0.06, 0.06,
                      0.04, 0.04][:len(sizes)])
    probs = probs / probs.sum()
    return [int(rng.choice(sizes, p=probs)) for _ in range(n_batches)]


class SimDevicePlans:
    """A ``BucketedPlanSet`` whose batch calls take a fixed simulated
    device time.

    Every call runs the REAL underlying plan first (outputs stay
    bit-identical to the unwrapped plan set), then sleeps out the
    remainder of ``sim_s``.  ``time.sleep`` releases the GIL, so this
    models the paper's target regime — batch latency dominated by
    I/O-bound accelerator streaming while the host is idle — and lets
    executor-pool overlap show up even on a single-core host, where real
    host-side compute could never overlap with itself.  Everything else
    (bucket routing, dtype, warmup, ...) delegates to the wrapped set.
    """

    def __init__(self, base, sim_s: float):
        self._base = base
        self._sim_s = sim_s

    def __call__(self, x):
        t0 = time.perf_counter()
        y = self._base(x)
        pad = self._sim_s - (time.perf_counter() - t0)
        if pad > 0:
            time.sleep(pad)
        return y

    def __getattr__(self, name):
        return getattr(self._base, name)


def time_trace(run, trace, xs, iters_warm=2):
    """Per-batch wall latencies of ``run(x_n)`` over the trace sizes."""
    for n in sorted(set(trace)):
        for _ in range(iters_warm):
            run(xs[n])  # trace/warm every shape outside the timed loop
    lats = []
    for n in trace:
        t0 = time.perf_counter()
        run(xs[n])
        lats.append(time.perf_counter() - t0)
    return lats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[768, 1536, 1536, 768])
    ap.add_argument("--density", type=float, default=0.2)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--batches", type=int, default=60,
                    help="mixed-size trace length (in batches)")
    ap.add_argument("--reorder-iters", type=int, default=200)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "interpret", "jnp"))
    ap.add_argument("--plan-dir", default=None,
                    help="plan-store dir (default: fresh temp dir, so the "
                         "cold/warm comparison is reproducible)")
    ap.add_argument("--mesh", default=None, metavar="MODELxDATA",
                    help="benchmark through a sharded execution plan "
                         "(e.g. 4x2); default unsharded")
    ap.add_argument("--sim-device-ms", type=float, default=25.0,
                    help="simulated per-batch device time for the pipeline "
                         "sweep (the real plan still runs; the call sleeps "
                         "out the remainder)")
    ap.add_argument("--pipeline-requests", type=int, default=240,
                    help="requests per pipeline sweep point")
    ap.add_argument("--pipeline-rates", type=float, nargs="+",
                    default=[150.0, 300.0, 600.0],
                    help="open-loop offered rates (req/s) for the pipeline "
                         "sweep; the >=1.3x assertion applies at the "
                         "highest (saturating) rate")
    ap.add_argument("--pipeline-workers", type=int, default=4,
                    help="executor-pool size compared against 1 worker in "
                         "the pipeline sweep")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    mesh = Mesh.parse(args.mesh) if args.mesh else None

    rng = np.random.default_rng(0)
    layers = make_layers(args.sizes, args.density, args.block)

    plan_dir = args.plan_dir or tempfile.mkdtemp(prefix="plan_store_")
    store = PlanStore(plan_dir)
    # a reused --plan-dir may already hold this entry; evict it so the cold
    # measurement is genuinely cold on every run
    store.evict(make_engine(args), layers, mesh=mesh)

    # ---- cold start: schedule + CR + lowering, then persisted ---------- #
    t0 = time.perf_counter()
    plan_cold, hit = store.get_or_compile(make_engine(args), layers,
                                          mesh=mesh)
    cold_s = time.perf_counter() - t0
    assert not hit, "expected a cold start against a fresh plan store"
    print(f"cold compile:  {cold_s:6.2f}s "
          f"({plan_cold.annealer_iters} annealer iters)")

    # ---- warm start: content-addressed hit, zero annealing ------------- #
    t0 = time.perf_counter()
    plan_warm, hit = store.get_or_compile(make_engine(args), layers,
                                          mesh=mesh)
    warm_s = time.perf_counter() - t0
    assert hit, "expected a plan-store hit on the second compile"
    assert plan_warm.annealer_iters == 0, "warm start must skip annealing"
    print(f"warm compile:  {warm_s:6.2f}s (plan-store hit, "
          f"{plan_warm.annealer_iters} annealer iters, "
          f"{cold_s / max(warm_s, 1e-9):.0f}x faster)")

    x_full = rng.standard_normal(
        (args.max_batch, args.sizes[0])).astype(np.float32)
    y_cold = np.asarray(plan_cold(x_full))
    y_warm = np.asarray(plan_warm(x_full))
    assert np.array_equal(y_cold, y_warm), \
        "warm-start outputs must be bit-identical to the cold compile"
    print("warm outputs bit-identical to cold: OK")

    # ---- bucketed vs fixed-batch latency on a mixed-size trace --------- #
    plans = BucketedPlanSet.compile(layers, engine=make_engine(args),
                                    max_batch=args.max_batch,
                                    plan_store=store, mesh=mesh)
    plans.warmup()
    trace = mixed_trace(rng, args.batches, args.max_batch)
    xs = {n: rng.standard_normal((n, args.sizes[0])).astype(np.float32)
          for n in sorted(set(trace))}

    lat_bucketed = time_trace(plans, trace, xs)

    # the old fixed-batch policy: every batch padded up to max_batch
    def fixed(x):
        n = x.shape[0]
        if n < args.max_batch:
            x = np.concatenate(
                [x, np.zeros((args.max_batch - n, x.shape[1]), x.dtype)])
        return np.asarray(plans.plans[args.max_batch](x))[:n]

    lat_fixed = time_trace(fixed, trace, xs)

    b50, b99 = percentile(lat_bucketed, 50), percentile(lat_bucketed, 99)
    f50, f99 = percentile(lat_fixed, 50), percentile(lat_fixed, 99)
    print(f"trace: {len(trace)} batches, sizes p50={percentile([float(t) for t in trace], 50):.0f}, "
          f"mean={np.mean(trace):.1f}, max={max(trace)}")
    print(f"  bucketed: p50 {1e3*b50:7.2f} ms  p99 {1e3*b99:7.2f} ms")
    print(f"  fixed:    p50 {1e3*f50:7.2f} ms  p99 {1e3*f99:7.2f} ms "
          f"(pad to {args.max_batch})")
    assert b50 < f50, "bucketed p50 must beat fixed-batch p50 on a mixed trace"

    # ---- end-to-end serve loop through the scheduler ------------------- #
    server = SparseServer(plans, slo_ms=args.slo_ms)
    for n in trace:
        for _ in range(n):
            server.submit(rng.standard_normal(
                args.sizes[0]).astype(np.float32))
        server.poll()
    server.drain()
    print("serve loop:", server.metrics.summary())

    # ---- async vs step-driven serve-loop throughput -------------------- #
    # same request stream both ways: the step-driven loop interleaves
    # submission and execution in one thread; async mode overlaps them —
    # submitter threads keep the queue fed while the scheduler thread
    # executes, so batches stay full and wall time drops.  The stream is
    # long enough that per-run constants (thread spawn, jit-cache touch)
    # amortize away and steady-state throughput is what's measured.
    n_req = max(2048, int(sum(trace)))
    req_rows = [rng.standard_normal(args.sizes[0]).astype(np.float32)
                for _ in range(n_req)]

    def run_step(tracer=None) -> float:
        server = SparseServer(plans, slo_ms=args.slo_ms, max_queue=n_req,
                              tracer=tracer)
        t0 = time.perf_counter()
        for x in req_rows:
            server.submit(x)
            server.poll()
        server.drain()
        dt = time.perf_counter() - t0
        assert server.metrics.served == n_req
        return n_req / dt

    def run_async(n_threads: int = 4) -> float:
        server = SparseServer(plans, slo_ms=args.slo_ms,
                              max_queue=n_req).start()
        shards = [req_rows[i::n_threads] for i in range(n_threads)]
        gate = threading.Barrier(n_threads + 1)

        def client(shard):
            gate.wait()
            for x in shard:
                server.submit(x)

        ts = [threading.Thread(target=client, args=(s,)) for s in shards]
        for t in ts:
            t.start()
        gate.wait()                      # all submitters ready: go
        t0 = time.perf_counter()
        for t in ts:
            t.join()
        server.shutdown(drain=True)
        dt = time.perf_counter() - t0
        assert server.metrics.served == n_req
        return n_req / dt

    # best-of-3: the first run of either mode pays one-off warm-in costs
    # (thread pools, page cache); steady-state throughput is the comparison
    step_rps = max(run_step() for _ in range(3))
    async_rps = max(run_async() for _ in range(3))
    print(f"  step-driven: {step_rps:8.0f} req/s")
    print(f"  async:       {async_rps:8.0f} req/s "
          f"({async_rps / step_rps:.2f}x, 4 submit threads)")
    assert async_rps >= 0.9 * step_rps, \
        "async serving should not lose throughput to the step-driven loop"

    # ---- pipelined execution: open-loop RPS sweep, 1 vs N workers ------ #
    # device time is simulated (see SimDevicePlans): the real plan runs,
    # the call then sleeps out --sim-device-ms.  That is the paper's
    # regime — batch latency dominated by I/O-bound weight streaming on
    # the accelerator while the host idles — and it makes the sweep
    # deterministic and host-independent.  1-worker capacity is one
    # max-bucket batch per sim tick; N workers overlap different-bucket
    # batches (the spill policy forms smaller-bucket batches while the
    # preferred lane is busy), so saturated throughput must scale.
    sim_s = args.sim_device_ms / 1e3
    n_pipe = args.pipeline_requests
    # a dedicated small-max-batch plan set: worker overlap comes from the
    # SPILL lanes (buckets below the preferred one), whose combined rows
    # are 1+2+4 = 7/8 of the max bucket at max_batch=8 — so N workers can
    # approach ~1.9x one worker.  At max_batch=32 the smaller buckets sum
    # to less than one full lane (31/32) and the ceiling collapses to
    # ~1.25x: the sweep would measure lane arithmetic, not the pipeline
    pipe_max = min(8, args.max_batch)
    pipe_plans = BucketedPlanSet.compile(layers, engine=make_engine(args),
                                         max_batch=pipe_max,
                                         plan_store=store, mesh=mesh)
    pipe_plans.warmup()
    pool_x = [rng.standard_normal(args.sizes[0]).astype(np.float32)
              for _ in range(16)]
    # single-row references through the UNwrapped plans: the pipeline's
    # outputs must match bit-for-bit regardless of worker count, bucket
    # routing, or batch composition
    expected = [np.asarray(pipe_plans(x[None, :]))[0] for x in pool_x]

    def run_pipeline(workers: int, rate) -> dict:
        """One sweep point: open-loop arrivals at ``rate`` req/s, or a
        single up-front burst (``rate=None``) that keeps the queue
        saturated — the capacity-bound regime the scaling assertion
        uses, free of arrival-pacing jitter."""
        server = SparseServer(SimDevicePlans(pipe_plans, sim_s),
                              slo_ms=args.slo_ms, max_queue=n_pipe,
                              executor_workers=workers)
        server.start()
        rids = []
        t0 = time.perf_counter()
        for i in range(n_pipe):
            if rate is not None:                # open-loop arrivals
                target = t0 + i / rate
                now = time.perf_counter()
                if target > now:
                    time.sleep(target - now)
            rid = server.submit(pool_x[i % len(pool_x)])
            assert rid is not None, "pipeline sweep must not reject"
            rids.append(rid)
        outs = [server.wait(rid, timeout=120.0) for rid in rids]
        dt = time.perf_counter() - t0
        snap = server.snapshot()                # pool stats live until
        server.shutdown(drain=True)             # shutdown releases them
        assert server.metrics.served == n_pipe, "zero lost requests"
        for i, o in enumerate(outs):
            assert o is not None and np.array_equal(
                np.asarray(o), expected[i % len(pool_x)]), \
                f"request {i}: pipeline output != single-row reference"
        per_worker = {w: s["batches"] for w, s in
                      snap.get("pool", {}).get("per_worker", {}).items()}
        return {
            "workers": workers,
            "offered_rps": rate,
            "effective_rps": n_pipe / dt,
            "latency_p99_ms": snap["latency_ms"]["p99"],
            "dispatch_wait_p99_ms": snap["dispatch_wait_ms"]["p99"],
            "batches": snap["batches"],
            "per_worker_batches": per_worker,
            "bit_identical": True,
        }

    sweep = []
    for rate in sorted(args.pipeline_rates) + [None]:
        for workers in (1, args.pipeline_workers):
            r = run_pipeline(workers, rate)
            sweep.append(r)
            offered = (f"{rate:5.0f} req/s" if rate is not None
                       else "saturated")
            print(f"  pipeline offered={offered} workers={workers}: "
                  f"{r['effective_rps']:6.0f} req/s effective, "
                  f"p99 {r['latency_p99_ms']:8.1f} ms, "
                  f"batches={r['per_worker_batches']}")
    # the scaling assertion runs on the SATURATED (burst) points: both
    # configs are capacity-bound there, so the ratio measures lane
    # overlap, not arrival-pacing jitter
    pipe1 = next(r for r in sweep
                 if r["offered_rps"] is None and r["workers"] == 1)
    pipeN = next(r for r in sweep
                 if r["offered_rps"] is None
                 and r["workers"] == args.pipeline_workers)
    pipe_speedup = pipeN["effective_rps"] / pipe1["effective_rps"]
    print(f"  pipeline speedup at saturation: "
          f"{pipe_speedup:.2f}x ({args.pipeline_workers} vs 1 workers, "
          f"sim device {args.sim_device_ms:.0f} ms/batch, "
          f"outputs bit-identical)")
    assert pipe_speedup >= 1.3, \
        (f"{args.pipeline_workers} executor workers must reach >= 1.3x the "
         f"1-worker pipeline at saturation (got {pipe_speedup:.2f}x)")

    # ---- tracer overhead: disabled vs enabled on the hot path ---------- #
    # a DISABLED tracer must cost one attribute read per instrumentation
    # site — indistinguishable from no tracer at all (within measurement
    # noise); an ENABLED tracer pays span/event recording per request and
    # is reported so the observability tax stays visible across PRs
    from repro.obs import Tracer

    tracer_off_rps = max(run_step(Tracer(enabled=False)) for _ in range(3))
    tracer_on_rps = max(run_step(Tracer(capacity=4096)) for _ in range(3))
    print(f"  tracer off:  {tracer_off_rps:8.0f} req/s "
          f"({tracer_off_rps / step_rps:.2f}x of no-tracer baseline)")
    print(f"  tracer on:   {tracer_on_rps:8.0f} req/s "
          f"({tracer_on_rps / tracer_off_rps:.2f}x of disabled)")
    assert tracer_off_rps >= 0.8 * step_rps, \
        "a disabled tracer must be within noise of serving with no tracer"

    result = {
        "net": {
            "sizes": args.sizes,
            "density": args.density,
            "block": args.block,
            "nnz_blocks": int(sum(l.nnz_blocks for l in layers)),
        },
        "backend": plan_cold.backend,
        "reorder_iters": args.reorder_iters,
        "compile_s": {
            "cold": cold_s,
            "warm": warm_s,
            "warm_speedup": cold_s / max(warm_s, 1e-9),
            "warm_annealer_iters": plan_warm.annealer_iters,
            "bit_identical_outputs": True,
        },
        "trace": {
            "batches": len(trace),
            "max_batch": args.max_batch,
            "mean_batch": float(np.mean(trace)),
            "buckets": list(plans.buckets),
        },
        "latency_ms": {
            "bucketed_p50": 1e3 * b50,
            "bucketed_p99": 1e3 * b99,
            "fixed_p50": 1e3 * f50,
            "fixed_p99": 1e3 * f99,
            "bucketed_vs_fixed_p50_speedup": f50 / max(b50, 1e-12),
        },
        "serve_loop": server.metrics.snapshot(),
        "serve_modes": {
            "step_rps": step_rps,
            "async_rps": async_rps,
            "async_vs_step": async_rps / step_rps,
            "submit_threads": 4,
        },
        "serve_pipeline": {
            "sim_device_ms": args.sim_device_ms,
            "max_batch": pipe_max,
            "requests_per_point": n_pipe,
            "workers_compared": [1, args.pipeline_workers],
            "sweep": sweep,
            "saturated_speedup": pipe_speedup,
            "bit_identical_outputs": True,
        },
        "tracer": {
            "off_rps": tracer_off_rps,
            "on_rps": tracer_on_rps,
            "disabled_vs_baseline": tracer_off_rps / step_rps,
            "enabled_vs_disabled": tracer_on_rps / tracer_off_rps,
        },
        "env": {
            "jax": jax.__version__,
            "jax_backend": jax.default_backend(),
            "python": platform.python_version(),
            # device count + mesh shape make the perf trajectory comparable
            # across environments (single vs forced-multi-device hosts)
            "devices": jax.device_count(),
            "mesh": {"model": mesh.model if mesh else 1,
                     "data": mesh.data if mesh else 1},
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.plan_dir is None:
        shutil.rmtree(plan_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
