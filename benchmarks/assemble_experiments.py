"""Insert the generated §Dry-run and §Roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.assemble_experiments
"""

import io
import os
import re
import sys
from contextlib import redirect_stdout

from . import gen_experiments

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PATH = os.path.join(ROOT, "EXPERIMENTS.md")


def capture(fn) -> str:
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn()
    return buf.getvalue()


def main():
    text = open(PATH).read()
    dr = capture(gen_experiments.dryrun_table)
    rl = capture(gen_experiments.roofline_table)
    text = re.sub(r"<!-- GENERATED:DRYRUN -->(.|\n)*?(?=\n---)",
                  "<!-- GENERATED:DRYRUN -->\n\n" + dr, text, count=1) \
        if "GENERATED:DRYRUN -->\n\n|" in text else text.replace(
        "<!-- GENERATED:DRYRUN -->", "<!-- GENERATED:DRYRUN -->\n\n" + dr)
    text = text.replace("<!-- GENERATED:ROOFLINE -->",
                        "<!-- GENERATED:ROOFLINE -->\n\n" + rl)
    open(PATH, "w").write(text)
    print(f"EXPERIMENTS.md updated ({len(dr.splitlines())} dry-run rows, "
          f"{len(rl.splitlines())} roofline rows)")


if __name__ == "__main__":
    main()
