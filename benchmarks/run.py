"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all paper figures
    PYTHONPATH=src python -m benchmarks.run fig6 fig7  # a subset
    REPRO_BENCH_SCALE=paper ...                        # full paper scale

Prints ``name,us_per_call,derived`` CSV.  The roofline table has its own
entry point: ``python -m benchmarks.roofline`` (reads the dry-run artifacts).
"""

from __future__ import annotations

import sys

from . import paper_figs

GROUPS = {
    "fig2": [paper_figs.fig2_density, paper_figs.fig2_depth,
             paper_figs.fig2_width, paper_figs.fig2_memory],
    "fig3": [paper_figs.fig3_compact_growth],
    "fig4": [paper_figs.fig4_eviction_policies],
    "fig5": [paper_figs.fig5_memory_sizes],
    "fig6": [paper_figs.fig6_bert],
    "fig7": [paper_figs.fig7_random_mlp_timing],
    "fig8": [paper_figs.fig8_bert_timing],
}


def main() -> None:
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    selected = args or list(GROUPS)
    print("name,us_per_call,derived")
    for group in selected:
        for fn in GROUPS[group]:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()


if __name__ == "__main__":
    main()
