"""One benchmark per paper table/figure (Gleinig et al. 2023, §VI).

Each function yields CSV rows ``name,us_per_call,derived``:
  * ``us_per_call``: wall time of the dominant operation (one exact I/O
    simulation for the simulated experiments; one forward for the timing
    experiments);
  * ``derived``: the figure's actual quantities (exact I/O counts, bounds,
    reduction percentages, speedups).

Scale notes (recorded in EXPERIMENTS.md): CR iteration counts default to
2,000 (paper: 1,000,000) — the paper's own Fig. 4 shows the bulk of the
reduction lands early; pass REPRO_BENCH_SCALE=paper for full-width runs.
"""

from __future__ import annotations

import os
import time
from typing import Iterator, Tuple

import numpy as np

from repro.core import (
    connection_reordering,
    generate,
    random_ffnn,
    simulate,
    theorem1_bounds,
)
from repro.core.graph import from_dense_weights

FULL = os.environ.get("REPRO_BENCH_SCALE", "default") == "paper"
BASE_W = 500 if FULL else 250         # paper baseline: 500-wide, 4 layers
BASE_T = 20_000 if FULL else 2_000    # paper: 1e6
BERT_T = 2_000 if FULL else 400
Row = Tuple[str, float, str]


def _cr(net, M, T=None, policy="min", seed=0):
    t0 = time.time()
    order0 = net.theorem1_order()
    init = simulate(net, order0, M, policy)
    sim_us = (time.time() - t0) * 1e6
    res = connection_reordering(net, order0, M, policy=policy,
                                T=T or BASE_T, seed=seed)
    lo = theorem1_bounds(net).total_lo
    red = 100.0 * (init.total - res.ios) / max(1, init.total)
    gap_closed = 100.0 * (init.total - res.ios) / max(1, init.total - lo)
    return sim_us, (f"initial={init.total} reordered={res.ios} lower={lo} "
                    f"reduction={red:.1f}% gap_closed={gap_closed:.1f}%")


def fig2_density() -> Iterator[Row]:
    """CR vs edge density (paper Fig. 2a)."""
    for dens in (0.05, 0.1, 0.2, 0.4):
        net = random_ffnn(BASE_W, 4, dens, seed=1)
        us, derived = _cr(net, M=100)
        yield (f"fig2a_density_{dens}", us, f"W={net.W} {derived}")


def fig2_depth() -> Iterator[Row]:
    """CR vs depth (paper Fig. 2b)."""
    for depth in (2, 4, 8):
        net = random_ffnn(BASE_W, depth, 0.1, seed=2)
        us, derived = _cr(net, M=100)
        yield (f"fig2b_depth_{depth}", us, f"W={net.W} {derived}")


def fig2_width() -> Iterator[Row]:
    """CR vs width (paper Fig. 2c)."""
    for width in (100, 250, 500):
        net = random_ffnn(width, 4, 0.1, seed=3)
        us, derived = _cr(net, M=100)
        yield (f"fig2c_width_{width}", us, f"W={net.W} {derived}")


def fig2_memory() -> Iterator[Row]:
    """CR vs fast-memory size (paper Fig. 2d)."""
    net = random_ffnn(BASE_W, 4, 0.1, seed=4)
    for M in (10, 50, 100, 400):
        us, derived = _cr(net, M=M)
        yield (f"fig2d_M_{M}", us, derived)


def fig3_compact_growth() -> Iterator[Row]:
    """CG nets hit the lower bound exactly when M >= M_g (paper Fig. 3)."""
    for Mg in (100, 300, 500):
        cg = generate(M_g=Mg, n_iters=1000, in_degree=4, seed=Mg)
        b = theorem1_bounds(cg.net)
        for M in (Mg // 2, Mg - 10, Mg, Mg + 100):
            if M < 3:
                continue
            t0 = time.time()
            s = simulate(cg.net, cg.order, M, "min")
            us = (time.time() - t0) * 1e6
            yield (f"fig3_Mg{Mg}_M{M}", us,
                   f"ios={s.total} lower={b.total_lo} "
                   f"optimal={s.total == b.total_lo}")


def fig4_eviction_policies() -> Iterator[Row]:
    """CR under RR / LRU / MIN (paper Fig. 4)."""
    net = random_ffnn(BASE_W, 4, 0.1, seed=5)
    for policy in ("rr", "lru", "min"):
        us, derived = _cr(net, M=100, policy=policy,
                          T=max(400, BASE_T // 4))
        yield (f"fig4_{policy}", us, derived)


def fig5_memory_sizes() -> Iterator[Row]:
    """I/O vs M before/after CR; convergence to the bound (paper Fig. 5)."""
    net = random_ffnn(BASE_W, 3, 0.01, seed=6)
    lo = theorem1_bounds(net).total_lo
    for M in (5, 20, 100, 500, 2000):
        order = net.theorem1_order()
        t0 = time.time()
        before = simulate(net, order, M, "min").total
        us = (time.time() - t0) * 1e6
        res = connection_reordering(net, order, M, T=max(400, BASE_T // 4),
                                    seed=M)
        yield (f"fig5_M_{M}", us,
               f"before={before} after={res.ios} lower={lo}")


def fig6_bert() -> Iterator[Row]:
    """Pruned BERT-large encoder FFNN (1024x4096x1024), M=100 (paper Fig. 6).

    Weights are synthetic (no pretrained checkpoint offline) but the shapes
    and magnitude-pruning procedure match the paper."""
    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((1024, 4096)).astype(np.float32)
    w2 = rng.standard_normal((4096, 1024)).astype(np.float32)
    for dens in (0.02, 0.05, 0.1):
        net = from_dense_weights([w1, w2], density=dens, seed=0)
        lo = theorem1_bounds(net).total_lo
        for policy in ("lru", "min"):
            order = net.theorem1_order()
            t0 = time.time()
            init = simulate(net, order, 100, policy).total
            us = (time.time() - t0) * 1e6
            res = connection_reordering(net, order, 100, policy=policy,
                                        T=BERT_T, seed=1)
            yield (f"fig6_bert_d{dens}_{policy}", us,
                   f"W={net.W} initial={init} reordered={res.ios} lower={lo}")


# ---------------------------------------------------------------------------
# wall-clock (paper Fig. 7/8 analogue, CPU, JAX executors)
# ---------------------------------------------------------------------------

def _timing_pair(sizes, density, batch=128, block=64, reps=5):
    import jax
    import jax.numpy as jnp

    from repro.core.blocksparse import to_bsr
    from repro.sparse.layers import ScheduledSparseFFNN, prune_dense_stack

    rng = np.random.default_rng(0)
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32)
          * 0.05 for i in range(len(sizes) - 1)]
    bs = [np.zeros(sizes[i + 1], np.float32) for i in range(len(sizes) - 1)]
    x = jnp.asarray(rng.standard_normal((batch, sizes[0])), jnp.float32)

    # layer-based dense executor (the CSRMM-role baseline on this backend)
    mats = [jnp.asarray(w) for w in ws]

    @jax.jit
    def dense_forward(x):
        h = x
        for i, w in enumerate(mats):
            h = h @ w
            if i < len(mats) - 1:
                h = jax.nn.relu(h)
        return h

    layers = prune_dense_stack(ws, bs, density=density, block_m=block,
                               block_n=block)
    net = ScheduledSparseFFNN.build(layers)

    # scheduled block-computation executor (jnp; computes only nonzero blocks
    # in the paper-ordered schedule)
    def make_sched(layer, sch):
        rows = jnp.asarray(sch.rows[:layer.nnz_blocks])
        cols = jnp.asarray(sch.cols[:layer.nnz_blocks])
        blocks = jnp.asarray(sch.blocks[:layer.nnz_blocks])
        go = layer.grid_out

        def f(h, act):
            xt = h.reshape(batch, -1, layer.block_m)[:, rows]   # [B,nnz,bm]
            yt = jnp.einsum("bnm,nmk->bnk", xt, blocks)
            out = jax.ops.segment_sum(yt.transpose(1, 0, 2), cols,
                                      num_segments=go)
            out = out.transpose(1, 0, 2).reshape(batch, -1)
            return jax.nn.relu(out) if act else out
        return f

    fns = [make_sched(l, s) for l, s in zip(net.layers, net.schedules)]

    @jax.jit
    def sparse_forward(x):
        h = x
        for i, f in enumerate(fns):
            h = f(h, i < len(fns) - 1)
        return h

    dense_forward(x).block_until_ready()
    sparse_forward(x).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        dense_forward(x).block_until_ready()
    t_dense = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        sparse_forward(x).block_until_ready()
    t_sparse = (time.time() - t0) / reps
    ios = net.simulated_ios(M_tiles=3).total
    return t_dense, t_sparse, ios


def fig7_random_mlp_timing() -> Iterator[Row]:
    """Scheduled sparse vs layer-dense wall clock, random MLPs (Fig. 7a)."""
    for dens in (0.05, 0.1, 0.3):
        td, ts, ios = _timing_pair((512,) * 5, dens)
        yield (f"fig7_density_{dens}", ts * 1e6,
               f"dense_us={td*1e6:.0f} sparse_us={ts*1e6:.0f} "
               f"speedup={td/ts:.2f}x tile_ios={ios}")


def fig8_bert_timing() -> Iterator[Row]:
    """BERT FFNN shapes wall clock (Fig. 8)."""
    for dens in (0.05, 0.1):
        td, ts, ios = _timing_pair((1024, 4096, 1024), dens, block=128)
        yield (f"fig8_bert_density_{dens}", ts * 1e6,
               f"dense_us={td*1e6:.0f} sparse_us={ts*1e6:.0f} "
               f"speedup={td/ts:.2f}x tile_ios={ios}")
