"""Engine vs. layer-by-layer dispatch latency.

    PYTHONPATH=src python benchmarks/bench_engine.py [--density 0.2] [--batch 32]

Measures, for the same pruned multi-layer FFNN and the same connection
schedule:

  * layer-by-layer: one ``scheduled_bsr_layer`` dispatch per layer (the
    pre-engine call pattern — per-layer ``pallas_call``/jit boundaries);
  * engine: the fused plan from ``Engine.compile`` (single jitted program);

and reports wall latency plus the plan's simulated tile I/O next to the
Theorem-1 bounds.  On CPU hosts the comparison runs on the ``jnp`` backend
(the Pallas interpret mode is a correctness path, not a perf path); on TPU
pass ``--backend pallas``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import Engine, make_forward
from repro.sparse import prune_dense_stack


def timeit(fn, x, iters: int, warmup: int = 3) -> float:
    """Median wall time per call (seconds)."""
    for _ in range(warmup):
        fn(x).block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[1024, 4096, 2048, 1024])
    ap.add_argument("--density", type=float, default=0.2)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--reorder-iters", type=int, default=300)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "interpret", "jnp"))
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    sizes = args.sizes
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.03
          for i in range(len(sizes) - 1)]
    bs = [np.zeros(s, np.float32) for s in sizes[1:]]
    layers = prune_dense_stack(ws, bs, density=args.density,
                               block_m=args.block, block_n=args.block)

    engine = Engine(backend=args.backend, activation="relu", reorder=True,
                    reorder_iters=args.reorder_iters)
    t0 = time.time()
    plan = engine.compile(layers)
    print(f"compile: {time.time()-t0:.2f}s — {plan.describe()}")

    x = jnp.asarray(rng.standard_normal((args.batch, sizes[0])), jnp.float32)

    # layer-by-layer: same schedules/backend, but one jitted dispatch per
    # layer — the pre-engine call pattern.
    per_layer = [
        make_forward([lay], [sch], [act], plan.backend)
        for lay, sch, act in zip(plan.layers, plan.schedules, plan.activations)
    ]

    def layer_by_layer(h):
        for fn in per_layer:
            h = fn(h)
        return h

    t_layered = timeit(layer_by_layer, x, args.iters)
    t_engine = timeit(plan, x, args.iters)

    np.testing.assert_allclose(np.asarray(layer_by_layer(x)),
                               np.asarray(plan(x)), rtol=1e-5, atol=1e-5)

    print(f"backend={plan.backend} batch={args.batch} "
          f"net={'x'.join(map(str, sizes))} density={args.density}")
    print(f"  layer-by-layer: {1e3*t_layered:8.2f} ms/batch")
    print(f"  engine (fused): {1e3*t_engine:8.2f} ms/batch "
          f"({t_layered/max(t_engine,1e-12):.2f}x)")


if __name__ == "__main__":
    main()
