"""Megakernel vs. layer-by-layer dispatch latency + annealer delta speedup.

    PYTHONPATH=src python benchmarks/bench_engine.py [--density 0.2] [--batch 32]

Measures, for the same pruned multi-layer FFNN and the same connection
schedule:

  * layered: one dispatch per layer (the PR-1 call pattern — per-layer
    ``pallas_call``/jnp boundaries, hidden state through HBM each boundary);
  * fused: the flat cross-layer schedule from ``Engine.compile`` — the
    megakernel on pallas/interpret, one segment pass on jnp;
  * reorder: per-proposal cost of the annealer's windowed incremental I/O
    delta evaluation (``core.iosim.IncrementalSimulator``) vs a full O(W)
    ``simulate()`` per proposal, on the same proposal stream;

and reports simulated tile I/O next to the Theorem-1 bounds plus the fused
plan's cross-layer savings.  Results are printed AND written to a
machine-readable ``BENCH_engine.json`` so the perf trajectory is tracked
across PRs (CI uploads it as an artifact).

On CPU hosts the latency comparison runs on the ``jnp`` backend (the Pallas
interpret mode is a correctness path, not a perf path); on TPU pass
``--backend pallas``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.iosim import IncrementalSimulator, simulate
from repro.core import _iosim_c
from repro.engine import Engine, make_forward
from repro.sparse import prune_dense_stack


def timeit(fn, x, iters: int, warmup: int = 3) -> float:
    """Median wall time per call (seconds)."""
    for _ in range(warmup):
        fn(x).block_until_ready()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_reorder(net, order, M: int, iters: int, seed: int = 0) -> dict:
    """Per-proposal cost: windowed incremental delta vs full re-simulation.

    Replays the identical proposal stream through both evaluators (the delta
    totals are exact, so both see the same accept/reject costs)."""
    rng = np.random.default_rng(seed)
    src32 = np.ascontiguousarray(net.src, dtype=np.int32)
    dst32 = np.ascontiguousarray(net.dst, dtype=np.int32)
    avg_in = net.W / max(1, net.N - net.I)
    ws = max(1, int(round(4 * avg_in)))
    cur = np.ascontiguousarray(order, dtype=np.int64).copy()
    moves = []
    for _ in range(iters):
        i = int(rng.integers(0, net.W))
        w = int(rng.integers(0, ws))
        d = 0 if rng.random() < 0.5 else 1
        cand = cur.copy()
        if not _iosim_c.propose_move_c(cand, src32, dst32, i, w, d):
            from repro.core.reorder import _apply_move
            cand = np.array(_apply_move(cur.tolist(), net.src.tolist(),
                                        net.dst.tolist(), i, w, d), np.int64)
        moves.append(cand)

    sim = IncrementalSimulator(net, cur, M)
    t0 = time.perf_counter()
    delta_totals = [sim.propose(c) for c in moves]
    t_delta = (time.perf_counter() - t0) / len(moves)
    t0 = time.perf_counter()
    full_totals = [simulate(net, c, M, "min").total for c in moves]
    t_full = (time.perf_counter() - t0) / len(moves)
    assert delta_totals == full_totals, "delta evaluation diverged from full"
    return {
        "proposals": len(moves),
        "W_blocks": int(net.W),
        "delta_ms_per_proposal": 1e3 * t_delta,
        "full_ms_per_proposal": 1e3 * t_full,
        "speedup": t_full / max(t_delta, 1e-12),
    }


def bench_dynamic_sparsity(backend: str, batch: int, iters: int) -> dict:
    """Occupancy-gating sweep: ReLU nets at varying *dynamic* sparsity.

    The same pruned net is run with a growing fraction of its hidden tiles
    forced dead (bias ``-10`` drives every pre-activation in the tile below
    zero, so ReLU zeroes it for any input in range) — static structure and
    schedule identical across the sweep, only the runtime activation
    sparsity changes.  For each point: assert the gated forward is
    bit-identical to the ungated one, measure dynamic vs static weight-block
    reads, and time both forwards.
    """
    rng = np.random.default_rng(1)
    sizes = [256, 512, 512, 256]
    block = 64
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32)
          * 0.03 for i in range(len(sizes) - 1)]
    bs = [np.zeros(s, np.float32) for s in sizes[1:]]
    base_layers = prune_dense_stack(ws, bs, density=0.3,
                                    block_m=block, block_n=block)
    x = jnp.asarray(rng.standard_normal((batch, sizes[0])), jnp.float32)

    sweep = []
    for frac in (0.0, 0.25, 0.5, 0.75):
        layers = []
        for k, lay in enumerate(base_layers):
            if k < len(base_layers) - 1:
                kill = int(frac * lay.grid_out)
                bias = np.array(lay.bias, np.float32)
                bias.reshape(lay.grid_out, lay.block_n)[:kill] = -10.0
                lay = dataclasses.replace(lay, bias=bias)
            layers.append(lay)
        gated = Engine(backend=backend, activation="relu",
                       gate=True).compile(layers)
        ungated = Engine(backend=backend,
                         activation="relu").compile(layers)
        np.testing.assert_array_equal(np.asarray(gated(x)),
                                      np.asarray(ungated(x)))
        rep = gated.measure_dynamic(x)
        if frac >= 0.5:
            assert rep.dynamic_total < rep.static_total, (
                f"gating read no fewer blocks than the static schedule at "
                f"{frac:.0%} dead tiles: {rep.summary()}"
            )
        t_gated = timeit(gated, x, iters)
        t_ungated = timeit(ungated, x, iters)
        print(f"  gate sweep frac={frac:.2f}: read "
              f"{rep.dynamic_total}/{rep.static_total} blocks "
              f"({100 * rep.read_fraction:.0f}%), "
              f"gated {1e3*t_gated:.2f} ms vs ungated {1e3*t_ungated:.2f} ms")
        sweep.append({
            "dead_tile_fraction": frac,
            "static_blocks": rep.static_total,
            "dynamic_blocks": rep.dynamic_total,
            "blocks_skipped": rep.blocks_skipped,
            "read_fraction": rep.read_fraction,
            "latency_ms_gated": 1e3 * t_gated,
            "latency_ms_ungated": 1e3 * t_ungated,
        })
    return {
        "net": {"sizes": sizes, "density": 0.3, "block": block,
                "batch": batch},
        "sweep": sweep,
    }


def bench_weight_stream(layers, backend: str, x, iters: int,
                        reorder_iters: int) -> dict:
    """Quantized weight-stream sweep: bytes moved, latency, and error.

    The SAME schedule runs at f32/bf16/fp8 weight storage — tile counts and
    Theorem-1 bounds are dtype-invariant, only the bytes per streamed block
    shrink.  Asserts the acceptance floor: bf16 <= 0.55x the f32 weight
    bytes (>= 1.8x reduction), fp8 >= 3.5x, with bounded output error.
    """
    from repro.kernels.ops import FP8_DTYPE

    dtypes = ["f32", "bf16"] + (["fp8"] if FP8_DTYPE is not None else [])
    max_rel_err = {"f32": 0.0, "bf16": 1e-2, "fp8": 1e-1}
    min_reduction = {"bf16": 1.8, "fp8": 3.5}
    sweep = []
    y_ref = None
    f32_bytes = 0
    for wdt in dtypes:
        plan = Engine(backend=backend, activation="relu", reorder=True,
                      reorder_iters=reorder_iters,
                      weight_dtype=wdt).compile(layers)
        y = np.asarray(plan(x), np.float32)
        if wdt == "f32":
            y_ref = y
            f32_bytes = plan.io.weight_stream_bytes
        rel = float(np.max(np.abs(y - y_ref))
                    / max(1e-9, np.max(np.abs(y_ref))))
        assert rel <= max_rel_err[wdt], (
            f"{wdt} output error {rel:.4f} exceeds {max_rel_err[wdt]}")
        reduction = f32_bytes / plan.io.weight_stream_bytes
        if wdt in min_reduction:
            assert reduction >= min_reduction[wdt], (
                f"{wdt} weight-stream bytes shrank only {reduction:.2f}x "
                f"(need >= {min_reduction[wdt]}x)")
        t = timeit(plan, x, iters)
        print(f"  weight stream {wdt:>4}: "
              f"{plan.io.weight_stream_bytes:>9} B/forward "
              f"({reduction:.2f}x vs f32), {1e3*t:.2f} ms/batch, "
              f"max rel err {rel:.2e}")
        sweep.append({
            "weight_dtype": wdt,
            "weight_bytes_streamed": plan.io.weight_bytes_streamed,
            "scale_bytes_streamed": plan.io.scale_bytes_streamed,
            "weight_stream_bytes": plan.io.weight_stream_bytes,
            "bytes_reduction_vs_f32": reduction,
            "latency_ms": 1e3 * t,
            "max_rel_err_vs_f32": rel,
        })
    return {"sweep": sweep, "dtypes": dtypes}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[768, 1536, 1536, 1536, 1536, 768])
    ap.add_argument("--density", type=float, default=0.2)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--reorder-iters", type=int, default=300,
                    help="annealing budget for the compiled plan AND the "
                         "proposal count of the delta-vs-full comparison")
    ap.add_argument("--reorder-block", type=int, default=16,
                    help="tile size for the delta-evaluation benchmark DAG "
                         "(finer tiles -> the 10k+-block regime the "
                         "incremental evaluator targets)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "interpret", "jnp"))
    ap.add_argument("--out", default="BENCH_engine.json",
                    help="where to write the machine-readable results")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    sizes = args.sizes
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.03
          for i in range(len(sizes) - 1)]
    bs = [np.zeros(s, np.float32) for s in sizes[1:]]
    layers = prune_dense_stack(ws, bs, density=args.density,
                               block_m=args.block, block_n=args.block)

    engine = Engine(backend=args.backend, activation="relu", reorder=True,
                    reorder_iters=args.reorder_iters)
    t0 = time.time()
    plan = engine.compile(layers)
    compile_s = time.time() - t0
    print(f"compile: {compile_s:.2f}s — {plan.describe()}")
    assert plan.fused, "expected the fused flat-schedule plan"

    # the layered baseline: same layers, same schedule arrays, same backend,
    # but one *jitted dispatch per layer* — the PR-1 call pattern the
    # megakernel replaces (hidden state crosses HBM at every boundary)
    per_layer = [
        make_forward([lay], [sch], [act], plan.backend)
        for lay, sch, act in zip(plan.layers, plan.schedules,
                                 plan.activations)
    ]

    def layered(h):
        for fn in per_layer:
            h = fn(h)
        return h

    x = jnp.asarray(rng.standard_normal((args.batch, sizes[0])), jnp.float32)
    t_layered = timeit(layered, x, args.iters)
    t_fused = timeit(plan, x, args.iters)
    speedup = t_layered / max(t_fused, 1e-12)

    np.testing.assert_allclose(np.asarray(layered(x)),
                               np.asarray(plan(x)), rtol=1e-5, atol=1e-5)

    print(f"backend={plan.backend} batch={args.batch} "
          f"net={'x'.join(map(str, sizes))} density={args.density}")
    print(f"  layered (per-layer dispatch): {1e3*t_layered:8.2f} ms/batch")
    print(f"  fused   (megakernel path):    {1e3*t_fused:8.2f} ms/batch "
          f"({speedup:.2f}x)")

    # delta evaluation: benchmark on a finer-grained block DAG of the same
    # net — the 10k+-block regime "CR at scale" targets
    from repro.core.blocksparse import to_block_ffnn
    from repro.core.graph import drop_isolated
    fine_layers = prune_dense_stack(ws, bs, density=args.density,
                                    block_m=args.reorder_block,
                                    block_n=args.reorder_block)
    fine_net = to_block_ffnn(fine_layers).net
    fine_order = fine_net.theorem1_order()
    reorder_stats = bench_reorder(fine_net, fine_order, engine.M_tiles,
                                  iters=args.reorder_iters)
    print(f"  reorder: {reorder_stats['delta_ms_per_proposal']:.3f} ms/proposal "
          f"(delta) vs {reorder_stats['full_ms_per_proposal']:.3f} ms (full) "
          f"-> {reorder_stats['speedup']:.1f}x over "
          f"{reorder_stats['proposals']} proposals, "
          f"W={reorder_stats['W_blocks']} blocks")

    print("dynamic-sparsity gating sweep (ReLU, forced-dead hidden tiles):")
    dyn_stats = bench_dynamic_sparsity(plan.backend, args.batch, args.iters)

    print("quantized weight-stream sweep (same schedule, narrower storage):")
    quant_stats = bench_weight_stream(layers, plan.backend, x, args.iters,
                                      reorder_iters=args.reorder_iters)

    io = plan.io
    result = {
        "net": {
            "sizes": sizes,
            "density": args.density,
            "block": args.block,
            "batch": args.batch,
            "nnz_blocks": int(sum(l.nnz_blocks for l in layers)),
        },
        "backend": plan.backend,
        "fused": plan.fused,
        "compile_s": compile_s,
        "latency_ms": {
            "layered": 1e3 * t_layered,
            "fused": 1e3 * t_fused,
        },
        "fused_vs_layered_speedup": speedup,
        "io": {
            "simulated_reads": io.simulated.reads,
            "simulated_writes": io.simulated.writes,
            "simulated_total": io.simulated.total,
            "bound_total_lo": io.bounds.total_lo,
            "bound_total_hi": io.bounds.total_hi,
            "optimality_ratio": io.optimality_ratio,
            "within_bounds": io.within_bounds,
            "layered_total": io.layered_total,
            "cross_layer_savings": io.cross_layer_savings,
            "hidden_tiles_kept": io.hidden_tiles_kept,
            "hidden_bytes_kept_per_row": io.hidden_bytes_kept_per_row,
        },
        "reorder": reorder_stats,
        "dynamic_sparsity": dyn_stats,
        "weight_stream": quant_stats,
        "env": {
            "jax": jax.__version__,
            "jax_backend": jax.default_backend(),
            "python": platform.python_version(),
            # device count + mesh shape make the perf trajectory comparable
            # across environments (single vs forced-multi-device hosts)
            "devices": jax.device_count(),
            "mesh": {"model": 1, "data": 1},
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
