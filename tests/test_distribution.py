"""Distribution tests on a small multi-device host mesh.

These run in subprocesses because the host device count must be fixed via
XLA_FLAGS before jax initializes (the main pytest process keeps 1 device,
as required for the smoke tests)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 520) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_moe_a2a_matches_dense_dispatch():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.configs import get_config, reduced
        from repro.models.layers import init_moe, moe_dense, moe_a2a
        from repro.models.sharding import axes_from_mesh
        cfg = reduced(get_config('granite-moe-1b-a400m'))
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
        mesh = make_mesh((2, 2), ('data', 'model'),
                             axis_types=(AxisType.Auto,)*2)
        axes_from_mesh(mesh); set_mesh(mesh)
        rng = np.random.default_rng(0)
        p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)), jnp.float32)
        yd, auxd = jax.jit(lambda p_, x_: moe_dense(p_, x_, cfg))(p, x)
        ya, auxa = jax.jit(lambda p_, x_: moe_a2a(p_, x_, cfg, mesh))(p, x)
        err = float(jnp.max(jnp.abs(yd - ya)))
        print('ERR', err, float(auxd), float(auxa))
        assert err < 1e-4, err
        # aux is a per-shard estimator under a2a (mean of shard-local
        # E*sum(me*ce) != global formula) — both are standard; just sane:
        assert 0.5 < float(auxa) / float(auxd) < 2.0
    """)
    assert "ERR" in out


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import AxisType, make_mesh, named_shardings, set_mesh
        from repro.configs import get_config, reduced
        from repro.launch import partition
        from repro.launch.steps import make_train_step
        from repro.models import lm
        from repro.models.sharding import axes_from_mesh
        from repro.optim import OptConfig, adamw_init
        cfg = reduced(get_config('codeqwen1.5-7b'))
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (4, 32))),
                 'labels': jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)))}
        results = {}
        for shape, name in [((1, 1), 'single'), ((2, 2), 'sharded')]:
            mesh = make_mesh(shape, ('data', 'model'),
                                 axis_types=(AxisType.Auto,)*2)
            axes_from_mesh(mesh); set_mesh(mesh)
            params = lm.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
            p_specs = partition.params_specs(mesh, jax.eval_shape(lambda: params))
            params = jax.device_put(params, partition.to_named(mesh, p_specs))
            opt = adamw_init(params)
            o_specs = partition.opt_specs(mesh, jax.eval_shape(lambda: opt), p_specs)
            opt = jax.device_put(opt, partition.to_named(mesh, o_specs))
            step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3), mesh),
                           in_shardings=named_shardings(mesh, (p_specs, o_specs, None)),
                           out_shardings=named_shardings(mesh, (p_specs, o_specs, None)))
            p2, o2, m = step(params, opt, batch)
            results[name] = (float(m['loss']), jax.device_get(p2))
        l1, w1 = results['single']; l2, w2 = results['sharded']
        print('LOSS', l1, l2)
        assert abs(l1 - l2) < 1e-3
        for a, b in zip(jax.tree.leaves(w1), jax.tree.leaves(w2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-3)
        print('MATCH')
    """)
    assert "MATCH" in out


def test_elastic_reshard_4_to_2_devices(tmp_path):
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config, reduced
        from repro.launch import partition
        from repro.models import lm
        from repro.models.sharding import axes_from_mesh
        from repro.optim import adamw_init
        from repro.runtime.elastic import reshard_checkpoint
        cfg = reduced(get_config('mamba2-1.3b'))
        mesh4 = make_mesh((2, 2), ('data', 'model'),
                              axis_types=(AxisType.Auto,)*2)
        axes_from_mesh(mesh4); set_mesh(mesh4)
        params = lm.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        p_specs = partition.params_specs(mesh4, jax.eval_shape(lambda: params))
        params = jax.device_put(params, partition.to_named(mesh4, p_specs))
        opt = adamw_init(params)
        ck = CheckpointManager({str(tmp_path)!r}, keep=2)
        ck.save(3, {{'params': params, 'opt': opt}})
        mesh2 = make_mesh((2, 1), ('data', 'model'),
                              axis_types=(AxisType.Auto,)*2)
        p_shape = jax.eval_shape(lambda: params)
        o_shape = jax.eval_shape(lambda: opt)
        p2, o2 = reshard_checkpoint(ck, cfg, mesh2, p_shape, o_shape)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(a)).view(np.uint8),
                np.asarray(jax.device_get(b)).view(np.uint8))
        devs = {{d for leaf in jax.tree.leaves(p2) for d in leaf.devices()}}
        print('DEVICES', len(devs))
        assert len(devs) == 2
    """)
    assert "DEVICES 2" in out


def test_ring_matmul_matches_allgather_matmul():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import AxisType, make_mesh, set_mesh
        from repro.runtime.overlap import ring_ag_matmul
        mesh = make_mesh((2, 4), ('data', 'model'),
                             axis_types=(AxisType.Auto,)*2)
        set_mesh(mesh)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 64)) * 0.1, jnp.float32)
        y = jax.jit(lambda x_, w_: ring_ag_matmul(x_, w_, mesh, 'data'))(x, w)
        ref = jnp.einsum('bsd,df->bsf', x, w)
        err = float(jnp.max(jnp.abs(y - ref)))
        print('ERR', err)
        assert err < 1e-4
    """)
    assert "ERR" in out


def test_quantized_psum_on_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_mesh, set_mesh
        from jax.experimental.shard_map import shard_map
        from repro.runtime.compression import quantized_psum
        mesh = make_mesh((4,), ('data',), axis_types=(AxisType.Auto,))
        set_mesh(mesh)
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
        fn = shard_map(lambda x: quantized_psum(x[0], 'data'), mesh=mesh,
                       in_specs=P('data', None), out_specs=P(),
                       check_rep=False)
        out = jax.jit(fn)(g)
        ref = jnp.sum(g, 0)
        rel = float(jnp.max(jnp.abs(out - ref) / (1 + jnp.abs(ref))))
        print('REL', rel)
        assert rel < 0.05  # int8 representatives
    """)
    assert "REL" in out
