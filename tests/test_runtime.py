"""Runtime tests: checkpointing, fault tolerance, stragglers, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step
from repro.runtime.compression import (
    dequantize_int8,
    init_compression,
    quantize_int8,
    topk_compress_with_feedback,
)
from repro.runtime.failure import (
    FaultInjector,
    ResilientTrainer,
    StragglerMonitor,
)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
        "b16": jnp.asarray(rng.standard_normal((4, 4)), jnp.bfloat16),
        "nested": {"s": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip_with_bf16(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    out = load_checkpoint(str(tmp_path), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_crc_detects_corruption(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 2, t)
    # corrupt one leaf
    victim = os.path.join(path, "leaf_00000.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="crc"):
        load_checkpoint(str(tmp_path), t, step=2)


def test_manager_retention_and_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        m.async_save(s, t)
    m.wait()
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_restore_mismatched_tree_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"only": jnp.zeros((2,))}, step=1)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def _toy_step():
    """y = w*x regression; returns a train_step-compatible callable."""

    def step(params, opt_state, batch):
        def loss(p):
            return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

        lval, g = jax.value_and_grad(loss)(params)
        new = {"w": params["w"] - 0.05 * g["w"]}
        return new, opt_state, {"loss": lval}

    return jax.jit(step)


def _toy_batches(step):
    rng = np.random.default_rng(step)
    x = rng.standard_normal((16, 4)).astype(np.float32)
    w_true = np.arange(4, dtype=np.float32)[:, None]
    return {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true)}


def test_resilient_trainer_recovers_from_injected_faults(tmp_path):
    params = {"w": jnp.zeros((4, 1), jnp.float32)}
    trainer = ResilientTrainer(
        _toy_step(), params, {}, CheckpointManager(str(tmp_path)),
        ckpt_every=5, fault_injector=FaultInjector([7, 13]))
    out = trainer.run(_toy_batches, 25)
    assert out["restarts"] == 2
    assert out["final_loss"] < out["losses"][0]
    assert trainer.step == 25
    fails = [h for h in out["history"] if h[0] == "failure"]
    assert len(fails) == 2


def test_resilient_trainer_determinism_vs_no_faults(tmp_path):
    """Replayed batches after restart give the same final weights."""
    p0 = {"w": jnp.zeros((4, 1), jnp.float32)}
    t_fault = ResilientTrainer(
        _toy_step(), p0, {}, CheckpointManager(str(tmp_path / "a")),
        ckpt_every=5, fault_injector=FaultInjector([8]))
    out_f = t_fault.run(_toy_batches, 20)
    t_clean = ResilientTrainer(
        _toy_step(), p0, {}, CheckpointManager(str(tmp_path / "b")),
        ckpt_every=5)
    out_c = t_clean.run(_toy_batches, 20)
    np.testing.assert_allclose(np.asarray(t_fault.params["w"]),
                               np.asarray(t_clean.params["w"]), rtol=1e-6)
    assert out_f["restarts"] == 1 and out_c["restarts"] == 0


def test_nan_loss_triggers_restart(tmp_path):
    calls = {"n": 0}

    def step(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            return params, opt_state, {"loss": jnp.asarray(float("nan"))}
        return params, opt_state, {"loss": jnp.asarray(1.0)}

    trainer = ResilientTrainer(step, {"w": jnp.zeros(2)}, {},
                               CheckpointManager(str(tmp_path)), ckpt_every=2)
    out = trainer.run(lambda s: {}, 5)
    assert out["restarts"] == 1
    assert trainer.step == 5


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(factor=3.0, warmup=2)
    for i, dt in enumerate([1.0, 1.0, 1.0, 1.0, 10.0, 1.0]):
        mon.observe(i, dt)
    assert len(mon.events) == 1
    assert mon.events[0].step == 4
    assert mon.events[0].factor > 3
    # outlier did not poison the EMA
    assert mon.ema < 2.0


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_topk_error_feedback_conservation():
    """sum(sent over steps) + final residual == sum(grads): nothing is lost."""
    params = {"a": jnp.zeros((64,)), "b": jnp.zeros((8, 8))}
    state = init_compression(params)
    rng = np.random.default_rng(0)
    total_g = jax.tree.map(jnp.zeros_like, state.error)
    total_sent = jax.tree.map(jnp.zeros_like, state.error)
    for step in range(10):
        g = {"a": jnp.asarray(rng.standard_normal(64), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
        sent, state, metrics = topk_compress_with_feedback(g, state,
                                                           k_frac=0.05)
        total_g = jax.tree.map(lambda t, x: t + x, total_g, g)
        total_sent = jax.tree.map(lambda t, x: t + x, total_sent, sent)
        assert metrics["sent_density"] <= 0.2
    for ts, tg, e in zip(jax.tree.leaves(total_sent), jax.tree.leaves(total_g),
                         jax.tree.leaves(state.error)):
        np.testing.assert_allclose(np.asarray(ts + e), np.asarray(tg),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), block=st.sampled_from([32, 256]))
def test_int8_quantization_error_bound(seed, block):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(500) * rng.uniform(0.1, 10),
                    jnp.float32)
    q, scale, shape, pad = quantize_int8(x, block)
    out = dequantize_int8(q, scale, shape, pad)
    # error per element bounded by half a quantization bin of its block
    blocks = np.pad(np.asarray(x), (0, pad)).reshape(-1, block)
    bins = np.abs(blocks).max(1, keepdims=True) / 127.0
    err = np.abs(np.pad(np.asarray(x - out), (0, pad)).reshape(-1, block))
    assert (err <= bins * 0.5 + 1e-6).all()
