"""Plan persistence: manifest round-trips and the content-addressed store.

The acceptance bar: schedule arrays (int32) and low-precision weights
(bf16/f8) restore bit-identical through the checkpoint manifest machinery,
and a plan-store hit rebuilds a plan with ZERO annealer iterations whose
outputs exactly match the cold compile it came from.
"""

import numpy as np
import pytest

ml_dtypes = pytest.importorskip("ml_dtypes")

from repro.checkpoint import (  # noqa: E402
    load_checkpoint,
    read_manifest_dir,
    save_checkpoint,
    write_manifest_dir,
)
from repro.engine import Engine, IOReport  # noqa: E402
from repro.serving import PlanStore, layers_fingerprint, plan_cache_key  # noqa: E402


def _bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape \
        and a.tobytes() == b.tobytes()


# --------------------------------------------------------------------------- #
# manifest round-trips (the storage layer the plan store sits on)
# --------------------------------------------------------------------------- #

def test_manifest_roundtrip_schedule_and_lowp_arrays(tmp_path):
    rng = np.random.default_rng(0)
    arrays = {
        "order": rng.permutation(100).astype(np.int64),
        "flat_rows": rng.integers(0, 8, 64).astype(np.int32),
        "bias_bf16": rng.standard_normal(33).astype(ml_dtypes.bfloat16),
    }
    f8 = getattr(ml_dtypes, "float8_e4m3fn", None)
    if f8 is not None:
        arrays["w_f8"] = rng.standard_normal(17).astype(f8)
    path = write_manifest_dir(str(tmp_path / "art"), arrays,
                              extra={"kind": "test", "n": 3})
    out, extra = read_manifest_dir(path)
    assert extra == {"kind": "test", "n": 3}
    assert set(out) == set(arrays)
    for name in arrays:
        assert _bitwise_equal(arrays[name], out[name]), name


def test_manifest_crc_detects_corruption(tmp_path):
    path = write_manifest_dir(str(tmp_path / "art"),
                              {"a": np.arange(10, dtype=np.int32)}, {})
    victim = tmp_path / "art" / "a.npy"
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="crc"):
        read_manifest_dir(path)


def test_manifest_write_is_atomic(tmp_path):
    write_manifest_dir(str(tmp_path / "art"), {"a": np.zeros(3)}, {})
    assert not any(n.endswith(".tmp") for n in
                   [p.name for p in tmp_path.iterdir()])


def test_checkpoint_roundtrips_schedule_and_lowp_weights(tmp_path):
    """int32 schedule/prefetch arrays and bf16/f8 weights through the full
    checkpoint save/load path restore bit-identical."""
    rng = np.random.default_rng(1)
    # int32 throughout: device_put (x64 disabled) would downcast int64 leaves;
    # the plan store itself reads manifests as host numpy, so its int64
    # ``order`` is untouched (covered by the manifest round-trip test above)
    tree = {
        "schedule": {"order": rng.permutation(50).astype(np.int32),
                     "rows": rng.integers(0, 9, 40).astype(np.int32),
                     "first": (rng.random(40) < 0.3).astype(np.int32)},
        "w_bf16": rng.standard_normal((8, 8)).astype(ml_dtypes.bfloat16),
    }
    f8 = getattr(ml_dtypes, "float8_e5m2", None)
    if f8 is not None:
        tree["w_f8"] = rng.standard_normal((4, 4)).astype(f8)
    save_checkpoint(str(tmp_path), 1, tree)
    out = load_checkpoint(str(tmp_path), tree)
    import jax
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert _bitwise_equal(a, b)


# --------------------------------------------------------------------------- #
# content addressing
# --------------------------------------------------------------------------- #

def test_fingerprint_is_content_addressed(make_stack):
    a = make_stack(seed=5)
    b = make_stack(seed=5)          # same content, different objects
    c = make_stack(seed=6)
    assert layers_fingerprint(a) == layers_fingerprint(b)
    assert layers_fingerprint(a) != layers_fingerprint(c)
    # perturbing ONE weight value changes the key
    b[0].blocks[0, 0, 0] += 1.0
    assert layers_fingerprint(a) != layers_fingerprint(b)


def test_cache_key_tracks_schedule_settings(make_stack):
    layers = make_stack()
    e1 = Engine(backend="jnp", reorder=True, reorder_iters=10, seed=0)
    e2 = Engine(backend="jnp", reorder=True, reorder_iters=10, seed=1)
    e3 = Engine(backend="jnp", reorder=False)
    assert plan_cache_key(e1, layers) != plan_cache_key(e2, layers)
    assert plan_cache_key(e1, layers) != plan_cache_key(e3, layers)
    # backend does NOT affect the key: the stored order serves all backends
    e4 = Engine(backend="interpret", reorder=True, reorder_iters=10, seed=0)
    assert plan_cache_key(e1, layers) == plan_cache_key(e4, layers)


def test_cache_key_sensitive_to_every_schedule_setting(make_stack):
    """Changing ANY schedule-affecting engine setting must change the key —
    a stale hit would serve a schedule the settings no longer describe."""
    layers = make_stack()
    base = Engine(backend="jnp")
    key0 = plan_cache_key(base, layers)
    import dataclasses as dc
    changed = {
        "reorder": True,
        "M_tiles": 5,
        "reorder_iters": 77,
        "seed": 9,
        "max_move_span": 32,
        "policy": "lru",
        "fuse": False,
        "weight_dtype": "bf16",
    }
    keys = [key0]
    for field, value in changed.items():
        k = plan_cache_key(dc.replace(base, **{field: value}), layers)
        assert k != key0, f"{field} change must be a key miss"
        keys.append(k)
    assert len(set(keys)) == len(keys)   # all pairwise distinct
    # activation is deliberately NOT keyed (epilogue only, not the schedule)
    assert plan_cache_key(dc.replace(base, activation="gelu"), layers) == key0


def test_cache_key_sensitive_to_mesh_shape(make_stack):
    from repro.engine import Mesh
    layers = make_stack()
    eng = Engine(backend="jnp")
    k_none = plan_cache_key(eng, layers)
    k11 = plan_cache_key(eng, layers, mesh=Mesh(1, 1))
    k21 = plan_cache_key(eng, layers, mesh=Mesh(2, 1))
    k12 = plan_cache_key(eng, layers, mesh=Mesh(1, 2))
    k22 = plan_cache_key(eng, layers, mesh=Mesh(2, 2))
    assert len({k_none, k11, k21, k12, k22}) == 5


# --------------------------------------------------------------------------- #
# plan store warm starts
# --------------------------------------------------------------------------- #

def test_plan_store_miss_then_hit_bit_identical(tmp_path, make_stack):
    layers = make_stack(density=0.5)
    store = PlanStore(str(tmp_path))
    cold, hit = store.get_or_compile(
        Engine(backend="jnp", reorder=True, reorder_iters=30), layers)
    assert not hit
    assert cold.annealer_iters == 30

    # fresh engine, fresh process in spirit: rebuild the SAME layers by
    # content and hit the store
    layers2 = make_stack(density=0.5)
    warm, hit = store.get_or_compile(
        Engine(backend="jnp", reorder=True, reorder_iters=30), layers2)
    assert hit
    assert warm.annealer_iters == 0
    np.testing.assert_array_equal(cold.order, warm.order)

    rng = np.random.default_rng(7)
    for B in (1, 3, 8):
        x = rng.standard_normal((B, cold.n_in)).astype(np.float32)
        assert _bitwise_equal(cold(x), warm(x))
    # the stored IOReport is restored verbatim — no re-simulation drift
    assert warm.io == cold.io


def test_plan_store_layered_plans_roundtrip(tmp_path, make_stack):
    """fuse=False plans (no flat schedule) persist and restore too."""
    layers = make_stack()
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp", fuse=False, reorder=True, reorder_iters=10)
    cold, hit = store.get_or_compile(eng, layers)
    assert not hit and not cold.fused
    warm, hit = store.get_or_compile(
        Engine(backend="jnp", fuse=False, reorder=True, reorder_iters=10),
        make_stack())
    assert hit and not warm.fused and warm.annealer_iters == 0
    x = np.random.default_rng(8).standard_normal(
        (4, cold.n_in)).astype(np.float32)
    assert _bitwise_equal(cold(x), warm(x))


def test_plan_store_misses_on_different_content(tmp_path, make_stack):
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp")
    store.get_or_compile(eng, make_stack(seed=0))
    assert store.load(eng, make_stack(seed=1)) is None
    assert len(store.keys()) == 1


def test_plan_store_verify_rejects_drifted_artifact(make_stack):
    """A stored artifact whose arrays don't match the rebuild is a miss."""
    plan = Engine(backend="jnp").compile(make_stack())
    arrays = plan.artifact_arrays()
    assert PlanStore._matches(plan, arrays)
    arrays["flat_rows"] = arrays["flat_rows"].copy()
    arrays["flat_rows"][0] += 1
    assert not PlanStore._matches(plan, arrays)


def test_plan_store_corrupt_entry_self_heals(tmp_path, make_stack):
    """A damaged entry (crc mismatch) is a miss, not a crash: the store
    recompiles and overwrites it."""
    import os
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp")
    store.get_or_compile(eng, make_stack())
    (key,) = store.keys()
    victim = os.path.join(store.path_for(key), "order.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    assert store.load(eng, make_stack()) is None
    plan, hit = store.get_or_compile(Engine(backend="jnp"), make_stack())
    assert not hit and plan is not None
    assert store.load(Engine(backend="jnp"), make_stack()) is not None


@pytest.mark.parametrize("damage", ["truncate", "garbage", "missing_field"])
def test_plan_store_corrupt_manifest_self_heals(tmp_path, make_stack, damage):
    """The manifest file itself being mangled (not just an array crc) is a
    miss that recompiles and overwrites — the self-heal path, directly."""
    import json
    import os
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp")
    store.get_or_compile(eng, make_stack())
    (key,) = store.keys()
    manifest = os.path.join(store.path_for(key), "manifest.json")
    if damage == "truncate":
        raw = open(manifest).read()
        open(manifest, "w").write(raw[: len(raw) // 2])
    elif damage == "garbage":
        open(manifest, "w").write("not json at all {{{")
    else:
        d = json.load(open(manifest))
        d.pop("extra", None)
        d.pop("arrays", None)
        json.dump(d, open(manifest, "w"))
    assert store.load(eng, make_stack()) is None       # miss, no crash
    plan, hit = store.get_or_compile(Engine(backend="jnp"), make_stack())
    assert not hit and plan is not None
    warm = store.load(Engine(backend="jnp"), make_stack())
    assert warm is not None                            # healed


# --------------------------------------------------------------------------- #
# sharded plans through the store
# --------------------------------------------------------------------------- #

def test_plan_store_sharded_roundtrip_bit_identical(tmp_path, make_stack):
    from repro.engine import Mesh
    layers = make_stack(density=0.5)
    store = PlanStore(str(tmp_path))
    mesh = Mesh(model=2, data=1)
    eng = Engine(backend="jnp", reorder=True, reorder_iters=20)
    cold, hit = store.get_or_compile(eng, layers, mesh=mesh)
    assert not hit and cold.annealer_iters == 2 * 20
    warm, hit = store.get_or_compile(
        Engine(backend="jnp", reorder=True, reorder_iters=20),
        make_stack(density=0.5), mesh=Mesh(model=2, data=1))
    assert hit and warm.annealer_iters == 0
    for c, w in zip(cold.shards, warm.shards):
        np.testing.assert_array_equal(c.order, w.order)
        assert w.io == c.io            # stored reports restored verbatim
    rng = np.random.default_rng(11)
    for B in (1, 3, 8):
        x = rng.standard_normal((B, cold.n_in)).astype(np.float32)
        assert _bitwise_equal(cold(x), warm(x))


def test_plan_store_sharded_misses_other_mesh(tmp_path, make_stack):
    from repro.engine import Mesh
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp")
    store.get_or_compile(eng, make_stack(), mesh=Mesh(model=2))
    # a different partition, the unsharded plan, and a different data axis
    # are all misses — per-shard orders are meaningless across topologies
    assert store.load(eng, make_stack(), mesh=Mesh(model=4)) is None
    assert store.load(eng, make_stack()) is None
    assert store.load(eng, make_stack(), mesh=Mesh(model=2, data=2)) is None
    assert store.load(eng, make_stack(), mesh=Mesh(model=2)) is not None


def test_plan_store_sharded_verify_rejects_drift(make_stack):
    from repro.engine import Mesh
    plan = Engine(backend="jnp").compile(make_stack(), mesh=Mesh(model=2))
    arrays = plan.artifact_arrays()
    assert PlanStore._matches_sharded(plan, arrays)
    bad = dict(arrays)
    bad["s1_flat_rows"] = bad["s1_flat_rows"].copy()
    bad["s1_flat_rows"][0] += 1
    assert not PlanStore._matches_sharded(plan, bad)
    # partition-assignment drift is a miss too
    bad2 = dict(arrays)
    bad2["assign_l0"] = 1 - bad2["assign_l0"]
    assert not PlanStore._matches_sharded(plan, bad2)


def test_plan_store_evict(tmp_path, make_stack):
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp")
    store.get_or_compile(eng, make_stack())
    assert store.evict(Engine(backend="jnp"), make_stack())
    assert store.load(eng, make_stack()) is None
    assert not store.evict(eng, make_stack())


def test_legacy_checkpoint_manifest_still_loads(tmp_path):
    """Checkpoints written by the pre-manifest-layer format (top-level
    'leaves' records) remain readable after the refactor."""
    import json
    import os
    import zlib
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    d = tmp_path / "step_00000003"
    os.makedirs(d)
    arr = tree["w"]
    np.save(d / "leaf_00000.npy", arr)
    legacy = {"step": 3, "n_leaves": 1, "extra": {},
              "leaves": [{"path": "['w']", "file": "leaf_00000.npy",
                          "shape": list(arr.shape), "dtype": str(arr.dtype),
                          "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF}]}
    (d / "manifest.json").write_text(json.dumps(legacy))
    out = load_checkpoint(str(tmp_path), tree, step=3)
    np.testing.assert_array_equal(np.asarray(out["w"]), arr)


def test_bucketed_compile_through_store(tmp_path, make_stack):
    from repro.serving import BucketedPlanSet
    store = PlanStore(str(tmp_path))
    cold = BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp"), max_batch=4,
        plan_store=store)
    assert not cold.cache_hit
    warm = BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp"), max_batch=4,
        plan_store=store)
    assert warm.cache_hit and warm.base.annealer_iters == 0
    x = np.random.default_rng(9).standard_normal(
        (3, cold.n_in)).astype(np.float32)
    np.testing.assert_array_equal(cold(x), warm(x))


# --------------------------------------------------------------------------- #
# plan introspection satellites
# --------------------------------------------------------------------------- #

def test_describe_surfaces_calls_and_compile_stats(make_stack):
    plan = Engine(backend="jnp", reorder=True, reorder_iters=5) \
        .compile(make_stack())
    plan(np.zeros((2, plan.n_in), np.float32))
    s = plan.describe()
    assert "5 annealer iters" in s
    assert "1 calls" in s
    assert "compiled in" in s


def test_optimality_ratio_empty_dag_guard():
    from repro.core.bounds import Bounds
    from repro.core.iosim import IOStats
    empty = IOReport(simulated=IOStats(0, 0),
                     bounds=Bounds(0, 0, 0, 0), M_tiles=3, policy="min")
    assert empty.optimality_ratio == 1.0


def test_io_report_dict_roundtrip(make_stack):
    plan = Engine(backend="jnp").compile(make_stack())
    assert IOReport.from_dict(plan.io.to_dict()) == plan.io
