"""HLO text analysis: shape parsing, trip-count-aware collective accounting."""

import numpy as np

from repro.launch.hlo_analysis import (
    analyze_module,
    model_flops_for,
    shape_bytes,
)
from repro.models.config import LM_SHAPES
from repro.configs import get_config


def test_shape_bytes():
    assert shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("(f32[4], s32[2,2])") == 16 + 16
    assert shape_bytes("s32[]") == 4


SYNTH = """
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %ar = f32[8,8] all-reduce(%x), to_apply=%add_comp
  %d = f32[8,8] dot(%ar, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body
  %ag = f32[16,8] all-gather(%a), dimensions={0}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_trip_count_aware_collectives_and_flops():
    mc = analyze_module(SYNTH)
    # while trips 7x: all-reduce 7 * 256B; top-level all-gather operand 256B
    assert mc.coll_by_op["all-reduce"] == 7 * 8 * 8 * 4
    assert mc.coll_by_op["all-gather"] == 8 * 8 * 4
    # dot: 2 * 8*8 (result) * 8 (contraction) = 1024 flops, 7 trips
    assert mc.flops == 7 * 2 * 8 * 8 * 8
    assert 7 in mc.trip_counts


def test_model_flops_kinds():
    cfg = get_config("codeqwen1.5-7b")
    n = cfg.n_params()
    t = LM_SHAPES["train_4k"]
    assert model_flops_for(cfg, t) == 6.0 * n * t.global_batch * t.seq_len
    d = LM_SHAPES["decode_32k"]
    assert model_flops_for(cfg, d) == 2.0 * n * d.global_batch
    moe = get_config("deepseek-moe-16b")
    assert moe.n_active_params() < moe.n_params()
