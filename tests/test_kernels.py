"""Pallas kernel tests: interpret-mode allclose vs pure-jnp oracles,
sweeping shapes / dtypes / densities, plus the schedule contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocksparse import to_bsr
from repro.kernels.moe_ffn import moe_ffn
from repro.kernels.ops import bsr_layer_ref, compile_schedule, scheduled_bsr_layer

CASES = [
    # (n_in, n_out, bm, bn, density, dtype, batch)
    (256, 384, 128, 128, 0.5, jnp.float32, 16),
    (256, 256, 64, 128, 0.3, jnp.bfloat16, 8),
    (512, 256, 128, 64, 0.15, jnp.float32, 32),
    (384, 512, 64, 64, 0.10, jnp.bfloat16, 8),
    (128, 128, 128, 128, 1.0, jnp.float32, 8),
    (512, 512, 64, 64, 0.05, jnp.float32, 8),
    (256, 640, 128, 128, 0.25, jnp.bfloat16, 16),
    (640, 256, 128, 128, 0.4, jnp.float32, 8),
]


@pytest.mark.parametrize("n_in,n_out,bm,bn,density,dtype,batch", CASES)
def test_bsr_matmul_matches_ref(n_in, n_out, bm, bn, density, dtype, batch):
    rng = np.random.default_rng(hash((n_in, n_out, bm, bn)) % 2**31)
    w = rng.standard_normal((n_in, n_out)).astype(np.float32) * 0.1
    b = rng.standard_normal(n_out).astype(np.float32) * 0.1
    lay = to_bsr(w, bm, bn, density=density, bias=b)
    perm = np.lexsort((lay.rows, lay.cols))  # theorem-1 grouped order
    sch = compile_schedule(lay, perm)
    x = jnp.asarray(rng.standard_normal((batch, n_in)), dtype=dtype)
    y = scheduled_bsr_layer(x, lay, sch, activation=jax.nn.relu, interpret=True)
    yr = bsr_layer_ref(x, lay, activation=jax.nn.relu)
    a, r = y.astype(jnp.float32), yr.astype(jnp.float32)
    err = float(jnp.max(jnp.abs(a - r) / (1.0 + jnp.abs(r))))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert err < tol, err
    assert y.dtype == x.dtype


def test_bsr_matmul_no_activation_and_bias():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((256, 256)).astype(np.float32)
    bias = rng.standard_normal(256).astype(np.float32)
    lay = to_bsr(w, 64, 64, density=0.4, bias=bias)
    sch = compile_schedule(lay, np.lexsort((lay.rows, lay.cols)))
    x = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    y = scheduled_bsr_layer(x, lay, sch, activation=None, interpret=True)
    yr = bsr_layer_ref(x, lay, activation=None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)


def test_schedule_rejects_non_contiguous():
    rng = np.random.default_rng(1)
    w = rng.standard_normal((256, 256)).astype(np.float32)
    lay = to_bsr(w, 64, 64, density=0.8)
    # row-major order interleaves output tiles -> must be rejected
    perm = np.lexsort((lay.cols, lay.rows))
    cols = lay.cols[perm]
    if len(set(cols.tolist())) > 1 and not all(
            cols[i] <= cols[i + 1] for i in range(len(cols) - 1)):
        with pytest.raises(ValueError, match="contiguous"):
            compile_schedule(lay, perm)


def test_empty_output_tiles_get_bias():
    """Output tiles with no nonzero block must still produce act(bias)."""
    w = np.zeros((128, 256), np.float32)
    w[:64, :64] = 1.0  # only the first output tile has mass
    bias = np.arange(256, dtype=np.float32) * 0.01
    lay = to_bsr(w, 64, 64, density=None, bias=bias)
    assert lay.grid_out == 4 and lay.nnz_blocks < 4
    sch = compile_schedule(lay, np.lexsort((lay.rows, lay.cols)))
    x = jnp.ones((4, 128), jnp.float32)
    y = scheduled_bsr_layer(x, lay, sch, activation=jax.nn.relu, interpret=True)
    yr = bsr_layer_ref(x, lay, activation=jax.nn.relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)


MOE_CASES = [
    (4, 16, 64, 256, 64, jnp.float32),
    (2, 32, 128, 512, 128, jnp.float32),
    (8, 8, 64, 128, 64, jnp.bfloat16),
    (3, 16, 96, 384, 128, jnp.float32),
]


@pytest.mark.parametrize("E,C,d,f,f_tile,dtype", MOE_CASES)
def test_moe_ffn_matches_ref(E, C, d, f, f_tile, dtype):
    rng = np.random.default_rng(E * 100 + C)
    x = jnp.asarray(rng.standard_normal((E, C, d)), dtype)
    wu = jnp.asarray(rng.standard_normal((E, d, f)) * 0.05, dtype)
    wd = jnp.asarray(rng.standard_normal((E, f, d)) * 0.05, dtype)
    y = moe_ffn(x, wu, wd, activation=jax.nn.gelu, f_tile=f_tile,
                interpret=True)
    h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                               wu.astype(jnp.float32)))
    yr = jnp.einsum("ecf,efd->ecd", h, wd.astype(jnp.float32))
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - yr) / (1 + jnp.abs(yr))))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert err < tol, err


def test_moe_ffn_f_tile_invariance():
    """Result must not depend on the VMEM tiling choice."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 16, 64)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((2, 64, 256)) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((2, 256, 64)) * 0.05, jnp.float32)
    y1 = moe_ffn(x, wu, wd, f_tile=64, interpret=True)
    y2 = moe_ffn(x, wu, wd, f_tile=256, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-6)
