"""End-to-end sparse pipeline: prune -> schedule -> (CR) -> kernel execution."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import simulate, theorem1_bounds, to_block_ffnn, to_bsr
from repro.core.blocksparse import is_contiguous_by_output, schedule_arrays
from repro.kernels.ops import bsr_layer_ref
from repro.sparse import ScheduledSparseFFNN, prune_dense_stack
from repro.core.blocksparse import regroup_by_output as _regroup_by_output


def _stack(seed=0, sizes=(256, 512, 256, 128), density=0.3, bs=64):
    rng = np.random.default_rng(seed)
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.05
          for i in range(len(sizes) - 1)]
    bss = [rng.standard_normal(sizes[i + 1]).astype(np.float32) * 0.1
           for i in range(len(sizes) - 1)]
    return prune_dense_stack(ws, bss, density=density, block_m=bs, block_n=bs)


def _oracle(layers, x):
    h = x
    for k, lay in enumerate(layers):
        act = jax.nn.relu if k < len(layers) - 1 else None
        h = bsr_layer_ref(h, lay, activation=act)
    return h


def test_scheduled_ffnn_matches_oracle():
    layers = _stack()
    net = ScheduledSparseFFNN.build(layers, activation=jax.nn.relu)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 256)),
                    jnp.float32)
    y, yr = net(x), _oracle(layers, x)
    err = float(jnp.max(jnp.abs(y - yr) / (1 + jnp.abs(yr))))
    assert err < 1e-4


def test_reordered_ffnn_matches_oracle_and_reduces_tile_ios():
    layers = _stack(density=0.35)
    base = ScheduledSparseFFNN.build(layers, activation=jax.nn.relu)
    opt = ScheduledSparseFFNN.build(layers, activation=jax.nn.relu,
                                    reorder=True, reorder_iters=400, seed=0)
    assert opt.block_ffnn.net.is_topological_connection_order(opt.order)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((8, 256)),
                    jnp.float32)
    yr = _oracle(layers, x)
    err = float(jnp.max(jnp.abs(opt(x) - yr) / (1 + jnp.abs(yr))))
    assert err < 1e-4
    assert opt.simulated_ios().total <= base.simulated_ios().total


def test_block_dag_obeys_theorem1_bounds():
    from repro.core.graph import drop_isolated

    layers = _stack(density=0.25)
    bf = to_block_ffnn(layers)
    net = drop_isolated(bf.net)  # Thm 1 assumes a connected FFNN
    b = theorem1_bounds(net)
    s = simulate(net, net.theorem1_order(), M=6, policy="min")
    assert b.reads_lo <= s.reads <= b.reads_hi
    assert b.writes_lo <= s.writes <= b.writes_hi


def test_schedule_arrays_first_last_flags():
    layers = _stack(density=0.4, sizes=(128, 256, 128), bs=64)
    bf = to_block_ffnn(layers)
    order = bf.net.theorem1_order()
    for layer in range(len(layers)):
        perm, rows, cols, first, last = schedule_arrays(bf, order, layer)
        assert is_contiguous_by_output(cols)
        # each output tile: exactly one first and one last
        for c in set(cols.tolist()):
            idx = np.flatnonzero(cols == c)
            assert first[idx[0]] == 1 and last[idx[-1]] == 1
            assert first[idx[1:]].sum() == 0 and last[idx[:-1]].sum() == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), m=st.integers(3, 30), t=st.integers(10, 120))
def test_regroup_by_output_preserves_topology(seed, m, t):
    from repro.core import connection_reordering, random_ffnn

    net = random_ffnn(width=12, depth=3, density=0.4, seed=seed)
    res = connection_reordering(net, net.theorem1_order(), M=m, T=t, seed=seed)
    regrouped = _regroup_by_output(net, res.order)
    assert net.is_topological_connection_order(regrouped)
    # grouped: every dst's occurrences contiguous
    assert is_contiguous_by_output(net.dst[regrouped])
