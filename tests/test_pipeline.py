"""The staged serving pipeline: dispatch lanes, executor pool, HTTP ingress.

What the pipeline refactor must NOT change (PR-5/7 invariants, now with
``executor_workers > 1``):

  * **same-bucket FIFO** — a dispatch lane admits one in-flight batch at a
    time, so batches of one bucket execute serially, in formation order,
    and request order within a bucket is submission order;
  * **different-bucket overlap** — that is the point of the pipeline: a
    held bucket-8 batch must not block a bucket-1 batch from being formed,
    dispatched, and served by another worker;
  * **output transparency** — every result is bit-identical to the base
    plan on that row alone, regardless of worker count, lane routing, or
    batch composition; a hot-swap under overlapped traffic is atomic
    (identical-weight swap: bit-identical throughout; new-weight swap:
    every row matches exactly one weight set);
  * **resilience composition** — K batch failures spread across workers
    still trip the circuit breaker exactly once; a crashed scheduler is
    restarted by the watchdog with zero requests lost;
  * **backpressure, not loss** — when lanes and queue are full, admission
    rejects (HTTP 429 at the front door); everything admitted is served.

Deterministic lane mechanics are unit-tested on :class:`DispatchQueues`
directly; overlap/ordering tests instrument a real compiled plan set with
recording + holds (events), so assertions are on synchronized state, not
sleeps.
"""

import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.engine import Engine
from repro.serving import (
    BucketedPlanSet,
    CircuitBreaker,
    DispatchQueues,
    FaultInjector,
    FormedBatch,
    HttpFrontDoor,
    ModelRouter,
    SparseServer,
)


@pytest.fixture
def plans(make_stack):
    return BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp"), max_batch=8).warmup()


def _xs(plans, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(plans.n_in).astype(np.float32)
            for _ in range(n)]


def _expected_rows(plans, xs):
    """Ground truth per request: the base plan on each row alone."""
    return [np.asarray(plans.base(x[None]))[0] for x in xs]


class InstrumentedPlans:
    """Wraps a compiled plan set: records every batch call ``(bucket,
    t_start, t_end, thread, rows)`` and optionally HOLDS calls of chosen
    buckets open until the test releases them.  Everything else delegates,
    so the server sees a normal ``BucketedPlanSet``."""

    def __init__(self, base, hold_buckets=()):
        self._base = base
        self.calls = []
        self._mu = threading.Lock()
        self.entered = {b: threading.Event() for b in hold_buckets}
        self.release = {b: threading.Event() for b in hold_buckets}

    def __call__(self, x):
        bucket = self._base.bucket_for(x.shape[0])
        t0 = time.monotonic()
        if bucket in self.entered:
            self.entered[bucket].set()
            assert self.release[bucket].wait(timeout=30.0), \
                f"bucket-{bucket} hold never released"
        y = self._base(x)
        with self._mu:
            self.calls.append({"bucket": bucket, "t0": t0,
                               "t1": time.monotonic(),
                               "thread": threading.current_thread().name,
                               "rows": np.array(x, copy=True)})
        return y

    def __getattr__(self, name):
        return getattr(self._base, name)


# --------------------------------------------------------------------------- #
# DispatchQueues: deterministic lane mechanics
# --------------------------------------------------------------------------- #

def _fb(bucket, t_formed, server=None):
    return FormedBatch(reqs=[], plans=None, bucket=bucket,
                       t_formed=t_formed, server=server)


def test_dispatch_lane_is_serial_and_fifo():
    d = DispatchQueues(per_lane=2)
    a, b = _fb(8, 1.0), _fb(8, 2.0)
    assert d.put(a) and d.put(b)
    first = d.take(timeout=0.1)
    assert first is a                          # oldest first
    # one in-flight per lane: b is queued but NOT ready until a completes
    assert d.take(timeout=0.05) is None
    d.complete(a)
    assert d.take(timeout=0.1) is b


def test_dispatch_take_prefers_oldest_across_lanes():
    d = DispatchQueues(per_lane=2)
    late, early = _fb(8, 5.0), _fb(1, 3.0)
    assert d.put(late) and d.put(early)
    assert d.take(timeout=0.1) is early        # global age order
    assert d.take(timeout=0.1) is late         # different lane: also ready


def test_dispatch_lane_capacity_is_backpressure():
    d = DispatchQueues(per_lane=1)
    a, b, c = _fb(4, 1.0), _fb(4, 2.0), _fb(4, 3.0)
    assert d.put(a)
    assert d.take(timeout=0.1) is a            # in flight
    assert d.put(b)                            # fills the lane buffer
    assert not d.can_accept(b.lane)
    assert not d.put(c)                        # full lane: rejected
    d.complete(a)
    assert d.take(timeout=0.1) is b


def test_dispatch_close_is_sticky_and_drains():
    d = DispatchQueues(per_lane=2)
    a, b = _fb(2, 1.0), _fb(4, 2.0)
    d.put(a), d.put(b)
    got = d.drain_batches()
    assert [g.t_formed for g in got] == [1.0, 2.0]
    d.close()
    assert not d.put(_fb(1, 3.0))              # closed: no new batches
    assert d.take(timeout=0.05) is None


def test_dispatch_pending_and_wait_idle_scoped_by_server():
    d = DispatchQueues(per_lane=2)
    s1, s2 = object(), object()
    a, b = _fb(2, 1.0, server=s1), _fb(2, 2.0, server=s2)
    d.put(a), d.put(b)
    assert d.pending(server=s1) == 1 and d.pending() == 2
    taken = d.take(timeout=0.1)
    assert taken is a
    assert not d.wait_idle(server=s1, timeout=0.05)   # a still in flight
    assert d.pending(server=s1) == 1
    d.complete(a)
    assert d.wait_idle(server=s1, timeout=0.5)
    assert d.pending(server=s2) == 1


# --------------------------------------------------------------------------- #
# executor pool: ordering + overlap (real threads)
# --------------------------------------------------------------------------- #

@pytest.mark.stress
def test_same_bucket_batches_execute_serially_in_submission_order(plans):
    """All traffic lands in one bucket: its lane must serialize execution
    (no two calls overlap) and preserve submission order across batches —
    even with 4 workers racing on the lane."""
    inst = InstrumentedPlans(plans)
    server = SparseServer(inst, slo_ms=50.0, executor_workers=4)
    xs = _xs(plans, 40, seed=3)
    for i, x in enumerate(xs):
        x[0] = float(i)                        # tag row with submit order
    expected = _expected_rows(plans, xs)
    server.start()
    try:
        rids = [server.submit(x) for x in xs]
        outs = [server.wait(r, timeout=20.0) for r in rids]
    finally:
        server.shutdown(drain=True)
    for got, want in zip(outs, expected):
        np.testing.assert_array_equal(got, want)
    calls = sorted(inst.calls, key=lambda c: c["t0"])
    per_bucket = {}
    for c in calls:
        per_bucket.setdefault(c["bucket"], []).append(c)
    for bucket, bcalls in per_bucket.items():
        for prev, nxt in zip(bcalls, bcalls[1:]):
            assert prev["t1"] <= nxt["t0"], \
                f"two bucket-{bucket} batches overlapped in time"
        tags = [float(row[0]) for c in bcalls for row in c["rows"]]
        assert tags == sorted(tags), \
            f"bucket-{bucket} rows out of submission order: {tags}"


@pytest.mark.stress
def test_different_bucket_batches_overlap(plans):
    """A held bucket-8 batch must not block a bucket-1 request: the small
    batch is formed onto its own lane and served by another worker WHILE
    the big one is still executing."""
    inst = InstrumentedPlans(plans, hold_buckets=(8,))
    server = SparseServer(inst, slo_ms=100.0, executor_workers=2)
    xs_big = _xs(plans, 8, seed=4)
    (x_small,) = _xs(plans, 1, seed=5)
    server.start()
    try:
        big_rids = [server.submit(x) for x in xs_big]
        assert inst.entered[8].wait(timeout=10.0)      # worker 1 is inside
        r_small = server.submit(x_small)
        got_small = server.wait(r_small, timeout=10.0)  # overlaps the hold
        assert got_small is not None
        np.testing.assert_array_equal(
            got_small, _expected_rows(plans, [x_small])[0])
        assert not inst.release[8].is_set()    # big batch was still held
        inst.release[8].set()
        for rid, want in zip(big_rids, _expected_rows(plans, xs_big)):
            np.testing.assert_array_equal(server.wait(rid, timeout=10.0),
                                          want)
    finally:
        inst.release[8].set()
        server.shutdown(drain=True)
    snap = server.metrics.snapshot()
    assert snap["served"] == 9
    assert snap["dispatch_wait_ms"]["count"] >= 2
    assert snap["form_wait_ms"]["count"] == 9


@pytest.mark.stress
def test_pool_snapshot_reports_workers_and_dispatch(plans):
    server = SparseServer(plans, slo_ms=50.0, executor_workers=3)
    server.start()
    try:
        rids = [server.submit(x) for x in _xs(plans, 20, seed=6)]
        for r in rids:
            assert server.wait(r, timeout=20.0) is not None
        snap = server.snapshot()
    finally:
        server.shutdown(drain=True)
    pool = snap["pool"]
    assert pool["workers"] == 3
    assert set(pool["per_worker"]) == {"0", "1", "2"}
    assert sum(w["batches"] for w in pool["per_worker"].values()) \
        == snap["batches"]
    # the per-worker map renders as worker= labelled Prometheus samples
    from repro.obs.prom import render_prometheus
    text = render_prometheus(snap)
    assert 'worker="0"' in text and "_pool_worker_" in text


# --------------------------------------------------------------------------- #
# resilience with workers > 1
# --------------------------------------------------------------------------- #

@pytest.mark.stress
def test_breaker_trips_once_for_failures_across_workers(make_stack):
    """K batch failures spread across concurrent workers feed ONE breaker:
    it trips exactly once, degrades to the safe twin, and subsequent
    traffic is served (bit-identical to the safe twin's forward)."""
    plans = BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp"), max_batch=8,
        safe_twin=True).warmup()

    class FailingPlans:
        def __init__(self, base):
            self._base = base

        def __call__(self, x):
            raise RuntimeError("injected fast-plan failure")

        def __getattr__(self, name):
            return getattr(self._base, name)

    server = SparseServer(FailingPlans(plans), slo_ms=50.0,
                          executor_workers=3,
                          breaker=CircuitBreaker(threshold=3,
                                                 cooldown_s=60.0))
    server.start()
    try:
        # waves of 11 rows: formation spreads each wave over several lanes
        # (8 + spills), so concurrent workers fail in parallel; keep
        # feeding until the shared failure count crosses the threshold
        deadline = time.monotonic() + 15.0
        while server.metrics.breaker_trips < 1:
            assert time.monotonic() < deadline, "breaker never tripped"
            doomed = [server.submit(x) for x in _xs(plans, 11, seed=7)]
            for rid in doomed:
                server.wait(rid, timeout=20.0)  # fail -> None results
        xs = _xs(plans, 6, seed=8)
        rids = [server.submit(x) for x in xs]
        expected = _expected_rows(plans, xs)
        for rid, want in zip(rids, expected):
            got = server.wait(rid, timeout=20.0)
            assert got is not None             # degraded path serves
            np.testing.assert_array_equal(got, want)
    finally:
        server.shutdown(drain=True)
    m = server.metrics.snapshot()
    assert m["breaker_trips"] == 1             # concurrent failures: 1 trip
    assert m["batch_failures"] >= 3
    assert m["degraded_batches"] >= 1


@pytest.mark.stress
@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_watchdog_restart_with_pipeline_zero_requests_lost(plans):
    """The formation (scheduler) thread crashes while a worker pool is
    attached; the watchdog respawns it and every request is served."""
    inj = FaultInjector()
    server = SparseServer(plans, slo_ms=20.0, watchdog_s=0.2,
                          fault_injector=inj, executor_workers=2)
    inj.inject("server.scheduler", error=RuntimeError("scheduler crash"),
               times=1)
    server.start()                             # dies on its first iteration
    xs = _xs(plans, 12, seed=9)
    expected = _expected_rows(plans, xs)
    rids = [server.submit(x) for x in xs]
    assert all(r is not None for r in rids)
    try:
        for rid, want in zip(rids, expected):
            got = server.wait(rid, timeout=10.0)
            assert got is not None             # zero requests lost
            np.testing.assert_array_equal(got, want)
        assert server.metrics.watchdog_restarts >= 1
    finally:
        server.shutdown(drain=True)


# --------------------------------------------------------------------------- #
# hot swap under overlapped execution
# --------------------------------------------------------------------------- #

@pytest.mark.stress
def test_swap_identical_weights_bit_identical_under_overlap(plans,
                                                            make_stack):
    """swap() of identical weights under concurrent multi-worker traffic:
    every result, before/during/after the swap, is bit-identical."""
    engine = Engine(backend="jnp")
    server = SparseServer(plans, slo_ms=50.0, engine=engine,
                          executor_workers=3)
    xs = _xs(plans, 16, seed=10)
    expected = _expected_rows(plans, xs)
    server.start()
    stop = threading.Event()
    results = []
    mu = threading.Lock()

    def client(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            i = int(rng.integers(len(xs)))
            rid = server.submit(xs[i])
            if rid is None:
                continue
            y = server.wait(rid, timeout=20.0)
            with mu:
                results.append((i, y))

    threads = [threading.Thread(target=client, args=(50 + k,))
               for k in range(3)]
    for t in threads:
        t.start()
    try:
        time.sleep(0.1)
        server.swap(make_stack())              # same seed: same weights
        time.sleep(0.1)
    finally:
        stop.set()
        for t in threads:
            t.join()
        server.shutdown(drain=True)
    assert len(results) > 10
    for i, y in results:
        assert y is not None
        np.testing.assert_array_equal(y, expected[i])
    assert server.metrics.swaps == 1


@pytest.mark.stress
def test_swap_new_weights_never_mixes_under_overlap(plans, make_stack):
    """A new-weight swap under multi-worker traffic: every row matches
    exactly one of the two weight sets — never a mixture (the batch's plan
    snapshot is immutable; the install happens between batches)."""
    engine = Engine(backend="jnp")
    new_plans = BucketedPlanSet.compile(make_stack(seed=99), engine=engine,
                                        max_batch=8).warmup()
    server = SparseServer(plans, slo_ms=50.0, engine=engine,
                          executor_workers=3)
    xs = _xs(plans, 8, seed=11)
    want_old = _expected_rows(plans, xs)
    want_new = _expected_rows(new_plans, xs)
    for old, new in zip(want_old, want_new):
        assert not np.array_equal(old, new)    # the swap must be visible
    server.start()
    stop = threading.Event()
    results = []
    mu = threading.Lock()

    def client(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            i = int(rng.integers(len(xs)))
            rid = server.submit(xs[i])
            if rid is None:
                continue
            y = server.wait(rid, timeout=20.0)
            with mu:
                results.append((i, y))

    threads = [threading.Thread(target=client, args=(70 + k,))
               for k in range(3)]
    for t in threads:
        t.start()
    try:
        time.sleep(0.1)
        server.swap(plans=new_plans)
        time.sleep(0.1)
    finally:
        stop.set()
        for t in threads:
            t.join()
        server.shutdown(drain=True)
    n_new = 0
    for i, y in results:
        assert y is not None
        is_old = np.array_equal(y, want_old[i])
        is_new = np.array_equal(y, want_new[i])
        assert is_old != is_new, "row matches neither/both weight sets"
        n_new += is_new
    assert n_new > 0                           # the swap took effect


def test_swap_async_builds_in_background_and_installs(plans, make_stack):
    """``swap_async=True`` returns a handle immediately; serving continues
    during the build; ``wait()`` returns the superseded plan set; the new
    weights take effect afterwards."""
    engine = Engine(backend="jnp")
    server = SparseServer(plans, slo_ms=50.0, engine=engine,
                          executor_workers=2)
    server.start()
    try:
        handle = server.swap(make_stack(seed=99), swap_async=True)
        # serving is NOT blocked by the background compile
        (x,) = _xs(plans, 1, seed=12)
        rid = server.submit(x)
        assert server.wait(rid, timeout=20.0) is not None
        old = handle.wait(timeout=60.0)
        assert handle.done
        assert old is plans                    # superseded set handed back
        new_plans = BucketedPlanSet.compile(make_stack(seed=99),
                                            engine=engine, max_batch=8)
        xs = _xs(plans, 3, seed=13)
        rids = [server.submit(v) for v in xs]
        for rid, want in zip(rids, _expected_rows(new_plans, xs)):
            np.testing.assert_array_equal(server.wait(rid, timeout=20.0),
                                          want)
        assert server.metrics.swaps == 1
    finally:
        server.shutdown(drain=True)


# --------------------------------------------------------------------------- #
# router: shared pool, totals vs per-model snapshots
# --------------------------------------------------------------------------- #

@pytest.mark.stress
def test_router_totals_match_per_model_under_concurrent_submitters(
        make_stack):
    """4 submitter threads across 2 models through ONE shared pool: no
    request lost or crossed between models, and the router's totals equal
    the sum of the per-model snapshots."""
    engine = Engine(backend="jnp")
    nets = {"a": make_stack(seed=1), "b": make_stack(seed=2)}
    router = ModelRouter.compile(nets, engine=engine, max_batch=8,
                                 executor_workers=2, slo_ms=50.0,
                                 max_queue=4096)
    refs = {name: router.servers[name].plans for name in nets}
    xs = _xs(refs["a"], 10, seed=14)
    expected = {name: _expected_rows(refs[name], xs) for name in nets}
    router.start()
    per_thread = 25
    outcomes = []
    mu = threading.Lock()
    gate = threading.Barrier(4)

    def submitter(k):
        rng = np.random.default_rng(90 + k)
        gate.wait()
        for _ in range(per_thread):
            name = "a" if rng.integers(2) else "b"
            i = int(rng.integers(len(xs)))
            rid = router.submit(name, xs[i])
            assert rid is not None
            y = router.wait(name, rid, timeout=20.0)
            with mu:
                outcomes.append((name, i, y))

    threads = [threading.Thread(target=submitter, args=(k,))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    router.shutdown(drain=True)
    assert len(outcomes) == 4 * per_thread
    for name, i, y in outcomes:
        assert y is not None
        np.testing.assert_array_equal(y, expected[name][i])  # never crossed
    snap = router.metrics_snapshot()
    assert snap["total"]["served"] == 4 * per_thread
    assert snap["total"]["served"] == sum(
        m["served"] for m in snap["models"].values())
    assert snap["total"]["failed_requests"] == 0
    full = None
    try:
        full = router.snapshot()
    finally:
        pass
    assert full["total"]["served"] == 4 * per_thread


# --------------------------------------------------------------------------- #
# HTTP front door
# --------------------------------------------------------------------------- #

def _post(url, body, timeout=10.0):
    req = urllib.request.Request(
        url + "/v1/infer", data=json.dumps(body).encode("utf-8"),
        headers={"Content-Type": "application/json"}, method="POST")
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read()), dict(resp.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}"), dict(e.headers)


@pytest.mark.stress
def test_http_front_door_roundtrip_and_status_mapping(plans):
    server = SparseServer(plans, slo_ms=50.0, executor_workers=2)
    server.start()
    front = HttpFrontDoor(server, port=0).start()
    try:
        (x,) = _xs(plans, 1, seed=15)
        want = _expected_rows(plans, [x])[0]
        code, payload, _ = _post(front.url, {"x": x.tolist()})
        assert code == 200
        np.testing.assert_array_equal(
            np.asarray(payload["y"], np.float32), want)

        # async submit + poll
        code, payload, _ = _post(front.url, {"x": x.tolist(),
                                             "wait": False})
        assert code == 202
        rid = payload["rid"]
        deadline = time.monotonic() + 10.0
        while True:
            try:
                with urllib.request.urlopen(
                        front.url + f"/v1/result/{rid}", timeout=5) as r:
                    code, payload = r.status, json.loads(r.read())
            except urllib.error.HTTPError as e:
                code, payload = e.code, json.loads(e.read() or b"{}")
            if code == 200:
                break
            assert code == 202 and time.monotonic() < deadline
            time.sleep(0.01)
        np.testing.assert_array_equal(
            np.asarray(payload["y"], np.float32), want)

        # ingress-side rejections never reach formation
        assert _post(front.url, {"x": "nonsense"})[0] == 400
        assert _post(front.url, {"x": x.tolist(), "model": "ghost"})[0] \
            == 404
        with urllib.request.urlopen(front.url + "/v1/models",
                                    timeout=5) as r:
            assert json.loads(r.read())["models"] == [server.name]
        with urllib.request.urlopen(front.url + "/healthz", timeout=5) as r:
            assert r.status == 200
    finally:
        front.stop()
        server.shutdown(drain=True)


@pytest.mark.stress
def test_http_429_backpressure_when_queue_full(plans):
    """Queue + lanes full => 429 with Retry-After; everything that got a
    202 is eventually served (backpressure sheds load, never loses it)."""
    inst = InstrumentedPlans(plans, hold_buckets=(1, 2, 4, 8))
    server = SparseServer(inst, slo_ms=50.0, max_queue=2,
                          executor_workers=2)
    server.start()
    front = HttpFrontDoor(server, port=0).start()
    try:
        (x,) = _xs(plans, 1, seed=16)
        codes, rids = [], []
        for _ in range(40):                    # wait=false: returns at once
            code, payload, headers = _post(front.url,
                                           {"x": x.tolist(), "wait": False})
            codes.append(code)
            if code == 202:
                rids.append(payload["rid"])
            else:
                assert code == 429
                assert "Retry-After" in headers
        assert codes.count(429) > 0            # admission control engaged
        assert codes.count(202) > 0
        for ev in inst.release.values():       # un-wedge the executors
            ev.set()
        want = _expected_rows(plans, [x])[0]
        for rid in rids:                       # nothing admitted was lost
            got = server.wait(rid, timeout=20.0)
            assert got is not None
            np.testing.assert_array_equal(got, want)
    finally:
        for ev in inst.release.values():
            ev.set()
        front.stop()
        server.shutdown(drain=True)
    assert server.metrics.rejected == codes.count(429)
    assert server.metrics.served == len(rids)


# --------------------------------------------------------------------------- #
# metrics: the formation/dispatch wait split
# --------------------------------------------------------------------------- #

def test_wait_split_sums_to_queue_wait_step_driven(plans):
    """Step-driven mode: dispatch wait is ~0 (execution starts at
    formation), so queue_wait == form_wait and the pre-pipeline series
    stays comparable."""
    from conftest import FakeClock
    clock = FakeClock()
    server = SparseServer(plans, slo_ms=1000.0, clock=clock)
    for x in _xs(plans, 8, seed=17):
        server.submit(x)
    clock.advance(0.05)
    server.poll()
    server.drain()
    snap = server.metrics.snapshot()
    assert snap["served"] == 8
    assert snap["form_wait_ms"]["count"] == 8
    assert snap["dispatch_wait_ms"]["p99"] == 0.0
    assert snap["queue_wait_ms"]["p50"] == pytest.approx(
        snap["form_wait_ms"]["p50"])
    assert snap["form_depth"]["count"] >= 1    # depth recorded at formation
