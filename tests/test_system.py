"""End-to-end behaviour tests for the whole system.

The full paper pipeline (prune -> bound -> order -> anneal -> kernel) plus a
short resilient sharded training run — the two deployment stories the
framework exists for.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.compat import named_shardings, set_mesh
from repro.configs import get_config, reduced
from repro.core import simulate, theorem1_bounds
from repro.core.graph import drop_isolated
from repro.kernels.ops import bsr_layer_ref
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_train_step
from repro.launch import partition
from repro.models import lm
from repro.models.sharding import axes_from_mesh
from repro.optim import OptConfig, adamw_init
from repro.runtime.failure import FaultInjector, ResilientTrainer
from repro.sparse import ScheduledSparseFFNN, prune_dense_stack


def test_paper_pipeline_end_to_end():
    """prune -> 2-optimal schedule -> CR -> Pallas kernel, with the exact
    simulated I/O staying inside the Theorem-1 window throughout."""
    rng = np.random.default_rng(0)
    sizes = [256, 512, 256]
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.05
          for i in range(2)]
    bs = [np.zeros(sizes[i + 1], np.float32) for i in range(2)]
    layers = prune_dense_stack(ws, bs, density=0.3, block_m=64, block_n=64)
    model = ScheduledSparseFFNN.build(layers, reorder=True, reorder_iters=250)

    net = drop_isolated(model.block_ffnn.net)
    b = theorem1_bounds(net)
    ios = simulate(net, net.theorem1_order(), M=3).total
    assert b.total_lo <= ios <= b.total_hi

    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    ref = x
    for i, lay in enumerate(layers):
        ref = bsr_layer_ref(ref, lay,
                            activation=jax.nn.relu if i < 1 else None)
    err = float(jnp.max(jnp.abs(model(x) - ref) / (1 + jnp.abs(ref))))
    assert err < 1e-4


def test_training_system_with_failure_recovery(tmp_path):
    """Sharded train step + checkpointing + fault injection: loss decreases
    across a simulated node failure."""
    cfg = reduced(get_config("codeqwen1.5-7b"))
    mesh = make_test_mesh(1, 1)
    axes_from_mesh(mesh)
    set_mesh(mesh)
    params = lm.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    p_specs = partition.params_specs(mesh, jax.eval_shape(lambda: params))
    opt = adamw_init(params)
    o_specs = partition.opt_specs(mesh, jax.eval_shape(lambda: opt), p_specs)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=2),
                                   mesh, grad_specs=o_specs["master"]),
                   in_shardings=named_shardings(mesh, (p_specs, o_specs, None)),
                   out_shardings=named_shardings(mesh, (p_specs, o_specs, None)))

    def batches(s):
        r = np.random.default_rng(s)
        toks = r.integers(0, cfg.vocab, (4, 33))
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    trainer = ResilientTrainer(
        step, params, opt, CheckpointManager(str(tmp_path)), ckpt_every=4,
        fault_injector=FaultInjector([6]))
    out = trainer.run(batches, 12)
    assert out["restarts"] == 1
    assert out["losses"][-1] < out["losses"][0]
