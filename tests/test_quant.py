"""Quantized weight-stream tests: bf16/fp8 block-scale weights.

Families:

  * quantization unit contract — per-block scales, all-zero blocks dequant
    to exact zero, storage dtypes and byte sizes;
  * parity — quantized forwards approximate the f32 plan within the
    documented tolerance on every CPU backend, quantized jnp == interpret
    bit-exactly (both dequantize the SAME stored narrow blocks), gated ==
    ungated bit-exactly, and the safe twin reuses the quantized stream so
    breaker degradation stays output-identical;
  * byte accounting — ``IOReport``/``DynamicIOReport`` count the streamed
    bytes in the storage dtype (bf16 >= 1.8x, fp8 >= 3.5x smaller than
    f32), while tile counts and Theorem-1 bounds are unchanged;
  * persistence — plan-store warm starts restore byte-identical quantized
    blocks + scales, the cache key separates weight dtypes, and pre-change
    report dicts still load (backward compat);
  * guard — requesting fp8 when ml_dtypes lacks float8_e4m3fn fails at
    compile time with a clear ValueError.
"""

import dataclasses as dc

import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.engine import Engine, Mesh
from repro.engine.plan import DynamicIOReport, IOReport
from repro.kernels.ops import (
    FP8_DTYPE,
    FP8_MAX,
    quantize_blocks,
    resolve_weight_dtype,
    weight_itemsize,
)
from repro.serving import PlanStore, plan_cache_key

CPU_BACKENDS = ("jnp", "interpret")

#: max |quantized - f32| / max|f32 output| tolerated per storage dtype
REL_TOL = {"bf16": 1e-2, "fp8": 1e-1}

needs_fp8 = pytest.mark.skipif(
    FP8_DTYPE is None, reason="ml_dtypes lacks float8_e4m3fn")

QUANT_DTYPES = ("bf16", pytest.param("fp8", marks=needs_fp8))


def _rel_err(y, y_ref):
    y, y_ref = np.asarray(y, np.float32), np.asarray(y_ref, np.float32)
    return float(np.max(np.abs(y - y_ref)) / max(1e-9,
                                                 np.max(np.abs(y_ref))))


def _x(n_in, batch=8, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((batch, n_in)), jnp.float32)


# --------------------------------------------------------------------------- #
# quantization unit contract
# --------------------------------------------------------------------------- #

def test_resolve_weight_dtype_aliases():
    assert resolve_weight_dtype(None) == "f32"
    assert resolve_weight_dtype("float32") == "f32"
    assert resolve_weight_dtype("bfloat16") == "bf16"
    assert resolve_weight_dtype("BF16") == "bf16"
    with pytest.raises(ValueError, match="unknown weight_dtype"):
        resolve_weight_dtype("int4")


def test_quantize_blocks_f32_is_identity():
    blocks = np.random.default_rng(0).standard_normal((3, 8, 8)).astype(
        np.float32)
    q, scales = quantize_blocks(blocks, "f32")
    assert scales is None and q.dtype == np.float32
    np.testing.assert_array_equal(q, blocks)


def test_quantize_blocks_bf16_unit_scales():
    blocks = np.random.default_rng(0).standard_normal((4, 8, 8)).astype(
        np.float32)
    q, scales = quantize_blocks(blocks, "bf16")
    assert q.itemsize == 2 and scales.shape == (4,)
    np.testing.assert_array_equal(scales, np.ones(4, np.float32))
    assert _rel_err(np.asarray(q, np.float32), blocks) < 8e-3


@needs_fp8
def test_quantize_blocks_fp8_per_block_scale():
    rng = np.random.default_rng(0)
    blocks = rng.standard_normal((4, 8, 8)).astype(np.float32)
    blocks[1] *= 1e3          # wildly different block ranges
    blocks[2] *= 1e-3
    blocks[3] = 0.0           # all-zero (patch) block
    q, scales = quantize_blocks(blocks, "fp8")
    assert q.itemsize == 1 and scales.dtype == np.float32
    np.testing.assert_allclose(
        scales[:3], np.max(np.abs(blocks[:3]), axis=(1, 2)) / FP8_MAX)
    assert scales[3] == 1.0   # zero block -> scale 1, dequants to exact 0
    deq = np.asarray(q, np.float32) * scales[:, None, None]
    np.testing.assert_array_equal(deq[3], 0.0)
    # the per-block scale makes the error relative per block, not global
    for k in range(3):
        assert _rel_err(deq[k], blocks[k]) < 7e-2


def test_weight_itemsize():
    assert [weight_itemsize(d) for d in ("f32", "bf16")] == [4, 2]
    if FP8_DTYPE is not None:
        assert weight_itemsize("fp8") == 1


# --------------------------------------------------------------------------- #
# parity: quantized plans vs the f32 plan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", CPU_BACKENDS)
@pytest.mark.parametrize("wdt", QUANT_DTYPES)
def test_quantized_close_to_f32(make_stack, backend, wdt):
    layers = make_stack()
    kw = dict(backend=backend, activation="relu", reorder_iters=20)
    y32 = Engine(**kw).compile(layers)(_x(128))
    plan = Engine(weight_dtype=wdt, **kw).compile(layers)
    assert plan.weight_dtype == wdt
    assert _rel_err(plan(_x(128)), y32) < REL_TOL[wdt]


@pytest.mark.parametrize("wdt", QUANT_DTYPES)
def test_quantized_jnp_interpret_bit_exact(make_stack, wdt):
    """Both backends dequantize the same stored narrow blocks, so they
    agree exactly — quantization error is a property of the stored
    weights, not the backend."""
    layers = make_stack()
    x = _x(128)
    ys = [Engine(backend=b, weight_dtype=wdt, reorder_iters=20)
          .compile(layers)(x) for b in CPU_BACKENDS]
    assert float(jnp.max(jnp.abs(ys[0] - ys[1]))) == 0.0


@pytest.mark.parametrize("wdt", QUANT_DTYPES)
def test_gated_quantized_bit_exact(make_stack, wdt):
    layers = make_stack()
    x = _x(128)
    kw = dict(backend="jnp", weight_dtype=wdt, reorder_iters=20)
    y = Engine(gate=False, **kw).compile(layers)(x)
    yg = Engine(gate=True, **kw).compile(layers)(x)
    assert float(jnp.max(jnp.abs(y - yg))) == 0.0


@pytest.mark.parametrize("wdt", QUANT_DTYPES)
def test_safe_twin_reuses_quantized_stream(make_stack, wdt):
    """Breaker degradation must be output-identical: the twin shares the
    same quantized schedule arrays, not a re-quantization."""
    plan = Engine(backend="jnp", gate=True, weight_dtype=wdt,
                  reorder_iters=20).compile(make_stack())
    twin = plan.safe_twin()
    assert twin.weight_dtype == wdt
    x = _x(128)
    assert float(jnp.max(jnp.abs(plan(x) - twin(x)))) == 0.0


@pytest.mark.parametrize("wdt", QUANT_DTYPES)
def test_sharded_quantized_matches_unsharded(make_stack, wdt):
    layers = make_stack()
    x = _x(128)
    kw = dict(backend="jnp", weight_dtype=wdt, reorder_iters=20)
    y = Engine(**kw).compile(layers)(x)
    splan = Engine(**kw).compile(layers, mesh=Mesh(2, 1))
    assert splan.weight_dtype == wdt
    assert float(jnp.max(jnp.abs(splan(x) - y))) == 0.0
    # shard byte accounting aggregates to the unsharded total
    uplan = Engine(**kw).compile(layers)
    assert splan.io.weight_stream_bytes == uplan.io.weight_stream_bytes


# --------------------------------------------------------------------------- #
# byte accounting
# --------------------------------------------------------------------------- #

def test_io_report_bytes_shrink_with_dtype(make_stack):
    layers = make_stack()
    plans = {w: Engine(backend="jnp", weight_dtype=w, reorder_iters=20)
             .compile(layers)
             for w in (("f32", "bf16", "fp8") if FP8_DTYPE is not None
                       else ("f32", "bf16"))}
    f32 = plans["f32"].io
    assert f32.weight_dtype == "f32" and f32.scale_bytes_streamed == 0
    assert f32.weight_bytes_streamed > 0
    for w, plan in plans.items():
        io = plan.io
        # the schedule (and so tile counts + bounds) is dtype-invariant
        assert io.simulated == f32.simulated
        assert io.bounds == f32.bounds
        if w == "f32":
            continue
        ratio = f32.weight_stream_bytes / io.weight_stream_bytes
        assert io.scale_bytes_streamed > 0
        assert ratio >= {"bf16": 1.8, "fp8": 3.5}[w], (w, ratio)


def test_dynamic_report_bytes_per_block(make_stack):
    block = 32
    plan = Engine(backend="jnp", gate=True, weight_dtype="bf16",
                  reorder_iters=20).compile(make_stack(block=block))
    rep = plan.measure_dynamic(np.asarray(_x(128)))
    assert rep.weight_dtype == "bf16"
    assert rep.bytes_per_block == block * block * 2 + 4   # blocks + scale
    assert rep.dynamic_weight_bytes == rep.dynamic_total * rep.bytes_per_block
    assert rep.static_weight_bytes >= rep.dynamic_weight_bytes


def test_io_report_dict_backward_compat():
    """A manifest dict persisted BEFORE byte accounting existed (no
    weight_dtype / byte keys) must still load, with zero-byte defaults."""
    old = {
        "simulated": {"reads": 10, "writes": 4},
        "bounds": {"reads_lo": 8, "reads_hi": 12,
                   "writes_lo": 4, "writes_hi": 6},
        "M_tiles": 3,
        "policy": "belady",
        "layered_reads": 11,
        "layered_writes": 5,
        "hidden_tiles_kept": 2,
        "hidden_bytes_kept_per_row": 1024,
        "dynamic": {
            "batch": 4,
            "per_layer_static": [6, 4],
            "per_layer_dynamic": [5, 3],
            "per_layer_in_tiles": [4, 4],
            "per_layer_live_tiles": [3, 3],
            "per_layer_row_occupancy": [0.5, 0.75],
            "per_layer_hist": [[1, 0, 1, 1, 1], [1, 0, 0, 1, 2]],
        },
    }
    io = IOReport.from_dict(old)
    assert io.weight_dtype == "f32"
    assert io.weight_stream_bytes == 0
    assert io.dynamic.bytes_per_block == 0
    assert io.dynamic.weight_dtype == "f32"
    # and the upgraded dict round-trips exactly
    assert IOReport.from_dict(io.to_dict()) == io


def test_quantized_io_report_roundtrip(make_stack):
    plan = Engine(backend="jnp", gate=True, weight_dtype="bf16",
                  reorder_iters=20).compile(make_stack())
    plan.measure_dynamic(np.asarray(_x(128)))
    assert plan.io.dynamic is not None
    restored = IOReport.from_dict(plan.io.to_dict())
    assert restored == plan.io
    assert restored.weight_dtype == "bf16"


# --------------------------------------------------------------------------- #
# persistence: plan store + cache key
# --------------------------------------------------------------------------- #

def test_plan_cache_key_separates_weight_dtypes(make_stack):
    layers = make_stack()
    dtypes = ("f32", "bf16", "fp8") if FP8_DTYPE is not None \
        else ("f32", "bf16")
    keys = {w: plan_cache_key(Engine(backend="jnp", weight_dtype=w), layers)
            for w in dtypes}
    assert len(set(keys.values())) == len(dtypes)
    # aliases normalize before keying: 'bfloat16' hits the 'bf16' entry
    assert plan_cache_key(
        Engine(backend="jnp", weight_dtype="bfloat16"), layers) \
        == keys["bf16"]
    # default f32 does not enter the dict: old store entries stay warm
    assert keys["f32"] == plan_cache_key(Engine(backend="jnp"), layers)


@pytest.mark.parametrize("wdt", QUANT_DTYPES)
def test_plan_store_warm_start_quantized(tmp_path, make_stack, wdt):
    layers = make_stack()
    eng = Engine(backend="jnp", weight_dtype=wdt, reorder_iters=20)
    store = PlanStore(tmp_path)
    cold, hit0 = store.get_or_compile(eng, layers)
    assert not hit0
    warm, hit1 = store.get_or_compile(eng, layers)
    assert hit1
    # byte-identical quantized stream: same narrow blocks, same scales
    assert np.asarray(warm.flat.blocks).dtype == \
        np.asarray(cold.flat.blocks).dtype
    assert np.asarray(warm.flat.blocks).tobytes() == \
        np.asarray(cold.flat.blocks).tobytes()
    assert np.asarray(warm.flat.scales).tobytes() == \
        np.asarray(cold.flat.scales).tobytes()
    x = _x(128)
    assert float(jnp.max(jnp.abs(warm(x) - cold(x)))) == 0.0
    # an f32 engine over the same net must miss (never alias dtypes)
    _, hit_f32 = store.get_or_compile(
        dc.replace(eng, weight_dtype="f32"), layers)
    assert not hit_f32


# --------------------------------------------------------------------------- #
# guard: fp8 unavailable
# --------------------------------------------------------------------------- #

def test_fp8_guard_when_ml_dtypes_lacks_float8(make_stack, monkeypatch):
    monkeypatch.setattr(ops, "FP8_DTYPE", None)
    with pytest.raises(ValueError, match="float8_e4m3fn"):
        resolve_weight_dtype("fp8")
    with pytest.raises(ValueError, match="float8_e4m3fn"):
        Engine(backend="jnp", weight_dtype="fp8").compile(make_stack())
    # bf16 and f32 stay unaffected by the missing fp8 dtype
    assert resolve_weight_dtype("bf16") == "bf16"
