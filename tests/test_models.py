"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes and finiteness, plus serving-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import encdec, lm
from repro.models.config import applicable_shapes
from repro.models.sharding import set_mesh_axes

set_mesh_axes(("data",), "model")
B, S = 2, 32


def _batch(cfg, rng):
    if cfg.family == "encdec":
        return {"src_embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.float32),
            "tgt_tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S // 4))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S // 4)))}
    if cfg.modality == "vision_stub":
        return {"embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_loss_grad_decode(arch):
    cfg = reduced(get_config(arch))
    mod = encdec if cfg.family == "encdec" else lm
    rng = np.random.default_rng(0)
    p = mod.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    batch = _batch(cfg, rng)
    lval, metrics = mod.loss_fn(p, cfg, batch)
    assert np.isfinite(float(lval))
    g = jax.grad(lambda pp: mod.loss_fn(pp, cfg, batch)[0])(p)
    gnorm = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                               for x in jax.tree.leaves(g))))
    assert np.isfinite(gnorm) and gnorm > 0
    # one decode step
    if cfg.family == "encdec":
        enc_out = encdec.encode(p, cfg, batch["src_embeds"])
        caches = encdec.make_dec_caches(p, cfg, enc_out, window=8,
                                        dtype=jnp.float32)
        logits, caches2 = encdec.decode_step(p, cfg,
                                             batch["tgt_tokens"][:, :1], caches)
    else:
        caches = lm.make_caches(cfg, B, 8, dtype=jnp.float32)
        tok = batch.get("tokens", jnp.zeros((B, 8), jnp.int32))[:, :1]
        logits, caches2 = lm.decode_step(p, cfg, tok, caches)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b",
                                  "codeqwen1.5-7b", "granite-moe-1b-a400m"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Greedy next-token from (prefill + decode) must equal the full forward.

    MoE archs use a no-drop capacity factor here: with finite capacity the
    full forward legitimately drops overflow tokens that a single-token
    decode step would not — that difference is semantic, not a bug."""
    import dataclasses

    cfg = reduced(get_config(arch))
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    rng = np.random.default_rng(1)
    p = lm.init(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    # full forward logits at the last position
    h, _ = lm.forward(p, cfg, tokens=toks)
    full_logits = jnp.einsum("bd,dv->bv", h[:, -1], lm.unembed_matrix(p))
    logits_pre, caches = lm.prefill(p, cfg, tokens=toks[:, :S])
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(full_logits),
                               rtol=5e-3, atol=5e-3)
    # decode one more token; compare against full forward on S+1 tokens
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)[:, None]
    caches = lm.grow_caches(cfg, caches, S + 4)
    logits_dec, _ = lm.decode_step(p, cfg, nxt, caches)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    h2, _ = lm.forward(p, cfg, tokens=toks2)
    full2 = jnp.einsum("bd,dv->bv", h2[:, -1], lm.unembed_matrix(p))
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(full2),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_applicable_shapes_rule(arch):
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    assert ("long_500k" in shapes) == (cfg.family in ("ssm", "hybrid"))
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_param_counts_in_expected_range():
    """Config sanity: derived parameter counts are near the nameplate sizes."""
    expect = {
        "granite-moe-1b-a400m": (0.8e9, 2.2e9),
        "deepseek-moe-16b": (13e9, 20e9),
        "nemotron-4-15b": (12e9, 18e9),
        "stablelm-12b": (10e9, 14.5e9),
        "minitron-4b": (3.5e9, 6e9),
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "internvl2-26b": (17e9, 23e9),  # LM backbone only (ViT is stubbed)
        "seamless-m4t-medium": (0.8e9, 1.8e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
