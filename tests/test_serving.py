"""Serving runtime: bucketed plans, the SLO scheduler, and metrics.

The bucket router must be output-transparent (same results as the base
plan, any batch size), and the scheduler must be deterministic under an
injected clock — every wait-or-fire rule is driven through virtual time.
"""

import numpy as np
import pytest
from conftest import FakeClock

from repro.engine import Engine
from repro.serving import (
    BucketedPlanSet,
    ServingMetrics,
    SparseServer,
    bucket_sizes,
    percentile,
)


@pytest.fixture
def plans(make_stack):
    return BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp"), max_batch=8)


# --------------------------------------------------------------------------- #
# bucketing
# --------------------------------------------------------------------------- #

def test_bucket_sizes_powers_of_two():
    assert bucket_sizes(1) == (1,)
    assert bucket_sizes(8) == (1, 2, 4, 8)
    # non-power-of-two max still gets an exact top bucket
    assert bucket_sizes(24) == (1, 2, 4, 8, 16, 24)
    with pytest.raises(ValueError):
        bucket_sizes(0)


def test_bucket_for_routes_to_smallest_fit(plans):
    assert [plans.bucket_for(n) for n in (1, 2, 3, 4, 5, 8)] == \
        [1, 2, 4, 4, 8, 8]
    with pytest.raises(ValueError):
        plans.bucket_for(0)


def test_bucketed_outputs_match_base_plan(plans, make_stack):
    """Routing through any bucket is output-transparent, odd sizes included."""
    rng = np.random.default_rng(1)
    n_in = plans.n_in
    full = rng.standard_normal((8, n_in)).astype(np.float32)
    y_base = np.asarray(plans.base(full))
    for n in (1, 2, 3, 5, 7, 8):
        y = plans(full[:n])
        assert y.shape == (n, plans.n_out)
        np.testing.assert_array_equal(y, y_base[:n])


def test_bucketed_chunks_oversized_batches(plans):
    rng = np.random.default_rng(2)
    x = rng.standard_normal((19, plans.n_in)).astype(np.float32)
    y = plans(x)
    assert y.shape == (19, plans.n_out)
    np.testing.assert_array_equal(y[:8], plans(x[:8]))
    np.testing.assert_array_equal(y[16:], plans(x[16:19]))


def test_buckets_share_schedule_and_count_calls(plans):
    """One schedule substrate; only the jitted forward differs per bucket."""
    for b in plans.buckets:
        p = plans.plans[b]
        assert p.schedules is plans.base.schedules
        assert p.flat is plans.base.flat
        assert p.io is plans.base.io
        assert p.order is plans.base.order
    plans.warmup()
    assert all(plans.plans[b].calls == 0 for b in plans.buckets)
    rng = np.random.default_rng(3)
    plans(rng.standard_normal((3, plans.n_in)).astype(np.float32))
    plans(rng.standard_normal((4, plans.n_in)).astype(np.float32))
    plans(rng.standard_normal((1, plans.n_in)).astype(np.float32))
    assert plans.bucket_calls[4] == 2 and plans.bucket_calls[1] == 1
    assert plans.plans[4].calls == 2


def test_bucketed_rejects_bad_input(plans):
    with pytest.raises(ValueError):
        plans(np.zeros((2, plans.n_in + 1), np.float32))


# --------------------------------------------------------------------------- #
# scheduler
# --------------------------------------------------------------------------- #

def test_server_results_match_direct_plan(plans):
    rng = np.random.default_rng(4)
    xs = [rng.standard_normal(plans.n_in).astype(np.float32)
          for _ in range(11)]
    server = SparseServer(plans, slo_ms=100.0)
    rids = [server.submit(x) for x in xs]
    server.poll()
    server.drain()
    expected = plans(np.stack(xs))
    for rid, want in zip(rids, expected):
        np.testing.assert_array_equal(server.result(rid), want)
    assert server.metrics.served == 11
    assert server.queue_depth == 0


def test_admission_control_rejects_when_full(plans):
    clock = FakeClock()
    server = SparseServer(plans, max_queue=2, clock=clock)
    assert server.submit(np.zeros(plans.n_in, np.float32)) is not None
    assert server.submit(np.zeros(plans.n_in, np.float32)) is not None
    assert server.submit(np.zeros(plans.n_in, np.float32)) is None
    assert server.metrics.rejected == 1
    assert server.metrics.admitted == 2


def test_fire_on_full_batch(plans):
    clock = FakeClock()
    server = SparseServer(plans, max_batch=4, slo_ms=1e6, clock=clock)
    for _ in range(3):
        server.submit(np.zeros(plans.n_in, np.float32))
    assert not server.should_fire()    # not full, nobody waited long enough
    server.submit(np.zeros(plans.n_in, np.float32))
    assert server.should_fire()        # full batch fires immediately
    assert server.step() == 4
    assert server.metrics.bucket_hist == {4: 1}


def test_fire_on_max_wait(plans):
    clock = FakeClock()
    server = SparseServer(plans, max_batch=8, slo_ms=100.0,
                          max_wait_ms=10.0, clock=clock)
    server.submit(np.zeros(plans.n_in, np.float32))
    assert server.step() == 0          # wait: batching might still grow it
    clock.advance(0.011)               # oldest has now waited past max_wait
    assert server.should_fire()
    assert server.step() == 1
    # the 1-row tail batch went through the 1-bucket, not the full one
    assert server.metrics.bucket_hist == {1: 1}


def test_fire_before_deadline_breach(plans):
    """Deadline-aware: fire once waiting longer would miss the SLO given
    the observed batch latency."""
    clock = FakeClock()
    server = SparseServer(plans, max_batch=8, slo_ms=1000.0,
                          max_wait_ms=1000.0, clock=clock)
    server._lat_ewma[1] = 0.010        # as if 1-row batches take 10 ms
    server.submit(np.zeros(plans.n_in, np.float32), deadline_ms=15.0)
    assert not server.should_fire()    # 15 ms budget > 10 ms estimate: wait
    clock.advance(0.006)
    assert server.should_fire()        # 9 ms left <= 10 ms estimate: fire
    assert server.step() == 1


def test_deadline_miss_is_counted(plans):
    clock = FakeClock()
    server = SparseServer(plans, clock=clock)
    server.submit(np.zeros(plans.n_in, np.float32), deadline_ms=5.0)
    clock.advance(1.0)                 # way past the deadline
    server.drain()
    assert server.metrics.deadline_misses == 1
    assert server.metrics.served == 1


def test_drain_serves_everything(plans):
    clock = FakeClock()
    server = SparseServer(plans, max_batch=8, slo_ms=1e6, max_wait_ms=1e6,
                          clock=clock)
    rids = [server.submit(np.zeros(plans.n_in, np.float32))
            for _ in range(13)]
    assert server.poll() == 8          # one full batch fires, 5 wait
    assert server.drain() == 5
    assert all(server.result(r) is not None for r in rids)


def test_bucketed_call_casts_to_plan_dtype_no_retrace(make_stack):
    """A float64 client must NOT lower a second program per bucket: inputs
    are cast to the plan dtype before bucket padding.  The Python-callable
    activation runs once per layer per trace, so it counts traces."""
    traces = {"n": 0}

    def act(x):
        traces["n"] += 1
        import jax.numpy as jnp
        return jnp.maximum(x, 0)

    plans = BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp", activation=act),
        max_batch=4)
    assert plans.dtype == np.float32
    plans.warmup()
    warm_traces = traces["n"]
    assert warm_traces > 0

    rng = np.random.default_rng(7)
    # float16 retraces unconditionally without the cast; float64 does too
    # whenever jax_enable_x64 is on (and costs a canonicalization otherwise)
    x64 = rng.standard_normal((3, plans.n_in))          # float64 client
    y64 = plans(x64)
    assert traces["n"] == warm_traces, "float64 input retraced a bucket"
    x16 = x64.astype(np.float16)
    plans(x16)
    assert traces["n"] == warm_traces, "float16 input retraced a bucket"
    y32 = plans(x64.astype(np.float32))
    assert traces["n"] == warm_traces
    np.testing.assert_array_equal(y64, y32)


def test_warmup_seeds_per_bucket_latency(plans):
    assert plans.warmup_s == {}
    plans.warmup()
    assert set(plans.warmup_s) == set(plans.buckets)
    assert all(t > 0 for t in plans.warmup_s.values())
    # a server built on warmed plans has a live latency estimate (and so a
    # live deadline clause) BEFORE any batch has completed
    server = SparseServer(plans, clock=FakeClock())
    est = server._estimated_batch_s(1)
    assert est > 0
    # a deadline tighter than the estimate fires immediately on submit —
    # the cold-start SLO hole this seeding closes
    server.submit(np.zeros(plans.n_in, np.float32),
                  deadline_ms=est * 1e3 / 2)
    assert server.should_fire()


def test_cold_server_without_warmup_estimates_zero(plans):
    server = SparseServer(plans, clock=FakeClock())
    assert server._estimated_batch_s(1) == 0.0


def test_result_capacity_eviction(plans):
    """Never-collected results are bounded: oldest finished results are
    evicted beyond result_capacity and counted."""
    clock = FakeClock()
    server = SparseServer(plans, max_batch=1, clock=clock,
                          result_capacity=3)
    rids = [server.submit(np.zeros(plans.n_in, np.float32))
            for _ in range(8)]
    server.drain()
    assert server.metrics.served == 8
    assert server.metrics.results_evicted == 5
    # the oldest five are gone, the newest three still collectable
    assert all(server.result(r) is None for r in rids[:5])
    assert all(server.result(r) is not None for r in rids[5:])


def test_result_ttl_eviction(plans):
    clock = FakeClock()
    server = SparseServer(plans, clock=clock, result_ttl_s=1.0)
    rid = server.submit(np.zeros(plans.n_in, np.float32))
    server.drain()
    clock.advance(2.0)                 # result now stale
    # the TTL sweep runs on the next submit (no background work needed)
    rid2 = server.submit(np.zeros(plans.n_in, np.float32))
    assert server.result(rid) is None
    assert server.metrics.results_evicted == 1
    server.drain()
    assert server.result(rid2) is not None   # fresh results unaffected


def test_queued_requests_never_evicted(plans):
    """Capacity/TTL eviction only applies to FINISHED results; queued
    requests always get served and stay collectable right after."""
    clock = FakeClock()
    server = SparseServer(plans, max_batch=8, clock=clock,
                          result_capacity=2, result_ttl_s=1.0)
    rids = [server.submit(np.zeros(plans.n_in, np.float32))
            for _ in range(6)]
    clock.advance(5.0)                 # queued far past the TTL
    server.submit(np.zeros(plans.n_in, np.float32))   # triggers TTL sweep
    assert server.queue_depth == 7
    server.drain()
    assert server.metrics.served == 7                # nothing dropped
    assert server.metrics.results_evicted == 5       # 7 done - capacity 2
    assert server.result(rids[5]) is not None        # newest survive


def test_queue_depth_convention_is_arrival_depth(plans):
    """Admitted and rejected submits record the SAME convention: the depth
    observed on arrival.  max_queue_depth is the depth attained."""
    clock = FakeClock()
    server = SparseServer(plans, max_queue=2, clock=clock)
    server.submit(np.zeros(plans.n_in, np.float32))   # sees depth 0
    server.submit(np.zeros(plans.n_in, np.float32))   # sees depth 1
    server.submit(np.zeros(plans.n_in, np.float32))   # rejected at depth 2
    assert server.metrics.queue_depth.values() == [0.0, 1.0, 2.0]
    assert server.metrics.snapshot()["max_queue_depth"] == 2


# --------------------------------------------------------------------------- #
# metrics
# --------------------------------------------------------------------------- #

def test_percentile_nearest_rank():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 50) == 3.0
    assert percentile(xs, 100) == 5.0
    assert percentile([], 50) == 0.0


def test_percentile_edge_cases():
    """Total on every input snapshot() can produce: empty and single-sample
    series, q=100 landing on max (never past the end), out-of-range q
    clamped rather than raised."""
    assert percentile([], 0) == 0.0
    assert percentile([], 100) == 0.0
    for q in (0, 50, 99, 100):
        assert percentile([7.5], q) == 7.5
    xs = [1.0, 2.0]
    assert percentile(xs, 100) == 2.0
    assert percentile(xs, 150) == 2.0     # clamps to q=100
    assert percentile(xs, -10) == 1.0     # clamps to q=0
    assert percentile(xs, 99) == 2.0      # nearest rank, not interpolation


def test_metrics_snapshot_never_raises_when_fresh():
    """A server that saw zero traffic must still snapshot/summarize."""
    m = ServingMetrics()
    s = m.snapshot()
    assert s["served"] == 0
    assert s["throughput_rps"] == 0.0
    assert s["latency_ms"]["p99"] == 0.0
    assert s["mean_batch_size"] == 0.0
    assert isinstance(m.summary(), str)
    # a single served request exercises the len-1 percentile path end-to-end
    m.record_submit(0.0, 0, admitted=True)
    m.record_batch(1.0, n=1, bucket=1, exec_s=0.25, waits_s=[0.5], misses=0)
    s = m.snapshot()
    assert s["latency_ms"]["p50"] == s["latency_ms"]["p99"] == 750.0


def test_metrics_snapshot_shape():
    m = ServingMetrics()
    m.record_submit(0.0, 1, admitted=True)
    m.record_submit(0.0, 2, admitted=True)
    m.record_batch(1.0, n=2, bucket=4, exec_s=0.5, waits_s=[0.1, 0.2],
                   misses=1)
    s = m.snapshot()
    assert s["served"] == 2 and s["batches"] == 1
    assert s["deadline_misses"] == 1
    assert s["padding_fraction"] == pytest.approx(0.5)
    assert s["latency_ms"]["p50"] <= s["latency_ms"]["p99"]
    assert s["bucket_hist"] == {"4": 1}
    assert s["throughput_rps"] == pytest.approx(2.0)
    assert "p50" in m.summary() or "latency" in m.summary()
