"""Tests for the paper's core contribution: bounds, simulator, CR, CG.

Each test names the paper statement it checks.
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    connection_reordering,
    generate,
    random_ffnn,
    simulate,
    theorem1_bounds,
)
from repro.core.bounds import (
    chain_order,
    lemma1_net,
    lemma2_net,
    lemma3_net,
    proposition2_net,
)
from repro.core.compact_growth import bandwidth_order
from repro.core.graph import from_layer_sizes
from repro.core.iosim import simulate as simulate_io
from repro.core.reorder import _apply_move


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

small_nets = st.builds(
    random_ffnn,
    width=st.integers(4, 40),
    depth=st.integers(2, 5),
    density=st.floats(0.05, 0.6),
    seed=st.integers(0, 10_000),
)


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(net=small_nets, M=st.integers(3, 120))
def test_theorem1_bounds_hold_for_theorem1_order_min(net, M):
    """Thm 1: the constructive order under MIN stays within all six bounds."""
    b = theorem1_bounds(net)
    s = simulate(net, net.theorem1_order(), M, "min")
    assert b.reads_lo <= s.reads <= b.reads_hi
    assert b.writes_lo <= s.writes <= b.writes_hi
    assert b.total_lo <= s.total <= b.total_hi


@settings(max_examples=25, deadline=None)
@given(net=small_nets, M=st.integers(3, 120))
def test_lower_bounds_hold_for_any_topological_order(net, M):
    """Thm 1 lower bounds hold for *every* strategy, here the layer order."""
    b = theorem1_bounds(net)
    for policy in ("min", "lru", "rr"):
        s = simulate(net, net.layer_order(), M, policy)
        assert s.reads >= b.reads_lo
        assert s.writes >= b.writes_lo


def test_lemma1_attains_lower_bound_exactly():
    net = lemma1_net(M=60)
    b = theorem1_bounds(net)
    s = simulate(net, net.theorem1_order(), M=60, policy="min")
    assert (s.reads, s.writes) == (b.reads_lo, b.writes_lo)


def test_lemma2_star_attains_read_upper_bound():
    net = lemma2_net(500)
    b = theorem1_bounds(net)
    s = simulate(net, net.theorem1_order(), M=3, policy="min")
    assert s.reads == b.reads_hi
    assert s.total == b.total_hi


def test_lemma3_write_heavy_net():
    net = lemma3_net(n_inputs=20, hidden=5, n_outputs=200)
    b = theorem1_bounds(net)
    s = simulate(net, net.theorem1_order(), M=10, policy="min")
    # S outputs must be written; with S >> h this approaches N - I
    assert s.writes >= net.S
    assert s.writes <= b.writes_hi


def test_proposition2_layer_order_write_blowup():
    """Prop 2: layer-by-layer needs >= M*c writes; chain-by-chain needs 1."""
    M, c = 12, 6
    net = proposition2_net(M, c)
    layer_writes = simulate(net, net.layer_order(), M, "min").writes
    chainw = simulate(net, chain_order(net), M, "min").writes
    assert layer_writes >= M * c
    assert chainw == 1


# ---------------------------------------------------------------------------
# Eviction policies
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(net=small_nets, M=st.integers(3, 100), use_layer=st.booleans())
def test_min_is_optimal_among_policies(net, M, use_layer):
    """Belady (MIN) never does worse than LRU or RR on the same order."""
    order = net.layer_order() if use_layer else net.theorem1_order()
    m = simulate(net, order, M, "min").total
    assert m <= simulate(net, order, M, "lru").total
    assert m <= simulate(net, order, M, "rr").total


@settings(max_examples=20, deadline=None)
@given(net=small_nets, M=st.integers(3, 100),
       policy=st.sampled_from(["min", "lru", "rr"]))
def test_c_accelerator_matches_python(net, M, policy):
    a = simulate_io(net, net.theorem1_order(), M, policy, force_python=True)
    b = simulate_io(net, net.theorem1_order(), M, policy)
    assert (a.reads, a.writes) == (b.reads, b.writes)


@settings(max_examples=15, deadline=None)
@given(net=small_nets, policy=st.sampled_from(["min", "lru"]))
def test_monotone_in_memory_size(net, policy):
    """More fast memory never costs more I/Os (for stack policies)."""
    order = net.theorem1_order()
    prev = None
    for M in (3, 8, 20, 60, 200):
        cur = simulate(net, order, M, policy).total
        if prev is not None and policy == "min":
            assert cur <= prev
        prev = cur


def test_large_memory_reaches_lower_bound():
    net = random_ffnn(width=30, depth=3, density=0.3, seed=7)
    b = theorem1_bounds(net)
    s = simulate(net, net.theorem1_order(), M=net.N + 2, policy="min")
    assert (s.reads, s.writes) == (b.reads_lo, b.writes_lo)


# ---------------------------------------------------------------------------
# Connection Reordering (paper IV)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(net=small_nets, seed=st.integers(0, 1000),
       i_frac=st.floats(0, 1), w=st.integers(0, 40),
       direction=st.integers(0, 1))
def test_moves_preserve_topological_validity(net, seed, i_frac, w, direction):
    order = net.theorem1_order().astype(np.int64).tolist()
    i = min(net.W - 1, int(i_frac * net.W))
    new = _apply_move(list(order), net.src.tolist(), net.dst.tolist(), i, w, direction)
    assert sorted(new) == list(range(net.W))
    assert net.is_topological_connection_order(np.array(new))


@settings(max_examples=8, deadline=None)
@given(net=small_nets, M=st.integers(4, 60), seed=st.integers(0, 100))
def test_cr_never_returns_worse_than_initial(net, M, seed):
    order = net.theorem1_order()
    res = connection_reordering(net, order, M, T=60, seed=seed)
    assert res.ios <= res.initial_ios
    assert net.is_topological_connection_order(res.order)


def test_cr_preserves_network_function():
    net = random_ffnn(width=25, depth=3, density=0.3, seed=11)
    order = net.theorem1_order()
    res = connection_reordering(net, order, M=10, T=150, seed=3)
    x = np.random.default_rng(0).standard_normal(net.I)
    np.testing.assert_allclose(net.forward(x, order), net.forward(x, res.order),
                               rtol=1e-5, atol=1e-6)


def test_cr_reduces_ios_on_memory_pressure():
    """With tight memory the initial 2-optimal order is improvable (paper VI.A.1)."""
    net = random_ffnn(width=120, depth=4, density=0.1, seed=0)
    res = connection_reordering(net, net.theorem1_order(), M=20, T=800, seed=0)
    assert res.ios < res.initial_ios  # strictly improves on this instance


# ---------------------------------------------------------------------------
# capped move spans (CR at scale)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(net=small_nets, seed=st.integers(0, 1000),
       i_frac=st.floats(0, 1), w=st.integers(0, 40),
       direction=st.integers(0, 1), span=st.integers(1, 12))
def test_capped_moves_preserve_topological_validity(net, seed, i_frac, w,
                                                    direction, span):
    """A span-capped move is a prefix of the full anchor scan — still a
    permutation, still topological, and never travels farther than span."""
    order = net.theorem1_order().astype(np.int64).tolist()
    i = min(net.W - 1, int(i_frac * net.W))
    new = _apply_move(list(order), net.src.tolist(), net.dst.tolist(),
                      i, w, direction, span)
    assert sorted(new) == list(range(net.W))
    assert net.is_topological_connection_order(np.array(new))


def test_capped_moves_never_travel_past_span():
    """The defining property of the cap: a single moved connection (window
    w=0) ends up at most ``span`` positions from where it started."""
    net = random_ffnn(width=40, depth=4, density=0.2, seed=7)
    order = net.theorem1_order().astype(np.int64).tolist()
    src, dst = net.src.tolist(), net.dst.tolist()
    rng = np.random.default_rng(1)
    for span in (1, 3, 8):
        for _ in range(100):
            i = int(rng.integers(0, net.W))
            d = int(rng.integers(0, 2))
            new = _apply_move(list(order), src, dst, i, 0, d, span)
            e = order[i]
            assert abs(new.index(e) - i) <= span, (span, i, d)


def test_capped_moves_c_matches_python():
    """The C accelerator's span-capped propose_move must stay bit-identical
    to the Python reference — stored plan orders (and plan-store warm-start
    bit-identity) would otherwise differ between hosts with/without cc."""
    from repro.core import _iosim_c
    if not _iosim_c.available():
        pytest.skip("C accelerator unavailable")
    net = random_ffnn(width=35, depth=3, density=0.3, seed=3)
    order = net.theorem1_order().astype(np.int64)
    src_l, dst_l = net.src.tolist(), net.dst.tolist()
    src32 = np.ascontiguousarray(net.src, np.int32)
    dst32 = np.ascontiguousarray(net.dst, np.int32)
    rng = np.random.default_rng(2)
    for span in (0, 1, 4, 11, 10 ** 9):
        for _ in range(150):
            i = int(rng.integers(0, net.W))
            w = int(rng.integers(0, 8))
            d = int(rng.integers(0, 2))
            py = np.array(_apply_move(order.tolist(), src_l, dst_l,
                                      i, w, d, span), np.int64)
            c = order.copy()
            assert _iosim_c.propose_move_c(c, src32, dst32, i, w, d, span)
            np.testing.assert_array_equal(py, c, err_msg=str((span, i, w, d)))


def test_huge_span_equals_unbounded_moves():
    net = random_ffnn(width=30, depth=3, density=0.3, seed=5)
    order = net.theorem1_order().astype(np.int64).tolist()
    src, dst = net.src.tolist(), net.dst.tolist()
    rng = np.random.default_rng(0)
    for _ in range(200):
        i = int(rng.integers(0, net.W))
        w = int(rng.integers(0, 10))
        d = int(rng.integers(0, 2))
        full = _apply_move(list(order), src, dst, i, w, d, 0)
        capped = _apply_move(list(order), src, dst, i, w, d, 10 ** 9)
        assert full == capped


def test_capped_cr_stays_within_theorem1_upper_bound():
    """ROADMAP 'CR at scale': capping move spans keeps the annealer's
    windowed delta evaluation cheap; the result must stay valid and — after
    Theorem-1 regrouping, as the engine consumes it — inside the paper's
    upper bound."""
    from repro.core.blocksparse import regroup_by_output
    net = random_ffnn(width=60, depth=4, density=0.15, seed=2)
    order = net.theorem1_order()
    bounds = theorem1_bounds(net)
    for span in (4, 16):
        res = connection_reordering(net, order, M=12, T=300, seed=1,
                                    max_move_span=span)
        assert res.ios <= res.initial_ios
        assert net.is_topological_connection_order(res.order)
        regrouped = regroup_by_output(net, res.order)
        s = simulate(net, regrouped, 12, "min")
        assert s.total <= bounds.total_hi
        assert bounds.writes_lo <= s.writes <= bounds.writes_hi


def test_cr_rejects_negative_span():
    net = random_ffnn(width=10, depth=2, density=0.4, seed=0)
    with pytest.raises(ValueError, match="max_move_span"):
        connection_reordering(net, net.theorem1_order(), M=5, T=5,
                              max_move_span=-1)


# ---------------------------------------------------------------------------
# Compact Growth (paper V)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(Mg=st.integers(5, 120), iters=st.integers(10, 300),
       indeg=st.integers(1, 8), seed=st.integers(0, 1000))
def test_compact_growth_attains_lower_bound_at_Mg(Mg, iters, indeg, seed):
    """Thm 2 'if' direction: CG nets run at the exact lower bound with M >= M_g."""
    cg = generate(M_g=Mg, n_iters=iters, in_degree=indeg, seed=seed)
    b = theorem1_bounds(cg.net)
    s = simulate(cg.net, cg.order, Mg, "min")
    assert (s.reads, s.writes) == (b.reads_lo, b.writes_lo)
    # also with any larger memory
    s2 = simulate(cg.net, cg.order, Mg + 50, "min")
    assert (s2.reads, s2.writes) == (b.reads_lo, b.writes_lo)


def test_compact_growth_below_Mg_needs_more_ios():
    cg = generate(M_g=100, n_iters=400, in_degree=5, seed=1)
    b = theorem1_bounds(cg.net)
    tight = simulate(cg.net, cg.order, 20, "min")
    assert tight.total > b.total_lo  # memory starvation costs extra I/Os


@settings(max_examples=10, deadline=None)
@given(net=small_nets)
def test_corollary1_bandwidth_order(net):
    """Cor 1: with M = bandwidth+2, the bandwidth order hits the lower bound."""
    order, M = bandwidth_order(net)
    b = theorem1_bounds(net)
    s = simulate(net, order, M, "min")
    assert (s.reads, s.writes) == (b.reads_lo, b.writes_lo)


# ---------------------------------------------------------------------------
# Graph / forward invariance
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(net=small_nets, seed=st.integers(0, 100))
def test_forward_invariant_under_any_topological_order(net, seed):
    x = np.random.default_rng(seed).standard_normal(net.I)
    y1 = net.forward(x, net.theorem1_order())
    y2 = net.forward(x, net.layer_order())
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


def test_order_validation_rejects_non_topological():
    net = from_layer_sizes([2, 2, 1], [np.ones((2, 2), bool), np.ones((2, 1), bool)])
    order = net.theorem1_order()
    bad = order[::-1].copy()
    assert not net.is_topological_connection_order(bad)
    with pytest.raises(ValueError):
        simulate(net, bad, 5, validate_order=True)
