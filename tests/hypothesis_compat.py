"""Use hypothesis when installed; otherwise degrade gracefully.

The property tests import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly.  When hypothesis is available these are the real
objects.  When it is not (the CI image does not ship it), a minimal stand-in
runs each property as a deterministic multi-example smoke test: every strategy
draws from a seeded ``numpy`` RNG, and ``@given`` executes the test body for a
handful of examples.  Weaker than real shrinking/fuzzing, but the properties
still execute instead of erroring the whole collection.
"""

from __future__ import annotations

import functools
import inspect

try:  # pragma: no cover - depends on the environment
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5  # examples per property when stubbing

    class _Strategy:
        """A draw(rng) callable; mirrors the tiny hypothesis surface we use."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(min_value + (max_value - min_value) * rng.random())
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])

        @staticmethod
        def builds(fn, **kw_strategies):
            return _Strategy(
                lambda rng: fn(**{k: s.draw(rng) for k, s in kw_strategies.items()})
            )

    def settings(**_kwargs):  # noqa: D401 - decorator factory
        """No-op stand-in for ``hypothesis.settings``."""

        def deco(fn):
            return fn

        return deco

    def given(**kw_strategies):
        """Run the property for a few seeded examples (deterministic)."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for example in range(_FALLBACK_EXAMPLES):
                    rng = np.random.default_rng(0xC0FFEE + example)
                    drawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not see the drawn parameters as fixtures: expose a
            # signature with them removed (real hypothesis does the same).
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in kw_strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__  # or inspect falls back to fn's signature
            return wrapper

        return deco
