"""Windowed/incremental I/O delta evaluation tests (``IncrementalSimulator``).

The contract is exactness: for ANY candidate order produced by the annealer's
windowed moves, ``propose(cand)`` must equal a full ``simulate()`` — on both
the C-accelerated and the pure-Python segment runners, across chained
commits, memory sizes, and DAG shapes (random FFNNs and real block DAGs).
``connection_reordering`` with the delta evaluator must therefore be
bit-identical to the full-re-simulation path for the same seed.
"""

import numpy as np
import pytest

from repro.core.blocksparse import to_block_ffnn
from repro.core.graph import random_ffnn
from repro.core.iosim import IncrementalSimulator, simulate
from repro.core.reorder import _apply_move, connection_reordering
from repro.sparse import prune_dense_stack


def _random_move(net, cur, rng, ws=8):
    src_l, dst_l = net.src.tolist(), net.dst.tolist()
    i = int(rng.integers(0, net.W))
    w = int(rng.integers(0, ws))
    d = 0 if rng.random() < 0.5 else 1
    return np.array(_apply_move(cur.tolist(), src_l, dst_l, i, w, d),
                    dtype=np.int64)


@pytest.mark.parametrize("use_c", [True, False])
@pytest.mark.parametrize("M", [3, 4, 6])
def test_delta_equals_full_simulation(use_c, M):
    for trial in range(3):
        net = random_ffnn(width=14, depth=4, density=0.35, seed=trial)
        order = net.theorem1_order()
        sim = IncrementalSimulator(net, order, M)
        if not use_c:
            sim._use_c = False
            sim._rebuild(np.ascontiguousarray(order, dtype=np.int64))
        assert sim.total == simulate(net, order, M, "min").total
        rng = np.random.default_rng(100 + trial)
        cur = np.asarray(order, dtype=np.int64).copy()
        for _ in range(40):
            cand = _random_move(net, cur, rng)
            got = sim.propose(cand)
            want = simulate(net, cand, M, "min", force_python=True).total
            assert got == want
            if rng.random() < 0.5:  # chained commits
                sim.commit()
                cur = cand
                assert sim.total == want


def test_delta_on_real_block_dag():
    rng = np.random.default_rng(0)
    sizes = (256, 512, 384, 256)
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32)
          for i in range(3)]
    bs = [np.zeros(s, np.float32) for s in sizes[1:]]
    layers = prune_dense_stack(ws, bs, density=0.3, block_m=32, block_n=32)
    net = to_block_ffnn(layers).net
    order = net.theorem1_order()
    sim = IncrementalSimulator(net, order, 3)
    rng = np.random.default_rng(1)
    cur = np.asarray(order, dtype=np.int64).copy()
    avg_in = net.W / max(1, net.N - net.I)
    ws_win = max(1, int(round(4 * avg_in)))
    for it in range(25):
        cand = _random_move(net, cur, rng, ws=ws_win)
        assert sim.propose(cand) == simulate(net, cand, 3, "min").total
        if it % 3 == 0:
            sim.commit()
            cur = cand


def test_propose_without_commit_leaves_baseline_intact():
    net = random_ffnn(width=12, depth=3, density=0.4, seed=9)
    order = net.theorem1_order()
    sim = IncrementalSimulator(net, order, 3)
    base = sim.total
    rng = np.random.default_rng(0)
    cur = np.asarray(order, dtype=np.int64)
    for _ in range(10):  # rejected proposals must not perturb the baseline
        sim.propose(_random_move(net, cur, rng))
    assert sim.total == base
    assert sim.propose(cur.copy()) == base  # no-op proposal


def test_non_min_policy_rejected():
    net = random_ffnn(width=10, depth=3, density=0.4, seed=0)
    with pytest.raises(ValueError, match="MIN"):
        IncrementalSimulator(net, net.theorem1_order(), 3, policy="lru")
    with pytest.raises(ValueError, match="M >= 3"):
        IncrementalSimulator(net, net.theorem1_order(), 2)


def test_reordering_incremental_is_bit_identical():
    net = random_ffnn(width=16, depth=4, density=0.3, seed=4)
    order = net.theorem1_order()
    inc = connection_reordering(net, order, M=3, T=250, seed=11,
                                incremental=True)
    full = connection_reordering(net, order, M=3, T=250, seed=11,
                                 incremental=False)
    assert inc.ios == full.ios
    assert inc.accepted == full.accepted
    np.testing.assert_array_equal(inc.order, full.order)
    np.testing.assert_array_equal(inc.history, full.history)


def test_reordering_incremental_forced_on_lru_raises():
    net = random_ffnn(width=10, depth=3, density=0.4, seed=0)
    with pytest.raises(ValueError, match="MIN"):
        connection_reordering(net, net.theorem1_order(), M=3, T=10,
                              policy="lru", incremental=True)
    # default: LRU silently uses the full evaluator
    res = connection_reordering(net, net.theorem1_order(), M=3, T=10,
                                policy="lru")
    assert res.proposed == 10
