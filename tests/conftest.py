"""Shared test configuration: deterministic seeds + small-net fixtures."""

import numpy as np
import pytest

from repro.sparse import prune_dense_stack


class FakeClock:
    """Manually-advanced virtual clock for the serving scheduler tests
    (inject as ``SparseServer(clock=...)``; shared by ``test_serving`` and
    ``test_server_async``)."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "stress: real-thread concurrency stress tests (CI runs these in "
        "their own lane with -p no:cacheprovider -x)")


@pytest.fixture(autouse=True)
def _deterministic_seeds():
    """Pin the legacy numpy global RNG for any test that touches it.

    Tests should prefer explicit ``np.random.default_rng(seed)`` generators;
    this fixture just makes anything that slips through reproducible."""
    np.random.seed(0)
    yield


@pytest.fixture
def make_stack():
    """Factory for small pruned BSR layer stacks (the shared test net).

    ``make_stack(sizes=(128, 256, 128), density=0.4, block=32, seed=0)``
    returns a list of ``BSRLayer`` whose tile shapes chain, with nonzero
    biases so epilogue bugs cannot hide.
    """

    def make(sizes=(128, 256, 128), density=0.4, block=32, seed=0):
        rng = np.random.default_rng(seed)
        ws = [
            rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.1
            for i in range(len(sizes) - 1)
        ]
        bs = [
            rng.standard_normal(sizes[i + 1]).astype(np.float32) * 0.1
            for i in range(len(sizes) - 1)
        ]
        return prune_dense_stack(ws, bs, density=density,
                                 block_m=block, block_n=block)

    return make
