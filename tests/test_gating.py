"""Runtime tile-occupancy gating: bit-exactness, measured dynamic I/O,
pad-row hygiene, and the fallback-reason surfacing it rode in with.

The gated forward must be BIT-IDENTICAL to the ungated one on every
backend — gating only skips contributions that are exactly zero — so every
comparison here is ``assert_array_equal``, never allclose.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import DynamicIOReport, Engine, Mesh, activations_equal

CPU_BACKENDS = ("jnp", "interpret")


def _kill_tiles(layers, frac, bias_val=-10.0):
    """Force the first ``frac`` of every hidden layer's output tiles dead:
    a large negative bias drives each pre-activation in the tile below
    zero, so ReLU zeroes the tile for any in-range input."""
    out = []
    for k, lay in enumerate(layers):
        if k < len(layers) - 1:
            kill = int(frac * lay.grid_out)
            bias = np.array(lay.bias, np.float32)
            bias.reshape(lay.grid_out, lay.block_n)[:kill] = bias_val
            lay = dataclasses.replace(lay, bias=bias)
        out.append(lay)
    return out


def _zero_input_tiles(x, block, n_tiles):
    """Zero the first ``n_tiles`` input tiles of every row."""
    x = np.array(x)
    x[:, : n_tiles * block] = 0.0
    return x


# --------------------------------------------------------------------------- #
# bit-exactness
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", CPU_BACKENDS)
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_gated_bit_exact_with_dead_tiles(make_stack, backend, batch):
    """Gated == ungated bitwise on ReLU nets with half the hidden tiles
    forced dead, across odd and even batch sizes."""
    layers = _kill_tiles(make_stack(sizes=(128, 256, 256, 128)), 0.5)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((batch, 128)), jnp.float32)
    gated = Engine(backend=backend, activation="relu",
                   gate=True).compile(layers)
    ungated = Engine(backend=backend, activation="relu").compile(layers)
    np.testing.assert_array_equal(np.asarray(gated(x)),
                                  np.asarray(ungated(x)))


@pytest.mark.parametrize("backend", CPU_BACKENDS)
def test_gated_bit_exact_with_zero_input_tiles(make_stack, backend):
    """All-zero INPUT tiles (layer-0 gating, via the occ0 scalar prefetch on
    the kernel path) are skipped without changing a bit."""
    layers = make_stack(sizes=(128, 256, 128))
    rng = np.random.default_rng(2)
    x = _zero_input_tiles(
        rng.standard_normal((5, 128)).astype(np.float32), 32, 2)
    gated = Engine(backend=backend, activation="relu",
                   gate=True).compile(layers)
    ungated = Engine(backend=backend, activation="relu").compile(layers)
    np.testing.assert_array_equal(np.asarray(gated(x)),
                                  np.asarray(ungated(x)))


@pytest.mark.parametrize("backend", CPU_BACKENDS)
def test_gated_layered_path_bit_exact(make_stack, backend):
    """fuse=False: the layered jnp lowering gates its per-layer gather; the
    layered pallas path stays ungated (and says so) — both bit-exact."""
    layers = _kill_tiles(make_stack(sizes=(128, 256, 128)), 0.5)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((3, 128)), jnp.float32)
    gated = Engine(backend=backend, activation="relu", fuse=False,
                   gate=True).compile(layers)
    ungated = Engine(backend=backend, activation="relu",
                     fuse=False).compile(layers)
    np.testing.assert_array_equal(np.asarray(gated(x)),
                                  np.asarray(ungated(x)))
    if backend != "jnp":
        assert "occupancy gating inactive" in gated.describe()


def test_gated_sigmoid_epilogue_bit_exact(make_stack):
    """Sigmoid is never zero at zero — the activation can only die by f32
    underflow — yet gating must stay bit-exact (nothing skippable is not a
    correctness bug, just no savings)."""
    layers = make_stack(sizes=(128, 256, 128))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((3, 128)), jnp.float32)
    gated = Engine(backend="jnp", activation="sigmoid",
                   gate=True).compile(layers)
    ungated = Engine(backend="jnp", activation="sigmoid").compile(layers)
    np.testing.assert_array_equal(np.asarray(gated(x)),
                                  np.asarray(ungated(x)))


# --------------------------------------------------------------------------- #
# pad-row hygiene (the epilogue bugfix)
# --------------------------------------------------------------------------- #

def test_pad_rows_do_not_leak_into_occupancy():
    """Odd-batch sigmoid regression on the kernel path.

    The kernel pads the batch to the sublane multiple; sigmoid maps padded
    zero rows to 0.5 — NONZERO — so occupancy computed over padded rows
    would see every tile live.  Build a net whose real-row pre-activations
    underflow f32 sigmoid to exact 0 in tile 0 (pre-activation <= -150) and
    check the measured occupancy still reports that tile dead.
    """
    from repro.sparse import prune_dense_stack

    rng = np.random.default_rng(5)
    sizes = [64, 64, 64]
    ws = [np.full((64, 64), -3.0, np.float32) for _ in range(2)]
    bs = [np.zeros(64, np.float32) for _ in range(2)]
    layers = prune_dense_stack(ws, bs, density=1.0, block_m=32, block_n=32)
    # every input > 0 => each hidden pre-activation = -3 * sum(x) <= -192
    x = jnp.asarray(rng.uniform(1.0, 2.0, (3, 64)), jnp.float32)

    for backend in CPU_BACKENDS:
        gated = Engine(backend=backend, activation="sigmoid",
                       gate=True).compile(layers)
        ungated = Engine(backend=backend,
                         activation="sigmoid").compile(layers)
        np.testing.assert_array_equal(np.asarray(gated(x)),
                                      np.asarray(ungated(x)))
        rep = gated.measure_dynamic(x)
        # the whole hidden state underflows to exact zero: layer 1 reads
        # nothing, and no 0.5-valued pad row resurrects a tile
        assert rep.per_layer_live_tiles[1] == 0
        assert rep.per_layer_dynamic[1] == 0


# --------------------------------------------------------------------------- #
# measured dynamic I/O
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", CPU_BACKENDS)
def test_dynamic_reads_below_static_with_dead_tiles(make_stack, backend):
    """>= 50% dead hidden tiles => strictly fewer dynamic than static block
    reads, and the occupancy fields explain the gap."""
    layers = _kill_tiles(make_stack(sizes=(128, 256, 256, 128)), 0.5)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    plan = Engine(backend=backend, activation="relu",
                  gate=True).compile(layers)
    rep = plan.measure_dynamic(x)
    assert rep.dynamic_total < rep.static_total
    assert rep.blocks_skipped == rep.static_total - rep.dynamic_total
    assert 0.0 < rep.read_fraction < 1.0
    n_layers = len(layers)
    assert len(rep.per_layer_static) == n_layers
    # hidden layers 1.. see at most half their input tiles live
    for k in range(1, n_layers):
        assert rep.per_layer_live_tiles[k] <= rep.per_layer_in_tiles[k] // 2
        # histogram is total over the tile count: dead + live buckets
        assert sum(rep.per_layer_hist[k]) == rep.per_layer_in_tiles[k]
        assert rep.per_layer_hist[k][0] == \
            rep.per_layer_in_tiles[k] - rep.per_layer_live_tiles[k]
    assert "dynamic I/O" in rep.summary()
    # the measurement is recorded on the plan's IOReport (and serializes)
    assert plan.io.dynamic is rep
    assert "dynamic I/O" in plan.io.summary()
    rt = DynamicIOReport.from_dict(rep.to_dict())
    assert rt == rep


def test_measure_matches_backends(make_stack):
    """jnp and interpret (kernel occupancy output) agree on the counts."""
    layers = _kill_tiles(make_stack(sizes=(128, 256, 256, 128)), 0.25)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((5, 128)), jnp.float32)
    reps = [
        Engine(backend=b, activation="relu",
               gate=True).compile(layers).measure_dynamic(x)
        for b in CPU_BACKENDS
    ]
    assert reps[0] == reps[1]


def test_measure_dynamic_requires_gated_fused(make_stack):
    layers = make_stack()
    plan = Engine(backend="jnp", activation="relu").compile(layers)
    rng = np.random.default_rng(8)
    x = rng.standard_normal((2, 128)).astype(np.float32)
    with pytest.raises(RuntimeError, match="gated fused plan"):
        plan.measure_dynamic(x)
    gated = Engine(backend="jnp", activation="relu",
                   gate=True).compile(layers)
    with pytest.raises(ValueError, match="expected input"):
        gated.measure_dynamic(x[:, :64])


# --------------------------------------------------------------------------- #
# sharded gating
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("mesh", [Mesh(2, 1), Mesh(2, 2)])
def test_sharded_gated_bit_exact(make_stack, mesh):
    """Gated == ungated == unsharded bitwise through the collective path,
    including the data-axis pad (B=3 under data=2 pads one row; the traced
    valid mask must keep it out of the occupancy)."""
    layers = _kill_tiles(make_stack(sizes=(128, 256, 256, 128)), 0.5)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((3, 128)), jnp.float32)
    gated = Engine(backend="jnp", activation="relu",
                   gate=True).compile(layers, mesh=mesh)
    ungated = Engine(backend="jnp",
                     activation="relu").compile(layers, mesh=mesh)
    flat = Engine(backend="jnp", activation="relu").compile(layers)
    y = np.asarray(gated(x))
    np.testing.assert_array_equal(y, np.asarray(ungated(x)))
    np.testing.assert_array_equal(y, np.asarray(flat(x)))
    assert "+gated" in gated.describe()


def test_sharded_gated_fresh_forward(make_stack):
    """The bucketing rebuild path (with_fresh_forward) keeps gating."""
    layers = _kill_tiles(make_stack(sizes=(128, 256, 128)), 0.5)
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    gated = Engine(backend="jnp", activation="relu",
                   gate=True).compile(layers, mesh=Mesh(2, 2))
    fresh = gated.with_fresh_forward()
    np.testing.assert_array_equal(np.asarray(fresh(x)),
                                  np.asarray(gated(x)))


# --------------------------------------------------------------------------- #
# fallback reporting (the make_fused_forward satellite)
# --------------------------------------------------------------------------- #

def _leaky(slope, x):
    return jnp.where(x > 0, x, slope * x)


def test_equal_partials_still_fuse(make_stack):
    """Per-layer ``functools.partial`` epilogues with identical bound args
    are ONE activation — the plan must keep the fused lowering instead of
    silently dropping to layered dispatch on object identity."""
    layers = make_stack(sizes=(128, 256, 256, 128))
    acts = [functools.partial(_leaky, 0.1), functools.partial(_leaky, 0.1)]
    assert acts[0] is not acts[1] and activations_equal(*acts)
    plan = Engine(backend="jnp", activation=acts).compile(layers)
    assert plan.fused
    assert plan.fallback_reason is None
    # and it computes the right thing
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((2, 128)), jnp.float32)
    ref = Engine(backend="jnp",
                 activation=functools.partial(_leaky, 0.1)).compile(layers)
    np.testing.assert_array_equal(np.asarray(plan(x)), np.asarray(ref(x)))


def test_heterogeneous_activations_fall_back_with_reason(make_stack):
    layers = make_stack(sizes=(128, 256, 256, 128))
    plan = Engine(backend="jnp",
                  activation=[jax.nn.relu, jax.nn.gelu]).compile(layers)
    assert not plan.fused
    assert plan.fallback_reason is not None
    assert "ONE hidden-layer activation" in plan.fallback_reason
    assert "[fallback:" in plan.describe()
    # correctness of the layered lowering it fell back to
    rng = np.random.default_rng(12)
    x = np.asarray(rng.standard_normal((2, 128)), np.float32)
    h = x
    for lay, act in zip(layers, (jax.nn.relu, jax.nn.gelu, None)):
        W = np.zeros((lay.n_in, lay.n_out), np.float32)
        for r, c, b in zip(lay.rows, lay.cols, np.asarray(lay.blocks)):
            W[r * lay.block_m:(r + 1) * lay.block_m,
              c * lay.block_n:(c + 1) * lay.block_n] += b
        h = h @ W + np.asarray(lay.bias)
        if act is not None:
            h = np.asarray(act(h))
    np.testing.assert_allclose(np.asarray(plan(x)), h, rtol=1e-4, atol=1e-4)


def test_activation_sequence_length_validated(make_stack):
    layers = make_stack(sizes=(128, 256, 256, 128))
    with pytest.raises(ValueError, match="hidden layers"):
        Engine(backend="jnp", activation=[jax.nn.relu]).compile(layers)


def test_activations_equal_semantics():
    assert activations_equal(jax.nn.relu, jax.nn.relu)
    assert not activations_equal(jax.nn.relu, jax.nn.gelu)
    assert activations_equal(functools.partial(_leaky, 0.1),
                             functools.partial(_leaky, 0.1))
    assert not activations_equal(functools.partial(_leaky, 0.1),
                                 functools.partial(_leaky, 0.2))
    assert not activations_equal(functools.partial(_leaky, 0.1), _leaky)
    assert activations_equal(None, None)


def test_gate_in_plan_keys(make_stack, tmp_path):
    """Gated and ungated plans never alias — neither in the in-memory engine
    cache nor in the on-disk plan store key."""
    from repro.serving.plancache import plan_cache_key

    layers = make_stack()
    eng = Engine(backend="jnp", activation="relu")
    geng = Engine(backend="jnp", activation="relu", gate=True)
    p, gp = eng.compile(layers), geng.compile(layers)
    assert p is not gp and not p.gate and gp.gate
    from repro.core.blocksparse import to_block_ffnn
    net = to_block_ffnn(layers)
    assert plan_cache_key(eng, net) != plan_cache_key(geng, net)
