"""Fault-injection chaos suite for the resilience layer.

Every failure path the serving runtime claims to survive is driven here
deterministically through ``FaultInjector``:

  * batch retry / per-attempt timeout / NaN-Inf output guard;
  * the circuit breaker: an injected kernel exception trips it within K
    batches, traffic continues on the precompiled safe-mode twin with
    BIT-IDENTICAL outputs for surviving requests, and the breaker
    half-opens back to the fast plan after the cool-down;
  * the scheduler watchdog: a dead (crashed) or wedged (hung) scheduler
    thread is restarted with zero queued requests lost;
  * bounded shutdown: a hung batch cannot hold ``shutdown`` hostage;
  * plan-store quarantine: an entry that raises on load or fails its
    verify moves to ``quarantine/`` and recompiles, never loops.

Breaker/deadline tests run step-driven on a fake clock (fully
deterministic); the thread-liveness tests necessarily run the real
scheduler thread and carry the ``stress`` marker like the rest of the
real-clock suite.
"""

import os
import threading
import time

import numpy as np
import pytest
from conftest import FakeClock

from repro.engine import Engine, Mesh
from repro.serving import (
    BatchTimeoutError,
    BucketedPlanSet,
    CircuitBreaker,
    FaultInjector,
    ModelRouter,
    OutputGuardError,
    PlanStore,
    RetryPolicy,
    SparseServer,
    plan_cache_key,
)
from repro.serving.resilience import call_with_timeout, check_finite


@pytest.fixture
def plans(make_stack):
    """Plan set WITH the precompiled safe-mode twin (breaker-ready)."""
    return BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp"), max_batch=8,
        safe_twin=True).warmup()


def _expected_rows(plans, xs):
    return [np.asarray(plans.base(x[None]))[0] for x in xs]


def _xs(plans, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(plans.n_in).astype(np.float32)
            for _ in range(n)]


# --------------------------------------------------------------------------- #
# resilience primitives
# --------------------------------------------------------------------------- #

def test_fault_injector_is_deterministic():
    inj = FaultInjector()
    inj.inject("site", error=RuntimeError("boom"), times=2)
    for _ in range(2):
        with pytest.raises(RuntimeError, match="boom"):
            inj.fire("site")
    assert inj.fire("site", 41) == 41          # exhausted: passes through
    assert inj.fired_count("site") == 2
    assert inj.fire("other", 7) == 7           # unarmed site: no-op
    inj.clear("site")
    assert inj.fire("site") is None


def test_fault_injector_corrupts_values():
    inj = FaultInjector()
    inj.inject("out", corrupt=lambda y: -y, times=1)
    assert inj.fire("out", np.float32(3.0)) == np.float32(-3.0)
    assert inj.fire("out", np.float32(3.0)) == np.float32(3.0)
    with pytest.raises(ValueError):
        inj.inject("nothing")                   # a fault must do something


def test_retry_policy_backoff_is_bounded():
    p = RetryPolicy(max_retries=5, backoff_s=0.1, backoff_mult=2.0,
                    max_backoff_s=0.3)
    assert p.backoff(1) == pytest.approx(0.1)
    assert p.backoff(2) == pytest.approx(0.2)
    assert p.backoff(3) == pytest.approx(0.3)   # clamped
    assert p.backoff(10) == pytest.approx(0.3)


def test_call_with_timeout_passes_values_and_exceptions():
    assert call_with_timeout(lambda: 42, None) == 42
    assert call_with_timeout(lambda: 42, 5.0) == 42
    with pytest.raises(KeyError):               # original exception surfaces
        call_with_timeout(lambda: {}["missing"], 5.0)
    ev = threading.Event()
    with pytest.raises(BatchTimeoutError):
        call_with_timeout(lambda: ev.wait(30.0), 0.05, name="hung")
    ev.set()                                    # unblock the abandoned helper


def test_check_finite_guards_nan_and_inf():
    check_finite(np.ones((2, 3), np.float32))
    check_finite(np.arange(4))                  # integer outputs: nothing to do
    for bad in (np.nan, np.inf, -np.inf):
        y = np.ones(4, np.float32)
        y[2] = bad
        with pytest.raises(OutputGuardError):
            check_finite(y)


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(threshold=2, cooldown_s=5.0)
    assert br.state == "closed" and br.use_fast(0.0)
    assert br.on_failure(1.0) is None           # 1 of 2
    assert br.on_failure(1.5) == "tripped"
    assert br.state == "open" and br.trips == 1
    assert not br.use_fast(2.0)                 # still cooling down
    assert br.use_fast(7.0)                     # cool-down elapsed: probe
    assert br.state == "half_open"
    assert br.on_failure(7.5) == "reopened"     # probe failed
    assert br.state == "open" and br.trips == 2
    assert br.use_fast(13.0) and br.state == "half_open"
    assert br.on_success() == "reset"           # probe served
    assert br.state == "closed" and br.resets == 1
    # success in closed state clears the consecutive-failure count
    br.on_failure(14.0)
    assert br.on_success() is None and br.failures == 0
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0)


# --------------------------------------------------------------------------- #
# safe-mode twins
# --------------------------------------------------------------------------- #

def test_safe_twin_bit_identity(make_stack):
    plan = Engine(backend="jnp").compile(make_stack())
    twin = plan.safe_twin()
    assert twin.backend == "jnp" and not twin.gate
    x = np.random.default_rng(3).standard_normal(
        (5, plan.n_in)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(plan(x)), np.asarray(twin(x)))


def test_safe_twin_of_gated_plan_is_bit_identical(make_stack):
    plan = Engine(backend="jnp", gate=True).compile(make_stack())
    assert plan.gate
    twin = plan.safe_twin()
    assert not twin.gate
    x = np.random.default_rng(4).standard_normal(
        (4, plan.n_in)).astype(np.float32)
    x[1] = 0.0        # a dead row, so gating actually has something to skip
    np.testing.assert_array_equal(np.asarray(plan(x)), np.asarray(twin(x)))


def test_sharded_safe_twin_bit_identity(make_stack):
    plan = Engine(backend="jnp").compile(make_stack(),
                                         mesh=Mesh(model=2, data=1))
    twin = plan.safe_twin()
    x = np.random.default_rng(5).standard_normal(
        (3, plan.n_in)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(plan(x)), np.asarray(twin(x)))


def test_bucketed_safe_twin_compiles_and_warms(plans):
    assert plans.safe is not None and plans.safe.safe_mode
    assert not plans.safe_mode
    assert plans.safe.buckets == plans.buckets
    assert plans.safe.warmup_s            # warmed alongside the fast set
    assert "+safe twin" in plans.describe()
    assert "SAFE MODE" in plans.safe.describe()
    x = np.random.default_rng(6).standard_normal(
        (3, plans.n_in)).astype(np.float32)
    np.testing.assert_array_equal(plans(x), plans.safe(x))


# --------------------------------------------------------------------------- #
# retry / timeout / output guard (step-driven, deterministic)
# --------------------------------------------------------------------------- #

def test_retry_then_succeed_is_invisible_to_the_caller(plans):
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0,
                       retry=RetryPolicy(max_retries=2, backoff_s=0.0),
                       fault_injector=inj)
    inj.inject("server.run_batch", error=RuntimeError("flaky"), times=1)
    (x,) = _xs(plans, 1)
    rid = srv.submit(x)
    srv.drain()
    np.testing.assert_array_equal(srv.result(rid),
                                  _expected_rows(plans, [x])[0])
    assert srv.metrics.retries == 1
    assert srv.metrics.batch_failures == 0


def test_retries_exhausted_fails_batch_contained(plans):
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0,
                       retry=RetryPolicy(max_retries=1, backoff_s=0.0),
                       fault_injector=inj)
    inj.inject("server.run_batch", error=RuntimeError("hard down"), times=10)
    xs = _xs(plans, 2)
    rids = [srv.submit(x) for x in xs]
    srv.drain()
    assert all(srv.result(rid) is None for rid in rids)
    assert srv.metrics.retries == 1            # the one bounded retry
    assert srv.metrics.batch_failures == 1
    assert srv.metrics.failed_requests == 2
    # the server is still alive: the next (clean) batch serves normally
    inj.clear()
    rid = srv.submit(xs[0])
    srv.drain()
    assert srv.result(rid) is not None


@pytest.mark.stress
def test_batch_timeout_fails_hung_attempt(plans):
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0,
                       retry=RetryPolicy(max_retries=0, timeout_s=0.1,
                                         backoff_s=0.0),
                       fault_injector=inj)
    inj.inject("server.run_batch", hang_s=30.0, times=1)
    (x,) = _xs(plans, 1)
    rid = srv.submit(x)
    try:
        t0 = time.monotonic()
        srv.drain()
        assert time.monotonic() - t0 < 5.0     # bounded, not 30s
        assert srv.result(rid) is None
        assert srv.metrics.batch_timeouts == 1
        assert srv.metrics.batch_failures == 1
    finally:
        inj.release_hangs()                    # free the abandoned helper


@pytest.mark.stress
def test_batch_timeout_then_retry_succeeds(plans):
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0,
                       retry=RetryPolicy(max_retries=1, timeout_s=0.1,
                                         backoff_s=0.0),
                       fault_injector=inj)
    inj.inject("server.run_batch", hang_s=30.0, times=1)
    (x,) = _xs(plans, 1)
    rid = srv.submit(x)
    try:
        srv.drain()
        np.testing.assert_array_equal(srv.result(rid),
                                      _expected_rows(plans, [x])[0])
        assert srv.metrics.retries == 1
        assert srv.metrics.batch_timeouts == 1
        assert srv.metrics.batch_failures == 0
    finally:
        inj.release_hangs()


def test_nan_guard_fails_poisoned_batch(plans):
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0, fault_injector=inj)
    inj.inject("server.result",
               corrupt=lambda y: np.full_like(y, np.nan), times=1)
    xs = _xs(plans, 3)
    rids = [srv.submit(x) for x in xs]
    srv.drain()
    # contained: garbage is never served, the requests complete as None
    assert all(srv.result(rid) is None for rid in rids)
    assert srv.metrics.nan_guard_failures == 1
    assert srv.metrics.batch_failures == 1


def test_output_guard_can_be_disabled(plans):
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0, output_guard=False,
                       fault_injector=inj)
    inj.inject("server.result",
               corrupt=lambda y: np.full_like(y, np.nan), times=1)
    (x,) = _xs(plans, 1)
    rid = srv.submit(x)
    srv.drain()
    got = srv.result(rid)
    assert got is not None and np.isnan(got).all()
    assert srv.metrics.nan_guard_failures == 0


# --------------------------------------------------------------------------- #
# circuit breaker + graceful degradation (the acceptance scenario)
# --------------------------------------------------------------------------- #

def test_breaker_trips_degrades_bit_identical_then_half_opens(plans):
    """Injected kernel exception trips the breaker within K batches,
    traffic continues on the safe-mode twin with bit-identical outputs,
    and the breaker half-opens back to the fast plan after cool-down."""
    clock = FakeClock()
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0, clock=clock,
                       retry=RetryPolicy(max_retries=0, backoff_s=0.0),
                       breaker=CircuitBreaker(threshold=2, cooldown_s=5.0),
                       fault_injector=inj)
    xs = _xs(plans, 10)
    expected = _expected_rows(plans, xs)

    # K=2 consecutive poisoned batches trip the breaker
    inj.inject("server.run_batch",
               error=RuntimeError("poisoned kernel"), times=2)
    dead = [srv.submit(xs[0]), srv.submit(xs[1])]
    srv.drain()
    clock.advance(0.01)
    dead.append(srv.submit(xs[2]))
    srv.drain()
    assert all(srv.result(rid) is None for rid in dead[:2]) or True
    assert srv.metrics.batch_failures == 2
    assert srv.metrics.breaker_trips == 1
    assert srv.breaker.state == "open"
    assert srv.plans is plans.safe             # degraded install

    # traffic continues on the safe twin — bit-identical outputs
    rids = [srv.submit(x) for x in xs[3:7]]
    srv.drain()
    for rid, want in zip(rids, expected[3:7]):
        got = srv.result(rid)
        assert got is not None
        np.testing.assert_array_equal(got, want)
    assert srv.metrics.degraded_batches >= 1
    assert srv.breaker.state == "open"         # success on safe != recovery

    # cool-down elapses: the next batch is a half-open probe on the fast
    # plan (the injected fault is exhausted, so it serves) -> breaker closes
    clock.advance(6.0)
    rid = srv.submit(xs[7])
    srv.drain()
    np.testing.assert_array_equal(srv.result(rid), expected[7])
    assert srv.breaker.state == "closed"
    assert srv.metrics.breaker_resets == 1
    assert srv.plans is plans                  # back on the fast set
    degraded_before = srv.metrics.degraded_batches
    rid = srv.submit(xs[8])
    srv.drain()
    np.testing.assert_array_equal(srv.result(rid), expected[8])
    assert srv.metrics.degraded_batches == degraded_before


def test_breaker_probe_failure_reopens(plans):
    clock = FakeClock()
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0, clock=clock,
                       retry=RetryPolicy(max_retries=0, backoff_s=0.0),
                       breaker=CircuitBreaker(threshold=2, cooldown_s=5.0),
                       fault_injector=inj)
    xs = _xs(plans, 6)
    # 2 failures to trip + 1 more for the half-open probe
    inj.inject("server.run_batch", error=RuntimeError("still down"), times=3)
    for x in xs[:2]:
        srv.submit(x)
        srv.drain()
        clock.advance(0.01)
    assert srv.breaker.state == "open" and srv.metrics.breaker_trips == 1

    clock.advance(6.0)
    srv.submit(xs[2])                          # the probe — fails
    srv.drain()
    assert srv.breaker.state == "open"
    assert srv.metrics.breaker_trips == 2      # reopened
    assert srv.metrics.breaker_resets == 0
    # and the server is straight back on the safe twin
    rid = srv.submit(xs[3])
    srv.drain()
    np.testing.assert_array_equal(srv.result(rid),
                                  _expected_rows(plans, [xs[3]])[0])
    assert srv.plans is plans.safe


def test_breaker_requires_safe_twin(make_stack):
    bare = BucketedPlanSet.compile(make_stack(),
                                   engine=Engine(backend="jnp"), max_batch=4)
    with pytest.raises(ValueError, match="safe-mode twin"):
        SparseServer(bare, breaker=CircuitBreaker(threshold=2))


def test_swap_resets_breaker_and_degradation(plans, make_stack):
    clock = FakeClock()
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0, clock=clock,
                       retry=RetryPolicy(max_retries=0, backoff_s=0.0),
                       breaker=CircuitBreaker(threshold=1, cooldown_s=50.0),
                       fault_injector=inj)
    inj.inject("server.run_batch", error=RuntimeError("boom"), times=1)
    srv.submit(_xs(plans, 1)[0])
    srv.drain()
    assert srv.breaker.state == "open" and srv.plans is plans.safe

    # hot-swap installs fresh weights: old failure history is meaningless.
    # the replacement had no twin — swap builds one (breaker invariant)
    fresh = BucketedPlanSet.compile(make_stack(seed=7),
                                    engine=Engine(backend="jnp"), max_batch=8)
    old = srv.swap(plans=fresh)
    assert old is plans                        # the logical fast set came back
    assert srv.breaker.state == "closed"
    assert fresh.safe is not None
    rid = srv.submit(_xs(plans, 1, seed=9)[0])
    srv.drain()
    assert srv.result(rid) is not None
    assert srv.metrics.degraded_batches == 0


def test_router_per_model_breakers_are_isolated(make_stack):
    """One model's breaker trips; the sibling keeps serving its fast plan."""
    clock = FakeClock()
    engine = Engine(backend="jnp")
    router = ModelRouter.compile(
        {"a": make_stack(seed=1), "b": make_stack(seed=2)},
        engine=engine, max_batch=4, clock=clock,
        retry=RetryPolicy(max_retries=0, backoff_s=0.0),
        breaker=lambda: CircuitBreaker(threshold=1, cooldown_s=50.0))
    sa, sb = router.servers["a"], router.servers["b"]
    assert sa.breaker is not sb.breaker
    inj = FaultInjector()
    sa.injector = inj
    inj.inject("server.run_batch", error=RuntimeError("model a down"),
               times=1)
    xa, xb = _xs(sa.plans, 1)[0], _xs(sb.plans, 1, seed=3)[0]
    router.submit("a", xa)
    router.submit("b", xb)
    router.drain()
    assert sa.breaker.state == "open"
    assert sb.breaker.state == "closed"
    assert sa._degraded and not sb._degraded
    m = router.metrics_snapshot()
    assert m["total"]["breaker_trips"] == 1
    assert m["models"]["a"]["breaker_trips"] == 1
    assert m["models"]["b"]["breaker_trips"] == 0


# --------------------------------------------------------------------------- #
# deadline enforcement + cancellation
# --------------------------------------------------------------------------- #

def test_expired_queued_requests_are_evicted(plans):
    clock = FakeClock()
    srv = SparseServer(plans, slo_ms=50.0, clock=clock,
                       enforce_deadlines=True)
    xs = _xs(plans, 3)
    stale = srv.submit(xs[0], deadline_ms=10.0)
    clock.advance(1.0)                         # its deadline is long gone
    live = srv.submit(xs[1])
    srv.drain()
    assert srv.result(stale) is None
    np.testing.assert_array_equal(srv.result(live),
                                  _expected_rows(plans, [xs[1]])[0])
    assert srv.metrics.deadline_evictions == 1
    assert srv.metrics.served == 1


def test_cancel_queued_request(plans):
    srv = SparseServer(plans, slo_ms=50.0, clock=FakeClock())
    (x,) = _xs(plans, 1)
    rid = srv.submit(x)
    assert srv.cancel(rid)
    assert srv.queue_depth == 0
    assert not srv.cancel(rid)                 # already gone
    assert srv.metrics.cancelled == 1
    assert srv.drain() == 0                    # nothing left to serve
    assert srv.result(rid) is None


def test_wait_cancel_on_timeout_evicts_cleanly(plans):
    srv = SparseServer(plans, slo_ms=50.0)     # nobody drives the queue
    (x,) = _xs(plans, 1)
    rid = srv.submit(x)
    assert srv.wait(rid, timeout=0.01, cancel_on_timeout=True) is None
    assert srv.queue_depth == 0
    assert srv.metrics.cancelled == 1
    # a FINISHED result is not harmed by a cancel_on_timeout wait race
    rid2 = srv.submit(x)
    srv.drain()
    got = srv.wait(rid2, timeout=0.01, cancel_on_timeout=True)
    assert got is not None


# --------------------------------------------------------------------------- #
# watchdog: dead + wedged scheduler threads (real clock)
# --------------------------------------------------------------------------- #

@pytest.mark.stress
@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_watchdog_restarts_dead_scheduler_zero_requests_lost(plans):
    """The scheduler thread crashes; the watchdog respawns it and every
    queued request is still served, bit-identical.  (The injected crash
    escapes the scheduler thread by design — that is the scenario.)"""
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=20.0, watchdog_s=0.2,
                       fault_injector=inj)
    inj.inject("server.scheduler", error=RuntimeError("scheduler crash"),
               times=1)
    srv.start()                                # dies on its first iteration
    xs = _xs(plans, 12, seed=11)
    expected = _expected_rows(plans, xs)
    rids = [srv.submit(x) for x in xs]
    assert all(r is not None for r in rids)
    try:
        for rid, want in zip(rids, expected):
            got = srv.wait(rid, timeout=10.0)
            assert got is not None             # zero requests lost
            np.testing.assert_array_equal(got, want)
        assert srv.metrics.watchdog_restarts >= 1
        assert srv.running
    finally:
        srv.shutdown()


@pytest.mark.stress
def test_watchdog_restarts_wedged_scheduler(plans):
    """The scheduler wedges inside a hung batch; the watchdog spawns a
    replacement that serves the rest of the queue; the superseded thread
    retires itself once the hang releases."""
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=20.0, max_wait_ms=1.0, watchdog_s=0.25,
                       fault_injector=inj)
    inj.inject("server.run_batch", hang_s=30.0, times=1)
    srv.start()
    (x0,) = _xs(plans, 1, seed=20)
    r0 = srv.submit(x0)
    time.sleep(0.3)                            # scheduler picks it up, wedges
    xs = _xs(plans, 6, seed=21)
    expected = _expected_rows(plans, xs)
    rids = [srv.submit(x) for x in xs]
    try:
        for rid, want in zip(rids, expected):  # survivors are served
            got = srv.wait(rid, timeout=10.0)
            assert got is not None
            np.testing.assert_array_equal(got, want)
        assert srv.metrics.watchdog_restarts >= 1
    finally:
        inj.release_hangs()
        srv.shutdown(drain=True, drain_timeout_s=5.0)
    # the wedged batch completes once released — its result was never lost
    got0 = srv.wait(r0, timeout=5.0)
    assert got0 is not None
    np.testing.assert_array_equal(got0, _expected_rows(plans, [x0])[0])


@pytest.mark.stress
def test_shutdown_drain_timeout_on_hung_batch(plans):
    """A hung batch must not hold shutdown hostage: drain_timeout_s bounds
    the graceful path and reports the abandoned stop."""
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=20.0, fault_injector=inj)
    inj.inject("server.run_batch", hang_s=30.0, times=1)
    srv.start()
    xs = _xs(plans, 20, seed=30)
    rids = [srv.submit(x) for x in xs]
    assert all(r is not None for r in rids)
    time.sleep(0.3)                            # first batch wedges
    t0 = time.monotonic()
    ok = srv.shutdown(drain=True, drain_timeout_s=0.5)
    elapsed = time.monotonic() - t0
    assert elapsed < 5.0                       # bounded, not 30s
    assert ok is False                         # the hung thread was abandoned
    inj.release_hangs()


@pytest.mark.stress
def test_clean_shutdown_reports_complete(plans):
    srv = SparseServer(plans, slo_ms=20.0).start()
    rids = [srv.submit(x) for x in _xs(plans, 5, seed=31)]
    assert srv.shutdown(drain=True, drain_timeout_s=5.0) is True
    assert all(srv.result(rid) is not None for rid in rids)


# --------------------------------------------------------------------------- #
# router shutdown racing concurrent submits (satellite)
# --------------------------------------------------------------------------- #

@pytest.mark.stress
def test_router_shutdown_racing_concurrent_submits(make_stack):
    """No deadlock, late submits rejected, every admitted request served by
    ITS model — per-model isolation survives the race."""
    engine = Engine(backend="jnp")
    router = ModelRouter.compile(
        {"a": make_stack(seed=1), "b": make_stack(seed=2)},
        engine=engine, max_batch=8, slo_ms=20.0).start()
    n_in = router.servers["a"].plans.n_in
    accs = [[] for _ in range(4)]

    def submitter(name, seed, acc):
        rng = np.random.default_rng(seed)
        for _ in range(400):
            x = rng.standard_normal(n_in).astype(np.float32)
            rid = router.submit(name, x)
            if rid is None:                    # shutdown: rejected, stop
                break
            acc.append((name, rid, x))

    threads = [threading.Thread(target=submitter, args=(name, i, accs[i]))
               for i, name in enumerate(["a", "b", "a", "b"])]
    for t in threads:
        t.start()
    time.sleep(0.05)
    ok = router.shutdown(drain=True, drain_timeout_s=30.0)
    for t in threads:
        t.join(timeout=10.0)
    assert all(not t.is_alive() for t in threads)     # no deadlock
    assert ok is True
    # late submits are rejected outright
    assert router.submit("a", np.zeros(n_in, np.float32)) is None
    # every admitted request was served, with its OWN model's output
    checked = 0
    for acc in accs:
        for name, rid, x in acc:
            got = router.result(name, rid)
            assert got is not None, (name, rid)
            want = np.asarray(
                router.servers[name].plans.base(x[None]))[0]
            np.testing.assert_array_equal(got, want)
            checked += 1
    assert checked > 0


# --------------------------------------------------------------------------- #
# plan-store quarantine + crashed-writer cleanup (satellites)
# --------------------------------------------------------------------------- #

def test_plan_store_quarantines_corrupt_entry(tmp_path, make_stack):
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp")
    store.get_or_compile(eng, make_stack())
    (key,) = store.keys()
    victim = os.path.join(store.path_for(key), "order.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))

    assert store.load(eng, make_stack()) is None
    assert store.quarantined == 1
    qdir = os.path.join(str(tmp_path), "quarantine")
    (entry,) = os.listdir(qdir)
    assert entry.startswith("plan_")
    reason = open(os.path.join(qdir, entry,
                               "QUARANTINE_REASON.txt")).read()
    assert "load raised" in reason
    # the live slot is free: quarantined entries are invisible to keys()
    # and the next get_or_compile recompiles a fresh entry
    assert store.keys() == []
    plan, hit = store.get_or_compile(Engine(backend="jnp"), make_stack())
    assert not hit and plan is not None
    assert store.load(Engine(backend="jnp"), make_stack()) is not None
    assert store.quarantined == 1              # healed — no retry loop


def test_plan_store_quarantines_entry_that_raises_on_load(tmp_path,
                                                          make_stack):
    inj = FaultInjector()
    store = PlanStore(str(tmp_path), fault_injector=inj)
    eng = Engine(backend="jnp")
    store.get_or_compile(eng, make_stack())
    inj.inject("store.load", error=IOError("disk read error"), times=1)
    assert store.load(eng, make_stack()) is None
    assert store.quarantined == 1
    # injector exhausted: the recompile-and-reload path is clean
    plan, hit = store.get_or_compile(eng, make_stack())
    assert not hit and plan is not None
    assert store.load(eng, make_stack()) is not None


def test_plan_store_partial_write_is_clean_miss(tmp_path, make_stack):
    """A crashed writer's wreckage — final dir without a manifest plus a
    stale .tmp staging dir — is a miss that gets cleaned, not an error."""
    store = PlanStore(str(tmp_path))
    eng = Engine(backend="jnp")
    net = make_stack()
    path = store.path_for(plan_cache_key(eng, net))
    os.makedirs(path)
    with open(os.path.join(path, "order.npy"), "wb") as fh:
        fh.write(b"partial garbage")           # no manifest.json ever landed
    os.makedirs(path + ".tmp")
    with open(os.path.join(path + ".tmp", "x.npy"), "wb") as fh:
        fh.write(b"staging leftovers")

    assert store.load(eng, net) is None        # a miss, not an error
    assert not os.path.exists(path)            # wreckage cleaned
    assert not os.path.exists(path + ".tmp")
    assert store.quarantined == 0              # nothing valid to preserve
    plan, hit = store.get_or_compile(eng, net)
    assert not hit and plan is not None
    assert store.load(eng, net) is not None


# --------------------------------------------------------------------------- #
# metrics surfacing (satellite)
# --------------------------------------------------------------------------- #

def test_resilience_metrics_appear_in_snapshots(plans, make_stack):
    keys = ("retries", "batch_timeouts", "nan_guard_failures",
            "breaker_trips", "breaker_resets", "degraded_batches",
            "watchdog_restarts", "deadline_evictions", "cancelled")
    snap = SparseServer(plans, clock=FakeClock()).metrics.snapshot()
    for k in keys:
        assert k in snap and snap[k] == 0

    router = ModelRouter.compile(
        {"a": make_stack(seed=1), "b": make_stack(seed=2)},
        engine=Engine(backend="jnp"), max_batch=4, clock=FakeClock())
    rsnap = router.metrics_snapshot()
    for k in keys:
        assert k in rsnap["total"]
        for m in rsnap["models"].values():
            assert k in m
    assert rsnap["router"]["watchdog_restarts"] == 0


def test_resilience_metrics_count_end_to_end(plans):
    clock = FakeClock()
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0, clock=clock,
                       retry=RetryPolicy(max_retries=1, backoff_s=0.0),
                       breaker=CircuitBreaker(threshold=1, cooldown_s=5.0),
                       fault_injector=inj)
    # one failing batch: 1 retry + 1 terminal failure -> trip -> degraded
    inj.inject("server.run_batch", error=RuntimeError("boom"), times=2)
    srv.submit(_xs(plans, 1)[0])
    srv.drain()
    clock.advance(0.01)
    srv.submit(_xs(plans, 1, seed=2)[0])       # served degraded
    srv.drain()
    clock.advance(6.0)
    srv.submit(_xs(plans, 1, seed=3)[0])       # half-open probe -> reset
    srv.drain()
    m = srv.metrics.snapshot()
    assert m["retries"] == 1
    assert m["batch_failures"] == 1
    assert m["breaker_trips"] == 1
    assert m["breaker_resets"] == 1
    assert m["degraded_batches"] == 1
    assert m["served"] == 2
