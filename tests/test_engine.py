"""Fused inference engine tests.

Two families, matching the engine's two contracts:

  * parity — ``Engine.compile(net)(x)`` equals the dense layer-by-layer
    reference (``kernels/ref.py``) within 1e-5, across batch sizes, block
    sizes, activations, depths 1-4, and both CPU backends;
  * I/O invariants — every compiled plan's simulated tile traffic sits inside
    the Theorem-1 window (``S <= writes <= N - I``,
    ``total <= 2 (W + N - I)``) and its per-layer schedules are
    contiguous-by-output (the 2-optimal family the kernel requires).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import theorem1_bounds
from repro.core.blocksparse import is_contiguous_by_output
from repro.core.graph import drop_isolated
from repro.core.iosim import simulate
from repro.engine import Engine, resolve_backend
from repro.kernels.ops import bsr_layer_ref

# CPU-runnable backends; "pallas" (compiled) needs a TPU host.
CPU_BACKENDS = ("jnp", "interpret")


def _oracle(layers, x, activation, final_activation=None):
    h = x
    for k, lay in enumerate(layers):
        act = activation if k < len(layers) - 1 else final_activation
        h = bsr_layer_ref(h, lay, activation=act)
    return h


def _max_err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


# --------------------------------------------------------------------------- #
# parity vs the dense reference
# --------------------------------------------------------------------------- #

PARITY_CASES = [
    # (sizes, block, density, batch, activation)
    ((128, 128), 32, 0.5, 1, "relu"),                 # 1 layer, batch 1
    ((128, 256, 128), 32, 0.4, 8, "relu"),            # 2 layers
    ((128, 256, 128), 64, 0.3, 3, "gelu"),            # odd batch, gelu
    ((192, 192, 192, 192), 32, 0.25, 16, "silu"),     # 3 layers
    ((128, 192, 256, 192, 128), 64, 0.35, 4, "tanh"), # 4 layers, mixed dims
    ((256, 128), 128, 1.0, 8, None),                  # dense blocks, linear
]


@pytest.mark.parametrize("backend", CPU_BACKENDS)
@pytest.mark.parametrize("sizes,block,density,batch,activation", PARITY_CASES)
def test_engine_matches_dense_reference(make_stack, sizes, block, density,
                                        batch, activation, backend):
    layers = make_stack(sizes=sizes, density=density, block=block,
                        seed=hash((sizes, block)) % 2**31)
    plan = Engine(backend=backend, activation=activation).compile(layers)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((batch, sizes[0])), jnp.float32)
    y = plan(x)
    act = None if activation is None else getattr(jax.nn, activation, jnp.tanh)
    yr = _oracle(layers, x, act)
    assert y.shape == yr.shape and y.dtype == x.dtype
    assert _max_err(y, yr) < 1e-5


@pytest.mark.parametrize("backend", CPU_BACKENDS)
def test_engine_with_reordering_matches_reference(make_stack, backend):
    layers = make_stack(sizes=(128, 256, 128), density=0.4)
    plan = Engine(backend=backend, reorder=True,
                  reorder_iters=150).compile(layers)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)
    assert _max_err(plan(x), _oracle(layers, x, jax.nn.relu)) < 1e-5


def test_backends_agree(make_stack):
    layers = make_stack(sizes=(128, 192, 128), density=0.3)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    ys = [Engine(backend=b, activation="gelu").compile(layers)(x)
          for b in CPU_BACKENDS]
    assert _max_err(ys[0], ys[1]) < 1e-5


def test_engine_bf16_inputs(make_stack):
    layers = make_stack(sizes=(128, 256, 128), density=0.4)
    plan = Engine(backend="jnp").compile(layers)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((8, 128)), jnp.bfloat16)
    y = plan(x)
    assert y.dtype == jnp.bfloat16
    err = _max_err(y, _oracle(layers, x, jax.nn.relu))
    assert err < 3e-2  # bf16 output rounding


# --------------------------------------------------------------------------- #
# batched input handling + API contract
# --------------------------------------------------------------------------- #

def test_single_vector_and_batched_inputs_agree(make_stack):
    layers = make_stack()
    plan = Engine(backend="jnp").compile(layers)
    rng = np.random.default_rng(5)
    xb = rng.standard_normal((4, 128)).astype(np.float32)
    yb = plan(xb)
    y0 = plan(xb[0])  # 1-D input: engine adds/removes the batch dim
    assert y0.shape == (layers[-1].n_out,)
    assert _max_err(y0, yb[0]) < 1e-6


def test_bad_input_shape_raises(make_stack):
    plan = Engine(backend="jnp").compile(make_stack())
    with pytest.raises(ValueError, match="expected input"):
        plan(jnp.zeros((4, 64)))
    with pytest.raises(ValueError, match="expected input"):
        plan(jnp.zeros((2, 4, 128)))


def test_compile_once_run_many_cache(make_stack):
    layers = make_stack()
    engine = Engine(backend="jnp")
    plan = engine.compile(layers)
    assert engine.compile(layers) is plan            # cached
    # keyed on layer identity: the plan's own DAG wrapper hits the same entry
    assert engine.compile(plan.block_ffnn) is plan
    other = engine.compile(layers, backend="interpret")
    assert other is not plan and other.backend == "interpret"
    x = jnp.zeros((2, 128), jnp.float32)
    calls0 = plan.calls
    plan(x); plan(x)
    assert plan.calls == calls0 + 2


def test_unknown_backend_and_activation_raise(make_stack):
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda")
    with pytest.raises(ValueError, match="unknown activation"):
        Engine(backend="jnp", activation="swish9").compile(make_stack())


# --------------------------------------------------------------------------- #
# I/O invariants: every plan sits inside the Theorem-1 window
# --------------------------------------------------------------------------- #

IO_CASES = [
    ((128, 256, 128), 32, 0.4, False),
    ((128, 256, 128), 32, 0.4, True),
    ((192, 192, 192, 192), 32, 0.2, True),
    ((128, 128), 64, 0.6, False),
    ((128, 192, 256, 192, 128), 64, 0.35, True),
]


@pytest.mark.parametrize("sizes,block,density,reorder", IO_CASES)
def test_plan_io_satisfies_theorem1(make_stack, sizes, block, density, reorder):
    layers = make_stack(sizes=sizes, density=density, block=block)
    plan = Engine(backend="jnp", reorder=reorder,
                  reorder_iters=150).compile(layers)
    io = plan.io
    b = io.bounds
    # S <= writes <= N - I
    assert b.writes_lo <= io.simulated.writes <= b.writes_hi
    # total <= 2 (W + N - I)
    assert io.simulated.total <= b.total_hi
    assert io.within_bounds
    # the report is the exact simulator on the connected block DAG
    net = drop_isolated(plan.block_ffnn.net)
    assert io.simulated == simulate(net, plan.order, 3, "min")
    assert b == theorem1_bounds(net)


@pytest.mark.parametrize("reorder", [False, True])
def test_plan_schedules_contiguous_by_output(make_stack, reorder):
    layers = make_stack(sizes=(128, 256, 128), density=0.4)
    plan = Engine(backend="jnp", reorder=reorder,
                  reorder_iters=150).compile(layers)
    # whole-DAG order must stay a topological connection order
    assert plan.block_ffnn.net.is_topological_connection_order(plan.order)
    for sch in plan.schedules:
        assert is_contiguous_by_output(np.asarray(sch.cols))
        # first/last flags mark exactly one contiguous run per output tile
        cols = np.asarray(sch.cols)
        first = np.asarray(sch.first)
        last = np.asarray(sch.last)
        assert first.sum() == last.sum() == len(set(cols.tolist()))
    # every output tile is produced exactly once across the last layer
    assert set(np.asarray(plan.schedules[-1].cols).tolist()) == \
        set(range(layers[-1].grid_out))


def test_io_report_summary_strings(make_stack):
    plan = Engine(backend="jnp").compile(make_stack())
    s = plan.describe()
    assert "ExecutionPlan[jnp/fused]" in s and "tile I/O" in s
    assert plan.io.optimality_ratio >= 1.0
    assert Engine(backend="jnp", fuse=False).compile(make_stack()) \
        .describe().count("layered")
