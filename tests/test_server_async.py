"""The serving-loop race surface: async scheduler, hot-swap, multi-model.

Three properties the async runtime must not lose over the step-driven path:

  * **no request is dropped, duplicated, or corrupted** under concurrent
    submits — every rid resolves to exactly the row the base plan computes
    for its input, bit-for-bit (the bucket router is output-transparent,
    so batch composition cannot show through);
  * **swap is atomic** — a weight update installs between batches: outputs
    before/after a swap of identical weights are bit-identical, swapped-in
    new weights take effect on the next batch, and under concurrent
    traffic every result matches exactly one of the two weight sets
    (never a mix);
  * **models never cross** — a router result always comes from the model
    the request was submitted to.

The stress tests run the real scheduler thread against the real clock;
everything else stays deterministic (step-driven, fake clock).
"""

import threading

import numpy as np
import pytest
from conftest import FakeClock

from repro.engine import Engine
from repro.serving import BucketedPlanSet, ModelRouter, SparseServer


@pytest.fixture
def plans(make_stack):
    return BucketedPlanSet.compile(
        make_stack(), engine=Engine(backend="jnp"), max_batch=8).warmup()


def _expected_rows(plans, xs):
    """Ground truth per request: the base plan on each row alone (the
    bucket router is output-transparent, so any batching must match)."""
    return [np.asarray(plans.base(x[None]))[0] for x in xs]


# --------------------------------------------------------------------------- #
# async scheduler
# --------------------------------------------------------------------------- #

def test_async_start_shutdown_idempotent(plans):
    server = SparseServer(plans, slo_ms=20.0)
    server.start()
    assert server.running
    server.start()                     # idempotent
    server.shutdown()
    assert not server.running
    # post-shutdown submits are rejected, not queued forever
    assert server.submit(np.zeros(plans.n_in, np.float32)) is None
    assert server.metrics.rejected == 1


def test_async_serves_all_and_drains_on_shutdown(plans):
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(plans.n_in).astype(np.float32)
          for _ in range(37)]
    server = SparseServer(plans, slo_ms=20.0).start()
    rids = [server.submit(x) for x in xs]
    assert all(r is not None for r in rids)
    server.shutdown()                  # drains everything still queued
    expected = _expected_rows(plans, xs)
    for rid, want in zip(rids, expected):
        got = server.result(rid)
        assert got is not None
        np.testing.assert_array_equal(got, want)
    assert server.metrics.served == len(xs)
    assert server.queue_depth == 0


def test_async_wait_blocks_until_result(plans):
    rng = np.random.default_rng(1)
    x = rng.standard_normal(plans.n_in).astype(np.float32)
    server = SparseServer(plans, slo_ms=10.0).start()
    try:
        rid = server.submit(x)
        got = server.wait(rid, timeout=10.0)
        assert got is not None
        np.testing.assert_array_equal(got, _expected_rows(plans, [x])[0])
        assert server.wait(rid, timeout=0.01) is None   # already collected
    finally:
        server.shutdown()


@pytest.mark.stress
def test_async_concurrent_submit_stress(plans):
    """>= 4 submitter threads against the live scheduler: zero lost,
    duplicated, or corrupted results."""
    n_threads, per_thread = 6, 40
    rng = np.random.default_rng(2)
    xs = [[rng.standard_normal(plans.n_in).astype(np.float32)
           for _ in range(per_thread)] for _ in range(n_threads)]
    server = SparseServer(plans, slo_ms=30.0, max_queue=4096,
                          result_capacity=n_threads * per_thread).start()
    collected = [[] for _ in range(n_threads)]

    def client(i):
        rids = [server.submit(x) for x in xs[i]]
        for rid in rids:
            collected[i].append((rid, server.wait(rid, timeout=30.0)))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    server.shutdown()

    all_rids = [rid for per in collected for rid, _ in per]
    assert len(all_rids) == len(set(all_rids)), "duplicated rids"
    assert len(all_rids) == n_threads * per_thread, "lost submits"
    for i in range(n_threads):
        expected = _expected_rows(plans, xs[i])
        for (rid, got), want in zip(collected[i], expected):
            assert got is not None, f"request {rid} lost its result"
            np.testing.assert_array_equal(got, want)
    assert server.metrics.served == n_threads * per_thread


def test_step_driven_parity_with_async(plans):
    """The async path must serve byte-identical outputs to the
    deterministic step-driven path on the same inputs."""
    rng = np.random.default_rng(3)
    xs = [rng.standard_normal(plans.n_in).astype(np.float32)
          for _ in range(23)]

    step_server = SparseServer(plans, slo_ms=50.0)
    step_rids = [step_server.submit(x) for x in xs]
    step_server.drain()
    step_out = [step_server.result(r) for r in step_rids]

    async_server = SparseServer(plans, slo_ms=50.0).start()
    async_rids = [async_server.submit(x) for x in xs]
    async_server.shutdown()
    async_out = [async_server.result(r) for r in async_rids]

    for a, s in zip(async_out, step_out):
        assert a is not None and s is not None
        np.testing.assert_array_equal(a, s)


def test_submit_rejects_wrong_shape_in_caller_thread(plans):
    """A malformed input raises at submit() — in the submitting thread —
    and can never reach batch formation, where it would poison its whole
    batch (and, async, kill the scheduler thread)."""
    server = SparseServer(plans, clock=FakeClock())
    with pytest.raises(ValueError, match="expected input"):
        server.submit(np.zeros(plans.n_in + 1, np.float32))
    with pytest.raises(ValueError, match="expected input"):
        server.submit(np.zeros((1, plans.n_in), np.float32))
    assert server.queue_depth == 0


def test_failed_batch_does_not_kill_serving(plans):
    """If plan execution itself raises, the batch's requests complete as
    None (waiters unblock), the failure is counted, and the server keeps
    serving subsequent batches."""

    class Boom:
        def __init__(self, inner):
            self._inner = inner
            self.fuses = 1                      # first call raises

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def __call__(self, x):
            if self.fuses:
                self.fuses -= 1
                raise RuntimeError("injected batch failure")
            return self._inner(x)

    server = SparseServer(plans, clock=FakeClock())
    server.plans = Boom(plans)
    bad = server.submit(np.zeros(plans.n_in, np.float32))
    server.drain()                              # failing batch is contained
    assert server.result(bad) is None
    assert server.metrics.batch_failures == 1
    assert server.metrics.failed_requests == 1
    ok = server.submit(np.ones(plans.n_in, np.float32))
    server.drain()                              # next batch serves normally
    assert server.result(ok) is not None
    assert server.metrics.served == 1


def test_active_waiter_exempt_from_capacity_eviction(plans):
    """A thread already blocked in wait(rid) must receive its served
    result even when capacity eviction fires in the same batch."""
    server = SparseServer(plans, max_batch=8, slo_ms=1e6, max_wait_ms=1e6,
                          result_capacity=0)
    rid0 = server.submit(np.ones(plans.n_in, np.float32))
    rid1 = server.submit(np.zeros(plans.n_in, np.float32))
    got = {}

    def waiter():
        got["y"] = server.wait(rid0, timeout=10.0)

    t = threading.Thread(target=waiter)
    t.start()
    while server._results[rid0].waiters == 0:   # waiter registered
        pass
    server.drain()
    t.join(timeout=15.0)
    assert got["y"] is not None                 # waited-on result survived
    assert server.result(rid1) is None          # unclaimed one was evicted
    assert server.metrics.results_evicted == 1


def test_shutdown_without_drain_abandons_backlog(plans):
    server = SparseServer(plans, slo_ms=1e6, max_wait_ms=1e6).start()
    rids = [server.submit(np.zeros(plans.n_in, np.float32))
            for _ in range(3)]
    server.shutdown(drain=False)
    assert not server.running
    # backlog abandoned: nothing more is served, waiters just time out
    assert server.metrics.served + server.queue_depth == 3
    if server.queue_depth:
        assert server.wait(rids[-1], timeout=0.05) is None


# --------------------------------------------------------------------------- #
# plan hot-swap
# --------------------------------------------------------------------------- #

def test_swap_identical_weights_bit_identity(plans, make_stack):
    """Swapping in a plan compiled from the SAME weights must not change a
    single bit of any output."""
    engine = Engine(backend="jnp")
    server = SparseServer(plans, slo_ms=50.0, engine=engine)
    rng = np.random.default_rng(4)
    xs = [rng.standard_normal(plans.n_in).astype(np.float32)
          for _ in range(5)]

    old = server.swap(make_stack())    # same seed => identical weights
    assert old is plans
    assert server.metrics.swaps == 1

    rids = [server.submit(x) for x in xs]
    server.drain()
    after = [server.result(r) for r in rids]
    for b, a in zip(_expected_rows(plans, xs), after):
        np.testing.assert_array_equal(b, a)


def test_swap_new_weights_take_effect_next_batch(plans, make_stack):
    engine = Engine(backend="jnp")
    server = SparseServer(plans, slo_ms=50.0, engine=engine)
    new_net = make_stack(seed=99)      # genuinely different weights
    new_plans = BucketedPlanSet.compile(new_net, engine=engine, max_batch=8)

    rng = np.random.default_rng(5)
    x = rng.standard_normal(plans.n_in).astype(np.float32)
    server.swap(new_net)
    rid = server.submit(x)
    server.drain()
    got = server.result(rid)
    want_new = np.asarray(new_plans.base(x[None]))[0]
    want_old = _expected_rows(plans, [x])[0]
    np.testing.assert_array_equal(got, want_new)
    assert not np.array_equal(got, want_old)


def test_swap_queued_requests_not_dropped(plans, make_stack):
    """Requests queued across a swap are all served (by the new plans)."""
    server = SparseServer(plans, slo_ms=1e6, max_wait_ms=1e6,
                          clock=FakeClock(), engine=Engine(backend="jnp"))
    rng = np.random.default_rng(6)
    xs = [rng.standard_normal(plans.n_in).astype(np.float32)
          for _ in range(5)]
    rids = [server.submit(x) for x in xs]
    assert server.queue_depth == 5
    server.swap(make_stack(seed=99))
    assert server.queue_depth == 5     # nothing dropped by the swap
    server.drain()
    assert all(server.result(r) is not None for r in rids)


def test_swap_rejects_shape_change(plans, make_stack):
    server = SparseServer(plans, engine=Engine(backend="jnp"))
    with pytest.raises(ValueError, match="shape"):
        server.swap(make_stack(sizes=(64, 64)))
    with pytest.raises(ValueError, match="exactly one"):
        server.swap()
    with pytest.raises(ValueError, match="engine"):
        SparseServer(plans).swap(make_stack())


@pytest.mark.stress
def test_swap_atomic_under_concurrent_traffic(plans, make_stack):
    """Repeated hot-swaps between two weight sets while clients hammer the
    server: every result must match exactly one of the two weight sets —
    a batch that saw mixed weights would match neither."""
    engine = Engine(backend="jnp")
    net_b = make_stack(seed=99)
    plans_b = BucketedPlanSet.compile(net_b, engine=engine,
                                      max_batch=8).warmup()
    server = SparseServer(plans, slo_ms=30.0, max_queue=4096,
                          result_capacity=4096, engine=engine).start()

    rng = np.random.default_rng(7)
    xs = [rng.standard_normal(plans.n_in).astype(np.float32)
          for _ in range(120)]
    want_a = _expected_rows(plans, xs)
    want_b = [np.asarray(plans_b.base(x[None]))[0] for x in xs]

    results = {}

    def client(lo, hi):
        for i in range(lo, hi):
            rid = server.submit(xs[i])
            results[i] = (rid, server.wait(rid, timeout=30.0))

    clients = [threading.Thread(target=client, args=(i * 30, (i + 1) * 30))
               for i in range(4)]

    def swapper():
        for k in range(6):
            server.swap(plans=plans_b if k % 2 == 0 else plans)

    sw = threading.Thread(target=swapper)
    for t in clients + [sw]:
        t.start()
    for t in clients + [sw]:
        t.join(timeout=60.0)
    server.shutdown()

    assert server.metrics.swaps == 6
    for i, (rid, got) in results.items():
        assert got is not None, f"request {i} lost under swap traffic"
        ok_a = np.array_equal(got, want_a[i])
        ok_b = np.array_equal(got, want_b[i])
        assert ok_a or ok_b, \
            f"request {i} matches NEITHER weight set: mixed-weight batch"


# --------------------------------------------------------------------------- #
# multi-model routing
# --------------------------------------------------------------------------- #

def test_router_routes_by_model_step_driven(make_stack):
    engine = Engine(backend="jnp")
    router = ModelRouter.compile(
        {"a": make_stack(seed=0), "b": make_stack(seed=99)},
        engine=engine, max_batch=8, clock=FakeClock())
    rng = np.random.default_rng(8)
    xs = [rng.standard_normal(router.servers["a"].plans.n_in)
          .astype(np.float32) for _ in range(9)]
    rids = [(name, router.submit(name, x))
            for x, name in zip(xs, "abab abab a".replace(" ", ""))]
    router.drain()
    for (name, rid), x in zip(rids, xs):
        got = router.result(name, rid)
        want = np.asarray(router.servers[name].plans.base(x[None]))[0]
        np.testing.assert_array_equal(got, want)
    snap = router.metrics_snapshot()
    assert snap["models"]["a"]["served"] == 5
    assert snap["models"]["b"]["served"] == 4
    assert snap["total"]["served"] == 9
    with pytest.raises(KeyError, match="unknown model"):
        router.submit("nope", xs[0])


@pytest.mark.stress
def test_router_async_no_cross_model_mixing(make_stack):
    """Concurrent clients of two differently-pruned models through ONE
    scheduler thread: every result comes from the right model."""
    engine = Engine(backend="jnp")
    nets = {"a": make_stack(seed=0), "b": make_stack(seed=99)}
    router = ModelRouter.compile(nets, engine=engine, max_batch=8,
                                 slo_ms=30.0, max_queue=4096).start()
    rng = np.random.default_rng(9)
    n_in = router.servers["a"].plans.n_in
    xs = {m: [rng.standard_normal(n_in).astype(np.float32)
              for _ in range(40)] for m in nets}
    want = {m: [np.asarray(router.servers[m].plans.base(x[None]))[0]
                for x in xs[m]] for m in nets}
    got = {m: [] for m in nets}         # (input index, result) pairs

    def client(model):
        rids = [(i, router.submit(model, x))
                for i, x in enumerate(xs[model])]
        for i, rid in rids:
            got[model].append((i, router.wait(model, rid, timeout=30.0)))

    threads = [threading.Thread(target=client, args=(m,))
               for m in nets for _ in range(2)]   # two clients per model
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    router.shutdown()

    other = {"a": "b", "b": "a"}
    for m in nets:
        assert len(got[m]) == 80
        for i, g in got[m]:
            assert g is not None, f"{m}[{i}] lost"
            np.testing.assert_array_equal(g, want[m][i])
            # the two models genuinely disagree on these inputs, so a
            # cross-model mix-up could not have produced this row
            assert not np.array_equal(g, want[other[m]][i])
    snap = router.metrics_snapshot()
    assert snap["total"]["served"] == 160


def test_router_swap_one_model_keeps_other(make_stack):
    engine = Engine(backend="jnp")
    router = ModelRouter.compile(
        {"a": make_stack(seed=0), "b": make_stack(seed=99)},
        engine=engine, max_batch=8, clock=FakeClock())
    plans_b_before = router.servers["b"].plans
    router.swap("a", make_stack(seed=7))
    assert router.servers["b"].plans is plans_b_before
    assert router.servers["a"].metrics.swaps == 1
    assert router.servers["b"].metrics.swaps == 0


# --------------------------------------------------------------------------- #
# metrics snapshot atomicity (PR 8): a scrape under concurrent traffic is a
# consistent cut, never a torn read
# --------------------------------------------------------------------------- #

@pytest.mark.stress
def test_metrics_snapshot_atomic_under_concurrent_records():
    """Writer threads hammer ``record_batch``/``record_submit`` while a
    reader snapshots in a loop.  Every snapshot must satisfy the cross-field
    invariants the lock guarantees: ``served`` always equals the latency
    series count (``record_batch`` bumps both under one lock), and batch
    bookkeeping is internally consistent.  Without the shared lock a
    snapshot could land between the two updates and tear."""
    from repro.serving import ServingMetrics

    m = ServingMetrics()
    stop = threading.Event()
    ROWS = 2                       # rows per batch -> served == 2 * batches

    def hammer():
        i = 0
        while not stop.is_set():
            m.record_submit(i * 1e-4, depth=i % 5, admitted=True)
            m.record_batch(i * 1e-4, n=ROWS, bucket=ROWS, exec_s=1e-4,
                           waits_s=[1e-4] * ROWS, misses=0)
            i += 1

    writers = [threading.Thread(target=hammer) for _ in range(4)]
    for t in writers:
        t.start()
    try:
        for _ in range(300):
            snap = m.snapshot()
            assert snap["served"] == snap["latency_ms"]["count"]
            assert snap["served"] == snap["queue_wait_ms"]["count"]
            assert snap["served"] == ROWS * snap["batches"]
            assert snap["batches"] == snap["exec_ms"]["count"]
            assert snap["admitted"] >= snap["batches"]
    finally:
        stop.set()
        for t in writers:
            t.join(timeout=10.0)
    # quantiles still answer after the series collapse past exact_cap
    assert m.latency_s.count > 0
    assert m.snapshot()["latency_ms"]["p99"] > 0.0
