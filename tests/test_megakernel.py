"""Whole-network megakernel (flat cross-layer schedule) tests.

Three families:

  * parity — the fused flat-schedule forward equals both the PR-1 per-layer
    dispatch path and the dense layer-by-layer reference (``kernels/ref.py``)
    within 1e-5, on every CPU-runnable backend, including odd batch sizes
    (the engine pads B to the sublane multiple and slices the result);
  * flat-schedule invariants — flattening preserves each layer's
    contiguous-by-output grouping, segment arrays equal the per-layer
    schedule arrays, the cross-layer scalar-prefetch arrays (hbm_row,
    out_tile, bias_idx) obey their freezing/pinning contracts, and the flat
    simulated I/O equals the sum of the per-layer reports;
  * fallback — non-uniform tile sizes cannot flatten and the engine lowers
    the layered path instead, with identical numerics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocksparse import is_contiguous_by_output
from repro.engine import Engine
from repro.kernels.ops import bsr_layer_ref, compile_flat_schedule

CPU_BACKENDS = ("jnp", "interpret")


def _oracle(layers, x, activation, final_activation=None):
    h = x
    for k, lay in enumerate(layers):
        act = activation if k < len(layers) - 1 else final_activation
        h = bsr_layer_ref(h, lay, activation=act)
    return h


def _max_err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


# --------------------------------------------------------------------------- #
# parity: fused == layered == dense reference
# --------------------------------------------------------------------------- #

FUSED_CASES = [
    # (sizes, block, density, batch, activation, reorder)
    ((128, 128), 32, 0.5, 1, "relu", False),          # single layer
    ((128, 256, 128), 32, 0.4, 8, "relu", False),
    ((128, 256, 128), 32, 0.4, 8, "relu", True),      # with CR
    ((192, 192, 192, 192), 32, 0.25, 16, "silu", False),
    ((128, 192, 256, 192, 128), 64, 0.35, 4, "gelu", False),  # 4 layers
    ((128, 256, 128), 64, 0.4, 3, "relu", False),     # odd batch
    ((128, 256, 192, 128), 32, 0.4, 5, "tanh", False),  # odd batch, 3 layers
]


@pytest.mark.parametrize("backend", CPU_BACKENDS)
@pytest.mark.parametrize("sizes,block,density,batch,activation,reorder",
                         FUSED_CASES)
def test_fused_matches_layered_and_reference(make_stack, sizes, block,
                                             density, batch, activation,
                                             reorder, backend):
    layers = make_stack(sizes=sizes, density=density, block=block,
                        seed=hash((sizes, block)) % 2**31)
    kw = dict(backend=backend, activation=activation, reorder=reorder,
              reorder_iters=100)
    fused = Engine(fuse=True, **kw).compile(layers)
    layered = Engine(fuse=False, **kw).compile(layers)
    assert fused.fused and not layered.fused
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((batch, sizes[0])), jnp.float32)
    yf = fused(x)
    yl = layered(x)
    act = None if activation is None else getattr(jax.nn, activation, jnp.tanh)
    yr = _oracle(layers, x, act)
    assert yf.shape == yr.shape and yf.dtype == x.dtype
    assert _max_err(yf, yl) < 1e-5     # fused == per-layer dispatch
    assert _max_err(yf, yr) < 1e-5     # fused == dense reference


def test_fused_backends_agree(make_stack):
    layers = make_stack(sizes=(128, 192, 128), density=0.3)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    ys = [Engine(backend=b, activation="gelu").compile(layers)(x)
          for b in CPU_BACKENDS]
    assert _max_err(ys[0], ys[1]) < 1e-5


@pytest.mark.parametrize("batch", [1, 3, 5, 7, 9])
def test_odd_batch_sizes_on_kernel_backend(make_stack, batch):
    """B is padded to the sublane multiple inside the engine; odd batches
    must work (and match) on the Pallas-semantics backend."""
    layers = make_stack(sizes=(128, 256, 128), density=0.4)
    plan = Engine(backend="interpret").compile(layers)
    rng = np.random.default_rng(batch)
    x = jnp.asarray(rng.standard_normal((batch, 128)), jnp.float32)
    y = plan(x)
    assert y.shape == (batch, 128)
    assert _max_err(y, _oracle(layers, x, jax.nn.relu)) < 1e-5


# --------------------------------------------------------------------------- #
# flat-schedule invariants
# --------------------------------------------------------------------------- #

def test_flat_schedule_preserves_per_layer_grouping(make_stack):
    layers = make_stack(sizes=(128, 256, 192, 128), density=0.4)
    plan = Engine(backend="jnp", reorder=True, reorder_iters=150) \
        .compile(layers)
    flat = plan.flat
    assert flat is not None
    assert flat.nnz == sum(int(s.rows.shape[0]) for s in plan.schedules)
    for k, (s, e) in enumerate(flat.segments):
        sch = plan.schedules[k]
        # each layer segment IS that layer's schedule, verbatim
        np.testing.assert_array_equal(np.asarray(flat.rows[s:e]),
                                      np.asarray(sch.rows))
        np.testing.assert_array_equal(np.asarray(flat.cols[s:e]),
                                      np.asarray(sch.cols))
        np.testing.assert_array_equal(np.asarray(flat.first[s:e]),
                                      np.asarray(sch.first))
        np.testing.assert_array_equal(np.asarray(flat.last[s:e]),
                                      np.asarray(sch.last))
        assert is_contiguous_by_output(np.asarray(flat.cols[s:e]))
        assert set(np.asarray(flat.layer_id[s:e]).tolist()) == {k}


def test_flat_io_equals_sum_of_per_layer_reports(make_stack):
    layers = make_stack(sizes=(128, 256, 192, 128), density=0.4)
    plan = Engine(backend="jnp").compile(layers)
    flat = plan.flat
    assert flat.sim_reads == sum(s.sim_reads for s in plan.schedules)
    assert flat.sim_writes == sum(s.sim_writes for s in plan.schedules)
    assert flat.per_layer_io == tuple(
        (s.sim_reads, s.sim_writes) for s in plan.schedules)
    # and the plan's IOReport carries exactly these as the layered baseline
    assert plan.io.layered_reads == flat.sim_reads
    assert plan.io.layered_writes == flat.sim_writes


def test_flat_prefetch_array_contracts(make_stack):
    layers = make_stack(sizes=(128, 256, 192, 128), density=0.4)
    plan = Engine(backend="jnp").compile(layers)
    flat = plan.flat
    n0 = flat.segments[0][1]
    hbm_row = np.asarray(flat.hbm_row)
    rows = np.asarray(flat.rows)
    cols = np.asarray(flat.cols)
    lid = np.asarray(flat.layer_id)
    out_tile = np.asarray(flat.out_tile)
    # hbm_row live during layer 0, frozen after (no index change, no fetch)
    np.testing.assert_array_equal(hbm_row[:n0], rows[:n0])
    assert len(set(hbm_row[n0:].tolist()) | {int(hbm_row[n0 - 1])}) == 1
    # out_tile pinned to the final layer's first output tile before it
    fs, fe = flat.segments[-1]
    np.testing.assert_array_equal(out_tile[fs:fe], cols[fs:fe])
    assert set(out_tile[:fs].tolist()) <= {int(cols[fs])}
    # bias_idx points at the right global bias tile
    offs = np.concatenate([[0], np.cumsum([l.grid_out for l in layers])])
    np.testing.assert_array_equal(np.asarray(flat.bias_idx),
                                  offs[lid] + cols)
    assert flat.bias_tiles.shape == (int(offs[-1]), flat.block)


def test_cross_layer_savings_reported(make_stack):
    layers = make_stack(sizes=(128, 256, 192, 128), density=0.4)
    io = Engine(backend="jnp").compile(layers).io
    # whole-net schedule never moves more tiles than per-layer dispatch
    assert io.simulated.total <= io.layered_total
    assert io.cross_layer_savings == io.layered_total - io.simulated.total
    assert io.hidden_tiles_kept == sum(l.grid_out for l in layers[:-1])
    assert io.hidden_bytes_kept_per_row == \
        sum(2 * 4 * l.n_out for l in layers[:-1])
    assert "fused saves" in io.summary()


# --------------------------------------------------------------------------- #
# fallback for nets the flat schedule cannot express
# --------------------------------------------------------------------------- #

def test_non_uniform_tiles_fall_back_to_layered():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((128, 128)).astype(np.float32) * 0.1
    b = rng.standard_normal(128).astype(np.float32) * 0.1
    from repro.sparse import prune_dense_stack
    (layer,) = prune_dense_stack([w], [b], density=0.5,
                                 block_m=32, block_n=64)
    plan = Engine(backend="jnp").compile([layer])
    assert not plan.fused and plan.flat is None
    with pytest.raises(ValueError, match="uniform square tile"):
        compile_flat_schedule(plan.layers, plan.schedules)
    x = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    assert _max_err(plan(x), _oracle([layer], x, None)) < 1e-5
