"""Observability: tracer, bounded series, I/O telemetry, Prometheus export.

Covers the PR-8 acceptance scenarios end to end:

  * a single request is followable through the exported trace
    (submit -> queue -> batch.execute -> done) with bucket/model/I/O
    attributes on the spans;
  * the chaos lifecycle (injected failure -> breaker trip -> degraded
    serving -> half-open -> recovery) appears in span order, and the
    Chrome-trace export is structurally valid (monotonic ``ts``, complete
    ``X`` events);
  * ``BoundedSeries`` answers percentiles exactly below its cap (bit-for-bit
    with the legacy list implementation) and within the documented ~12%
    relative error after collapsing, at fixed memory;
  * the Prometheus endpoint exposes the per-bucket dynamic-vs-static
    block-read gauges for a gated model over real HTTP.
"""

import json
import math
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest
from conftest import FakeClock

from repro.engine import Engine
from repro.obs import (
    BoundedSeries,
    IOTelemetry,
    MetricsServer,
    Tracer,
    plan_io_attrs,
    render_prometheus,
)
from repro.obs.trace import NULL_TRACER
from repro.serving import (
    BucketedPlanSet,
    CircuitBreaker,
    FaultInjector,
    ModelRouter,
    PlanStore,
    RetryPolicy,
    SparseServer,
)
from repro.serving.metrics import percentile


# --------------------------------------------------------------------------- #
# Tracer
# --------------------------------------------------------------------------- #

def test_tracer_span_event_and_attrs():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("work", k=1) as sp:
        clk.advance(0.5)
        sp["out"] = 2
    tr.event("tick", n=3)
    spans = tr.spans()
    assert [s.name for s in spans] == ["work", "tick"]
    assert spans[0].phase == "X"
    assert spans[0].dur == pytest.approx(0.5)
    assert spans[0].attrs == {"k": 1, "out": 2}
    assert spans[1].phase == "i" and spans[1].attrs == {"n": 3}


def test_tracer_ring_bound_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.event("e", i=i)
    assert tr.recorded == 10
    assert tr.dropped == 6
    assert [s.attrs["i"] for s in tr.spans()] == [6, 7, 8, 9]
    snap = tr.snapshot()
    assert snap["buffered"] == 4 and snap["dropped"] == 6


def test_disabled_tracer_is_inert():
    tr = Tracer(enabled=False)
    with tr.span("x", a=1) as sp:
        sp["b"] = 2          # must be a silent no-op, not an AttributeError
    tr.event("y")
    tr.span_at("z", 0.0, 1.0)
    assert tr.spans() == [] and tr.recorded == 0
    assert NULL_TRACER.spans() == [] and not NULL_TRACER.enabled


def test_span_ctx_records_exception_type():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("no")
    (s,) = tr.spans()
    assert s.attrs["error"] == "ValueError"


@pytest.mark.stress
def test_tracer_thread_safety():
    tr = Tracer(capacity=100_000)

    def worker(k):
        for i in range(500):
            tr.event("e", k=k, i=i)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.recorded == 8 * 500
    assert len(tr.spans()) == 8 * 500 and tr.dropped == 0


def test_chrome_export_is_valid(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("a", x=1):
        clk.advance(0.1)
    tr.event("b")
    clk.advance(0.1)
    tr.span_at("c", 0.05, 0.15)     # retroactive: recorded out of ts order
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as fh:
        doc = json.load(fh)
    evs = doc["traceEvents"]
    assert len(evs) == 3
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts), "export must sort retroactive spans by ts"
    for e in evs:
        assert set(e) >= {"name", "cat", "ph", "ts", "pid", "tid", "args"}
        if e["ph"] == "X":
            assert "dur" in e and e["dur"] >= 0.0
        else:
            assert e["ph"] == "i" and e["s"] == "t"


def test_jsonl_export_round_trips(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("a", x=1):
        clk.advance(0.25)
    path = tr.export(str(tmp_path / "trace.jsonl"))
    assert path.endswith(".jsonl")
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 1
    assert lines[0]["name"] == "a" and lines[0]["dur"] == pytest.approx(0.25)
    assert lines[0]["attrs"] == {"x": 1}


# --------------------------------------------------------------------------- #
# BoundedSeries
# --------------------------------------------------------------------------- #

def test_bounded_series_exact_prefix_matches_legacy_percentile():
    rng = np.random.default_rng(0)
    xs = [float(v) for v in rng.exponential(0.05, size=1000)]
    s = BoundedSeries()
    s.extend(xs)
    assert s.exact and s.values() == xs
    for q in (0, 10, 50, 90, 99, 100):
        assert s.percentile(q) == percentile(xs, q)
    assert s.mean() == pytest.approx(sum(xs) / len(xs))


def test_bounded_series_post_cap_error_bound_and_fixed_memory():
    rng = np.random.default_rng(1)
    xs = [float(v) for v in rng.exponential(0.05, size=20_000)]
    s = BoundedSeries(exact_cap=1024)
    s.extend(xs)
    assert not s.exact and s.values() is None
    assert s.count == 20_000
    assert s.vmin == min(xs) and s.vmax == max(xs)
    assert s.total == pytest.approx(sum(xs))
    bound = math.sqrt(s.growth) - 1       # documented relative error
    for q in (50, 90, 99):
        want = percentile(xs, q)
        got = s.percentile(q)
        assert abs(got - want) / want <= bound + 1e-9, (q, got, want)


def test_bounded_series_extremes_stay_exact_after_collapse():
    s = BoundedSeries(exact_cap=4)
    s.extend([3.0, 1.0, 9.0, 2.0, 5.0, 0.5])
    assert not s.exact
    assert s.percentile(0) >= s.vmin and s.percentile(100) <= s.vmax
    assert s.vmin == 0.5 and s.vmax == 9.0


def test_bounded_series_buckets_are_cumulative():
    rng = np.random.default_rng(2)
    s = BoundedSeries(exact_cap=8)
    s.extend(float(v) for v in rng.exponential(0.01, size=500))
    pairs = list(s.buckets())
    edges = [e for e, _ in pairs]
    counts = [c for _, c in pairs]
    assert counts == sorted(counts) and counts[-1] == s.count
    assert edges == sorted(edges) and math.isinf(edges[-1])


def test_bounded_series_empty_and_single():
    s = BoundedSeries()
    assert len(s) == 0 and not s and s.percentile(50) == 0.0
    s.add(0.75)
    for q in (0, 50, 100):
        assert s.percentile(q) == 0.75
    d = s.to_dict()
    assert d["count"] == 1 and d["min"] == d["max"] == 0.75


# --------------------------------------------------------------------------- #
# I/O telemetry
# --------------------------------------------------------------------------- #

def test_plan_io_attrs_static(make_stack):
    plan = Engine(backend="jnp", reorder_iters=20).compile(make_stack())
    attrs = plan.trace_attrs()
    assert attrs["backend"] == "jnp"
    assert attrs["io_tile_reads"] >= 1
    assert attrs["io_tile_total"] == \
        attrs["io_tile_reads"] + attrs["io_tile_writes"]
    assert attrs["nnz_blocks"] > 0
    assert isinstance(attrs["io_within_bounds"], bool)
    # defensive on non-plan objects: empty dict, never a raise
    assert plan_io_attrs(object()) == {}


def test_io_telemetry_aggregates_dynamic_reports(make_stack):
    plan = Engine(backend="jnp", gate=True,
                  reorder_iters=20).compile(make_stack())
    telem = IOTelemetry(model="m")
    telem.observe_plan(4, plan)
    # an all-zero batch gates every block: dynamic reads must undercut the
    # static schedule
    rep = plan.measure_dynamic(np.zeros((4, plan.n_in), np.float32))
    telem.observe_dynamic(4, rep)
    snap = telem.snapshot()
    assert snap["model"] == "m" and snap["batches_measured"] == 1
    b = snap["buckets"][4]
    assert b["static_blocks"] > 0 and b["weight_bytes"] > 0
    assert b["dynamic_blocks"] < b["static_scheduled"]
    assert 0.0 <= b["read_fraction"] <= 1.0
    assert set(b["occupancy_hist"]) == {"dead", "lt25", "lt50",
                                        "lt75", "le100"}
    assert snap["dynamic_blocks"] == b["dynamic_blocks"]


# --------------------------------------------------------------------------- #
# serving integration: one request, end to end
# --------------------------------------------------------------------------- #

def test_single_request_followable_in_trace(make_stack):
    clock = FakeClock()
    tr = Tracer(clock=clock)
    plans = BucketedPlanSet.compile(make_stack(),
                                    engine=Engine(backend="jnp"), max_batch=8)
    srv = SparseServer(plans, clock=clock, tracer=tr, name="m0")
    rid = srv.submit(np.ones(plans.n_in, np.float32))
    clock.advance(0.01)
    srv.drain()
    assert srv.result(rid) is not None

    spans = srv.tracer.spans()
    names = [s.name for s in spans]
    i_sub = names.index("request.submit")
    i_q = names.index("request.queue")
    i_ex = names.index("batch.execute")
    i_done = names.index("request.done")
    assert i_sub < i_ex < i_done

    sub = spans[i_sub]
    assert sub.attrs["rid"] == rid and sub.attrs["admitted"] is True
    q = spans[i_q]
    assert q.attrs["rid"] == rid and q.attrs["bucket"] == 1
    ex = spans[i_ex]
    assert ex.attrs["model"] == "m0" and ex.attrs["bucket"] == 1
    assert ex.attrs["n"] == 1 and ex.attrs["degraded"] is False
    assert "io_tile_reads" in ex.attrs          # plan I/O rides on the span
    # the queue span closes exactly where the execute span opens
    assert q.t1 == ex.t0
    done = spans[i_done]
    assert done.attrs["rid"] == rid and done.attrs["ok"] is True
    assert done.attrs["miss"] is False


def test_rejected_submit_traced(make_stack):
    clock = FakeClock()
    tr = Tracer(clock=clock)
    plans = BucketedPlanSet.compile(make_stack(),
                                    engine=Engine(backend="jnp"), max_batch=8)
    srv = SparseServer(plans, clock=clock, tracer=tr, max_queue=1)
    srv.submit(np.zeros(plans.n_in, np.float32))
    assert srv.submit(np.zeros(plans.n_in, np.float32)) is None
    subs = [s for s in tr.spans() if s.name == "request.submit"]
    assert [s.attrs["admitted"] for s in subs] == [True, False]


def test_swap_emits_plan_swap_span(make_stack):
    clock = FakeClock()
    tr = Tracer(clock=clock)
    engine = Engine(backend="jnp", reorder_iters=20, tracer=tr)
    plans = BucketedPlanSet.compile(make_stack(), engine=engine, max_batch=8)
    srv = SparseServer(plans, clock=clock, tracer=tr, engine=engine)
    srv.swap(make_stack(seed=1))
    swaps = [s for s in tr.spans() if s.name == "plan.swap"]
    assert len(swaps) == 1
    assert swaps[0].attrs["cache_hit"] is False
    # the engine shares the tracer, so the swap's recompile phases land in
    # the same buffer
    assert any(s.name == "compile.theorem1" for s in tr.spans())


def test_tracing_disabled_by_default_and_keeps_serving(make_stack):
    plans = BucketedPlanSet.compile(make_stack(),
                                    engine=Engine(backend="jnp"), max_batch=8)
    srv = SparseServer(plans, clock=FakeClock())
    assert srv.tracer is NULL_TRACER
    rid = srv.submit(np.zeros(plans.n_in, np.float32))
    srv.drain()
    assert srv.result(rid) is not None
    assert NULL_TRACER.spans() == []
    assert "tracer" not in srv.snapshot()


# --------------------------------------------------------------------------- #
# chaos scenario: the whole breaker lifecycle in one exported trace
# --------------------------------------------------------------------------- #

def test_chaos_breaker_lifecycle_trace(make_stack, tmp_path):
    clock = FakeClock()
    tr = Tracer(clock=clock)
    plans = BucketedPlanSet.compile(make_stack(),
                                    engine=Engine(backend="jnp"),
                                    max_batch=8, safe_twin=True)
    inj = FaultInjector()
    srv = SparseServer(plans, slo_ms=50.0, clock=clock, tracer=tr, name="m0",
                       retry=RetryPolicy(max_retries=0, backoff_s=0.0),
                       breaker=CircuitBreaker(threshold=2, cooldown_s=5.0),
                       fault_injector=inj)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(plans.n_in).astype(np.float32)
          for _ in range(8)]

    # two consecutive poisoned batches trip the breaker
    inj.inject("server.run_batch",
               error=RuntimeError("poisoned kernel"), times=2)
    srv.submit(xs[0])
    srv.drain()
    clock.advance(0.01)
    srv.submit(xs[1])
    srv.drain()
    assert srv.breaker.state == "open"

    # degraded traffic on the safe twin
    clock.advance(0.01)
    rid = srv.submit(xs[2])
    srv.drain()
    assert srv.result(rid) is not None

    # cool-down elapses: half-open probe on the fast plan succeeds -> reset
    clock.advance(6.0)
    rid = srv.submit(xs[3])
    srv.drain()
    assert srv.result(rid) is not None
    assert srv.breaker.state == "closed"

    spans = tr.spans()

    def first(pred):
        for i, s in enumerate(spans):
            if pred(s):
                return i
        raise AssertionError("span not found")

    fails = [i for i, s in enumerate(spans)
             if s.name == "batch.execute" and "error" in s.attrs]
    assert len(fails) == 2
    assert all(spans[i].attrs["error"] == "RuntimeError" for i in fails)
    i_trip = first(lambda s: s.name == "breaker.tripped")
    i_deg = first(lambda s: s.name == "batch.execute"
                  and s.attrs.get("degraded") and "error" not in s.attrs)
    i_half = first(lambda s: s.name == "breaker.half_open")
    i_reset = first(lambda s: s.name == "breaker.reset")
    assert fails[1] < i_trip < i_deg < i_half < i_reset
    assert spans[i_trip].attrs["state"] == "open"
    assert spans[i_trip].attrs["model"] == "m0"
    assert spans[i_reset].attrs["state"] == "closed"
    # failed requests get done events with ok=False
    dones = [s for s in spans if s.name == "request.done"]
    assert [s.attrs["ok"] for s in dones] == [False, False, True, True]

    # the exported Chrome trace of the whole scenario is structurally valid
    doc = json.load(open(tr.export(str(tmp_path / "chaos.json"))))
    evs = doc["traceEvents"]
    assert len(evs) == len(spans)
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    for e in evs:
        if e["ph"] == "X":
            assert "dur" in e and e["dur"] >= 0.0


@pytest.mark.stress
@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_watchdog_restart_traced(make_stack):
    plans = BucketedPlanSet.compile(make_stack(),
                                    engine=Engine(backend="jnp"), max_batch=8)
    inj = FaultInjector()
    tr = Tracer()
    srv = SparseServer(plans, slo_ms=20.0, tracer=tr, fault_injector=inj,
                       watchdog_s=0.2)
    inj.inject("server.scheduler", error=RuntimeError("sched dies"), times=1)
    srv.start()                                # dies on its first iteration
    try:
        rid = srv.submit(np.zeros(plans.n_in, np.float32))
        assert srv.wait(rid, timeout=10.0) is not None
        assert srv.metrics.watchdog_restarts >= 1
    finally:
        srv.shutdown()
    restarts = [s for s in tr.spans() if s.name == "watchdog.restart"]
    assert restarts and restarts[0].attrs["model"] == "default"


# --------------------------------------------------------------------------- #
# engine + plan store compile-phase spans
# --------------------------------------------------------------------------- #

def test_engine_compile_phases_traced(make_stack):
    tr = Tracer()
    Engine(backend="jnp", reorder=True, reorder_iters=20,
           tracer=tr).compile(make_stack())
    names = [s.name for s in tr.spans()]
    for phase in ("compile.theorem1", "compile.reorder", "compile.pack",
                  "compile.lower", "compile.io_report"):
        assert phase in names, phase
    # the annealer span knows how many connections it ordered
    th = next(s for s in tr.spans() if s.name == "compile.theorem1")
    assert th.attrs["connections"] > 0


def test_plan_store_traces_miss_then_hit(make_stack, tmp_path):
    tr = Tracer()
    store = PlanStore(str(tmp_path / "plans"), tracer=tr)
    engine = Engine(backend="jnp", reorder_iters=20)
    net = make_stack()
    _, hit0 = store.get_or_compile(engine, net)
    _, hit1 = store.get_or_compile(engine, net)
    assert (hit0, hit1) == (False, True)
    loads = [s for s in tr.spans() if s.name == "store.load"]
    assert [s.attrs["hit"] for s in loads] == [False, True]
    assert sum(s.name == "store.compile" for s in tr.spans()) == 1


def test_bucket_fanout_and_warmup_traced(make_stack):
    tr = Tracer()
    engine = Engine(backend="jnp", tracer=tr)
    plans = BucketedPlanSet.compile(make_stack(), engine=engine, max_batch=4)
    plans.warmup()
    spans = tr.spans()
    fan = next(s for s in spans if s.name == "bucket.fanout")
    assert fan.attrs["buckets"] == len(plans.buckets)
    warms = [s for s in spans if s.name == "bucket.warmup"]
    assert sorted(s.attrs["bucket"] for s in warms) == list(plans.buckets)
    assert all(s.attrs["warmup_s"] >= 0.0 for s in warms)


# --------------------------------------------------------------------------- #
# Prometheus exposition
# --------------------------------------------------------------------------- #

@pytest.fixture
def gated_server(make_stack):
    clock = FakeClock()
    engine = Engine(backend="jnp", gate=True, reorder_iters=20)
    plans = BucketedPlanSet.compile(make_stack(), engine=engine, max_batch=8)
    srv = SparseServer(plans, clock=clock, name="gated",
                       measure_dynamic_every=1)
    rng = np.random.default_rng(0)
    for _ in range(4):
        srv.submit(rng.standard_normal(plans.n_in).astype(np.float32))
    clock.advance(0.01)
    srv.drain()
    return srv


def test_prometheus_exposes_dynamic_vs_static_io(gated_server):
    snap = gated_server.snapshot()
    assert snap["model"] == "gated"
    io = snap["io"]
    assert io["batches_measured"] >= 1
    assert io["dynamic_blocks"] <= io["static_scheduled"]

    text = render_prometheus(snap)
    assert "# TYPE repro_served gauge" in text
    assert "repro_served 4" in text
    assert 'repro_latency_ms{quantile="0.5"}' in text
    assert "repro_latency_ms_count 4" in text
    # the acceptance gauge: per-bucket dynamic vs static block reads
    assert 'repro_io_dynamic_blocks{bucket="4"}' in text
    assert 'repro_io_static_scheduled{bucket="4"}' in text
    assert 'repro_io_read_fraction{bucket="4"}' in text
    assert 'repro_io_occupancy_hist{bin="dead",bucket="4"}' in text
    # weight-stream byte accounting, dtype-labelled (f32 plan → one entry)
    assert 'repro_io_weight_bytes{bucket="4",dtype="f32"}' in text
    # booleans flatten to 0/1, strings are skipped
    assert 'repro_io_within_bounds{bucket="4"} 1' in text
    assert "gated" not in text.replace('model="gated"', "")


def test_prometheus_router_snapshot_has_model_labels(make_stack):
    clock = FakeClock()
    router = ModelRouter.compile(
        {"a": make_stack(), "b": make_stack(seed=1)},
        engine=Engine(backend="jnp"), max_batch=8, clock=clock)
    router.submit("a", np.zeros(router.servers["a"].plans.n_in, np.float32))
    clock.advance(0.01)
    router.drain()
    snap = router.snapshot()
    assert set(snap["models"]) == {"a", "b"}
    assert snap["models"]["a"]["served"] == 1
    text = render_prometheus(snap)
    assert 'repro_served{model="a"} 1' in text
    assert 'repro_served{model="b"} 0' in text
    assert "repro_total_served 1" in text


def test_metrics_http_server(gated_server):
    with MetricsServer(gated_server.snapshot, port=0) as msrv:
        assert msrv.port != 0
        body = urllib.request.urlopen(msrv.url, timeout=5).read().decode()
        assert "repro_served 4" in body
        assert 'repro_io_dynamic_blocks{bucket="4"}' in body
        health = urllib.request.urlopen(
            f"http://{msrv.host}:{msrv.port}/healthz", timeout=5)
        assert health.read().decode().strip() == "ok"
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(
                f"http://{msrv.host}:{msrv.port}/nope", timeout=5)
        assert ei.value.code == 404


def test_metrics_http_500_on_broken_snapshot():
    def boom():
        raise RuntimeError("snapshot broke")

    with MetricsServer(boom, port=0) as msrv:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(msrv.url, timeout=5)
        assert ei.value.code == 500
