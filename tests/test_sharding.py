"""Sharded execution plans: partitioner, per-shard Theorem-1 schedules,
collective forward, and the aggregate I/O report.

The contract under test is the acceptance bar of the sharding refactor:

  * sharded outputs are **bit-identical** to the unsharded plan on the same
    net (default, un-annealed schedule — every lowering sums each output
    tile's contributions in the same relative order);
  * every shard's simulated traffic sits inside *its own* shard DAG's
    Theorem-1 bounds, and the report aggregates traffic + load imbalance;
  * ``Mesh(1, 1)`` is the single-device path — same forward builder, not a
    parallel code path.

In-process tests run on however many devices the host exposes (1 in the
tier-1 lane → the sequential shard loop; 8 in the multi-device CI lane →
``shard_map``), so both lowerings are exercised by the same assertions.
The subprocess test forces an 8-device host either way.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.graph import partition_columns_balanced
from repro.engine import Engine, Mesh, ShardedExecutionPlan, ShardedIOReport
from repro.engine.sharding import partition_model

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# the balanced block-column partitioner
# --------------------------------------------------------------------------- #

def test_partitioner_equal_counts_and_determinism():
    rng = np.random.default_rng(0)
    loads = rng.integers(0, 20, size=24)
    a = partition_columns_balanced(loads, 4)
    b = partition_columns_balanced(loads, 4)
    np.testing.assert_array_equal(a, b)          # deterministic
    counts = np.bincount(a, minlength=4)
    assert (counts == 6).all()                   # exact equal counts
    per = np.array([loads[a == s].sum() for s in range(4)])
    # LPT sanity: the heaviest shard carries at least the heaviest column
    # and no more than a naive contiguous split's worst shard
    contiguous = loads.reshape(4, 6).sum(axis=1)
    assert loads.max() <= per.max() <= max(contiguous.max(), loads.max())


def test_partitioner_rejects_indivisible():
    with pytest.raises(ValueError, match="divisible"):
        partition_columns_balanced(np.ones(10), 4)
    with pytest.raises(ValueError, match="parts"):
        partition_columns_balanced(np.ones(8), 0)


def test_partition_model_shards_cover_all_blocks(make_stack):
    from repro.core.blocksparse import to_block_ffnn
    bffnn = to_block_ffnn(make_stack(sizes=(128, 256, 128), block=32))
    specs = partition_model(bffnn, 2)
    assert len(specs) == 2
    for k, lay in enumerate(bffnn.layers):
        owned = np.concatenate([sp.owned[k] for sp in specs])
        assert sorted(owned.tolist()) == list(range(lay.grid_out))
        nnz = sum(sp.bffnn.layers[k].nnz_blocks for sp in specs)
        assert nnz == lay.nnz_blocks             # every block exactly once
        # shard layers keep the full input width (they read the gather)
        for sp in specs:
            assert sp.bffnn.layers[k].n_in == lay.n_in


def test_partition_model_indivisible_grid_raises(make_stack):
    from repro.core.blocksparse import to_block_ffnn
    # 128/32 = 4 tiles in the final layer: model=3 cannot split it
    bffnn = to_block_ffnn(make_stack(sizes=(128, 256, 128), block=32))
    with pytest.raises(ValueError, match="divisible"):
        partition_model(bffnn, 3)


def test_mesh_validation():
    with pytest.raises(ValueError):
        Mesh(model=0)
    assert Mesh(4, 2).size == 8 and Mesh().shape == (1, 1)


# --------------------------------------------------------------------------- #
# output parity: sharded == unsharded, bit for bit
# --------------------------------------------------------------------------- #

MESHES = [Mesh(1, 1), Mesh(2, 1), Mesh(1, 2), Mesh(2, 2), Mesh(4, 2)]


@pytest.mark.parametrize("mesh", MESHES, ids=lambda m: f"{m.model}x{m.data}")
def test_sharded_outputs_bit_identical_to_unsharded(make_stack, mesh):
    layers = make_stack(sizes=(128, 256, 128), density=0.4, block=32)
    engine = Engine(backend="jnp")
    base = engine.compile(layers)
    plan = engine.compile(layers, mesh=mesh)
    assert isinstance(plan, ShardedExecutionPlan)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((8, 128)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(plan(x)), np.asarray(base(x)))
    # odd batches pad over the data axis and slice back
    np.testing.assert_array_equal(np.asarray(plan(x[:5])),
                                  np.asarray(base(x[:5])))
    # single-vector inputs keep the ExecutionPlan contract
    y0 = plan(x[0])
    assert y0.shape == (base.n_out,)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(base(x))[0])


def test_sharded_with_reordering_matches_reference(make_stack):
    """Each shard anneals independently; the function is preserved."""
    layers = make_stack(sizes=(128, 256, 128), density=0.4, block=32)
    engine = Engine(backend="jnp", reorder=True, reorder_iters=60)
    base = engine.compile(layers)
    plan = engine.compile(layers, mesh=Mesh(model=2))
    assert plan.annealer_iters == 2 * 60       # embarrassingly parallel CR
    rng = np.random.default_rng(2)
    x = rng.standard_normal((4, 128)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(plan(x)), np.asarray(base(x)),
                               rtol=1e-5, atol=1e-5)


def test_sharded_interpret_backend_matches_jnp(make_stack):
    layers = make_stack(sizes=(128, 128), density=0.5, block=32)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, 128)).astype(np.float32)
    y_jnp = Engine(backend="jnp").compile(layers, mesh=Mesh(2, 1))(x)
    y_int = Engine(backend="interpret").compile(layers, mesh=Mesh(2, 1))(x)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_int),
                               rtol=1e-5, atol=1e-5)


def test_unit_mesh_is_the_single_device_path(make_stack):
    """Mesh(1,1) shares the unsharded builder's forward — same code, no
    duplicated forward builder."""
    layers = make_stack()
    engine = Engine(backend="jnp")
    plan = engine.compile(layers, mesh=Mesh(1, 1))
    assert len(plan.shards) == 1
    if plan.mesh.jax_mesh() is None:   # single-device host
        assert plan._forward is plan.shards[0]._forward
    base = engine.compile(layers)
    np.testing.assert_array_equal(
        np.asarray(plan(np.zeros((2, 128), np.float32))),
        np.asarray(base(np.zeros((2, 128), np.float32))))


def test_sharded_plan_api_contract(make_stack):
    layers = make_stack()
    plan = Engine(backend="jnp").compile(layers, mesh=Mesh(2, 2))
    assert plan.n_in == 128 and plan.n_out == 128
    with pytest.raises(ValueError, match="expected input"):
        plan(np.zeros((2, 64), np.float32))
    # model>1 shard plans are not standalone-runnable
    with pytest.raises(RuntimeError, match="not standalone-runnable"):
        plan.shards[0](np.zeros((2, 128), np.float32))
    s = plan.describe()
    assert "mesh(model=2, data=2)" in s and "imbalance" in s
    # compile caching keyed on mesh shape
    engine = Engine(backend="jnp")
    assert engine.compile(layers, mesh=Mesh(2, 1)) is \
        engine.compile(layers, mesh=Mesh(2, 1))
    assert engine.compile(layers, mesh=Mesh(2, 1)) is not \
        engine.compile(layers, mesh=Mesh(4, 1))


def test_with_fresh_forward_shares_substrate(make_stack):
    plan = Engine(backend="jnp").compile(make_stack(), mesh=Mesh(2, 1))
    fresh = plan.with_fresh_forward()
    assert fresh.shards is plan.shards and fresh.calls == 0
    x = np.random.default_rng(4).standard_normal((3, 128)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(fresh(x)), np.asarray(plan(x)))


# --------------------------------------------------------------------------- #
# the aggregate I/O report
# --------------------------------------------------------------------------- #

def test_per_shard_io_within_theorem1_bounds(make_stack):
    from repro.core.bounds import theorem1_bounds
    from repro.core.graph import drop_isolated
    from repro.core.iosim import simulate
    for reorder in (False, True):
        plan = Engine(backend="jnp", reorder=reorder,
                      reorder_iters=50).compile(
            make_stack(sizes=(192, 192, 192, 192), density=0.25, block=32),
            mesh=Mesh(model=2))
        report = plan.io_report()
        assert isinstance(report, ShardedIOReport)
        assert report.within_bounds
        for shard, r in zip(plan.shards, report.per_shard):
            assert r.bounds.writes_lo <= r.simulated.writes \
                <= r.bounds.writes_hi
            assert r.simulated.total <= r.bounds.total_hi
            # the report is the exact simulator on the shard's own DAG
            net = drop_isolated(shard.block_ffnn.net)
            assert r.simulated == simulate(net, shard.order, 3, "min")
            assert r.bounds == theorem1_bounds(net)


def test_io_report_aggregates_and_imbalance(make_stack):
    plan = Engine(backend="jnp").compile(
        make_stack(sizes=(128, 256, 128), density=0.4, block=32),
        mesh=Mesh(model=4, data=2))
    report = plan.io_report()
    assert report.total == sum(r.simulated.total for r in report.per_shard)
    assert report.reads + report.writes == report.total
    assert report.load_imbalance >= 1.0
    assert report.max_shard_total * len(report.per_shard) >= report.total
    assert "imbalance" in report.summary()
    # round-trips through the plan-store dict form
    back = ShardedIOReport.from_dict(report.to_dict())
    assert back == report


def test_empty_shard_imbalance_guard():
    empty = ShardedIOReport(per_shard=(), model=1, data=1)
    assert empty.load_imbalance == 1.0 and empty.total == 0


# --------------------------------------------------------------------------- #
# forced multi-device host: the shard_map lowering itself
# --------------------------------------------------------------------------- #

def run_py(body: str, devices: int = 8, timeout: int = 520) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_shard_map_lowering_bit_identical_on_8_devices():
    out = run_py("""
        import numpy as np, jax
        from repro.engine import Engine, Mesh
        from repro.sparse import prune_dense_stack
        assert jax.device_count() == 8
        rng = np.random.default_rng(0)
        sizes = (128, 256, 128)
        ws = [rng.standard_normal((sizes[i], sizes[i+1])).astype(np.float32)*0.1
              for i in range(2)]
        bs = [rng.standard_normal(sizes[i+1]).astype(np.float32)*0.1
              for i in range(2)]
        layers = prune_dense_stack(ws, bs, density=0.4,
                                   block_m=32, block_n=32)
        engine = Engine(backend='jnp')
        base = engine.compile(layers)
        x = rng.standard_normal((8, 128)).astype(np.float32)
        y0 = np.asarray(base(x))
        plan = engine.compile(layers, mesh=Mesh(model=4, data=2))
        assert plan.mesh.jax_mesh() is not None, 'expected the shard_map path'
        assert np.array_equal(np.asarray(plan(x)), y0)
        assert np.array_equal(np.asarray(plan(x[:5])), y0[:5])
        assert plan.io_report().within_bounds
        print('SHARDMAP_BITIDENTICAL')
    """)
    assert "SHARDMAP_BITIDENTICAL" in out


def test_sharded_serving_on_8_devices():
    out = run_py("""
        import numpy as np, jax
        from repro.engine import Engine, Mesh
        from repro.serving import BucketedPlanSet, SparseServer
        from repro.sparse import prune_dense_stack
        rng = np.random.default_rng(0)
        ws = [rng.standard_normal((128, 128)).astype(np.float32)*0.1]
        layers = prune_dense_stack(ws, [np.zeros(128, np.float32)],
                                   density=0.5, block_m=32, block_n=32)
        plans = BucketedPlanSet.compile(
            layers, engine=Engine(backend='jnp'), max_batch=8,
            mesh=Mesh(model=2, data=2)).warmup()
        server = SparseServer(plans, slo_ms=100.0)
        rids = [server.submit(rng.standard_normal(128).astype(np.float32))
                for _ in range(13)]
        server.poll(); server.drain()
        assert all(server.result(r) is not None for r in rids)
        assert server.metrics.served == 13
        print('SHARDED_SERVE_OK')
    """)
    assert "SHARDED_SERVE_OK" in out
