"""Data pipeline: determinism across restarts (fault-tolerance contract)."""

import numpy as np

from repro.data import SyntheticLM, TokenBatcher


def test_batcher_deterministic_in_step():
    src = SyntheticLM(vocab=128, seed=3)
    b1 = TokenBatcher(src, batch=4, seq_len=16, seed=9)
    b2 = TokenBatcher(SyntheticLM(vocab=128, seed=3), batch=4, seq_len=16, seed=9)
    for step in (0, 5, 17):
        x1, x2 = b1(step), b2(step)
        np.testing.assert_array_equal(x1["tokens"], x2["tokens"])
        np.testing.assert_array_equal(x1["labels"], x2["labels"])


def test_labels_are_shifted_tokens():
    src = SyntheticLM(vocab=64, seed=0)
    b = TokenBatcher(src, batch=2, seq_len=8, seed=0)(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 8)
    # markov structure: labels[t] follows tokens[t] in the chain
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_stream_has_learnable_structure():
    """Transition entropy must be well below uniform (so training can learn)."""
    src = SyntheticLM(vocab=32, seed=1)
    rng = np.random.default_rng(0)
    seqs = src.sample(rng, 64, 256)
    # empirical bigram counts
    joint = np.zeros((32, 32))
    for row in seqs:
        for a, b in zip(row[:-1], row[1:]):
            joint[a, b] += 1
    cond = joint / np.maximum(1, joint.sum(1, keepdims=True))
    ent = -np.nansum(np.where(cond > 0, cond * np.log(cond), 0), axis=1).mean()
    assert ent < 0.8 * np.log(32)
