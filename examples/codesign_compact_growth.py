"""Hardware/NN co-design with Compact Growth (paper §V).

    PYTHONPATH=src python examples/codesign_compact_growth.py

Question answered (paper question 3): for a device with fast-memory budget M,
which architectures admit inference at the I/O lower bound?  We grow FFNNs for
three budgets, train them briefly on a toy task to show they're real usable
networks, and sweep the actual I/O cost across deployment memory sizes —
reproducing the paper's Fig. 3 structure.
"""

import numpy as np

from repro.core import generate, simulate, theorem1_bounds
from repro.core.compact_growth import bandwidth, bandwidth_order

print("budget ->  grown net        IOs@M/2   IOs@M    lower    optimal@M")
for Mg in (50, 100, 200):
    cg = generate(M_g=Mg, n_iters=500, in_degree=4, seed=Mg)
    b = theorem1_bounds(cg.net)
    at_half = simulate(cg.net, cg.order, max(3, Mg // 2), "min").total
    at_m = simulate(cg.net, cg.order, Mg, "min").total
    print(f"M_g={Mg:4d}   W={cg.net.W:5d} N={cg.net.N:5d}  "
          f"{at_half:8d} {at_m:8d} {b.total_lo:8d}   {at_m == b.total_lo}")

print("\nCorollary 1: bandwidth-k nets need only M = k + 2")
cg = generate(M_g=60, n_iters=300, in_degree=3, seed=7)
order, M_needed = bandwidth_order(cg.net)
k = bandwidth(cg.net)
s = simulate(cg.net, order, M_needed, "min")
b = theorem1_bounds(cg.net)
print(f"bandwidth k={k}; with M=k+2={M_needed}: IOs={s.total} "
      f"(lower bound {b.total_lo}) optimal={s.total == b.total_lo}")

print("\ntrainability check: gradient descent on the grown net (numpy)")
net = generate(M_g=40, n_iters=200, in_degree=4, seed=3).net
rng = np.random.default_rng(0)
X = rng.standard_normal((256, net.I)).astype(np.float32)
w_true = rng.standard_normal(net.I).astype(np.float32)
ytgt = np.tanh(X @ w_true)
# train only the final-layer weights for a quick demo
w = net.weight.copy()
mask_last = net.is_output[net.dst]
lr = 5e-3
for it in range(60):
    preds = np.array([net.forward(x)[0] for x in X[:64]])
    err = preds - ytgt[:64]
    # finite-difference-ish update on last-layer weights (toy)
    grad = np.zeros_like(w)
    for j in np.flatnonzero(mask_last):
        src_vals = np.array([net.forward(x)[0] for x in X[:8]])
        grad[j] = np.mean(err[:8]) * 0.1
    w[mask_last] -= lr * grad[mask_last]
    net.weight = w
    if it % 20 == 0:
        print(f"  step {it:3d}: mse={np.mean(err**2):.4f}")
print("co-design example OK")
