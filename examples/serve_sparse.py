"""End-to-end driver: serve a magnitude-pruned BERT-style FFNN with batched
requests through the paper-scheduled sparse executor (the paper's deployment
scenario: sparse FFNN inference).

    PYTHONPATH=src python examples/serve_sparse.py [--requests 64] [--density 0.1]

A request = one feature vector through the pruned 1024-4096-1024 FFNN (the
BERT encoder MLP the paper targets).  Requests are batched (batch=32), the
connection schedule is Theorem-1-ordered and CR-optimized offline, and the
exact simulated I/O counts are reported next to wall time.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import theorem1_bounds
from repro.core.graph import drop_isolated
from repro.sparse import ScheduledSparseFFNN, prune_dense_stack


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--reorder-iters", type=int, default=500)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((1024, 4096)).astype(np.float32) * 0.03
    w2 = rng.standard_normal((4096, 1024)).astype(np.float32) * 0.03
    b1 = np.zeros(4096, np.float32)
    b2 = np.zeros(1024, np.float32)

    print(f"pruning BERT FFNN to density {args.density} ...")
    layers = prune_dense_stack([w1, w2], [b1, b2], density=args.density,
                               block_m=128, block_n=128)
    t0 = time.time()
    model = ScheduledSparseFFNN.build(layers, activation=jax.nn.gelu,
                                      reorder=True,
                                      reorder_iters=args.reorder_iters)
    print(f"offline schedule build (+CR): {time.time()-t0:.1f}s")
    ios = model.simulated_ios(M_tiles=3)
    bounds = theorem1_bounds(drop_isolated(model.block_ffnn.net))
    print(f"schedule tile-I/O: {ios.total} (lower bound {bounds.total_lo}, "
          f"2-opt upper {bounds.total_hi})")

    # request loop (continuous batches)
    done = 0
    t0 = time.time()
    lat = []
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        x = jnp.asarray(rng.standard_normal((args.batch, 1024)), jnp.float32)
        t1 = time.time()
        y = model(x)
        y.block_until_ready()
        lat.append(time.time() - t1)
        done += n
    dt = time.time() - t0
    print(f"served {done} requests in {dt:.2f}s "
          f"(p50 batch latency {1e3*np.median(lat):.1f} ms, "
          f"{done/dt:.1f} req/s)")
    print("output sample:", np.asarray(y[0, :4]).round(3).tolist())


if __name__ == "__main__":
    main()
