"""End-to-end driver: serve a magnitude-pruned BERT-style FFNN with batched
requests through the fused inference engine (the paper's deployment scenario:
sparse FFNN inference).

    PYTHONPATH=src python examples/serve_sparse.py [--requests 64] [--density 0.1]

A request = one feature vector through the pruned 1024-4096-1024 FFNN (the
BERT encoder MLP the paper targets).  Requests are batched (batch=32); the
whole network is compiled ONCE into an execution plan (Theorem-1 ordered and
CR-optimized offline, all layers fused into a single jitted program) and every
batch then runs the plan.  The plan's exact simulated I/O is reported next to
the Theorem-1 bounds and wall time.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import Engine
from repro.sparse import prune_dense_stack


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--reorder-iters", type=int, default=500)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "interpret", "jnp"))
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((1024, 4096)).astype(np.float32) * 0.03
    w2 = rng.standard_normal((4096, 1024)).astype(np.float32) * 0.03
    b1 = np.zeros(4096, np.float32)
    b2 = np.zeros(1024, np.float32)

    print(f"pruning BERT FFNN to density {args.density} ...")
    layers = prune_dense_stack([w1, w2], [b1, b2], density=args.density,
                               block_m=128, block_n=128)
    engine = Engine(backend=args.backend, activation=jax.nn.gelu,
                    reorder=True, reorder_iters=args.reorder_iters)
    t0 = time.time()
    plan = engine.compile(layers)
    print(f"engine compile (schedule + CR + lowering): {time.time()-t0:.1f}s")
    print(plan.describe())

    # request loop (continuous batches) — run-many against the cached plan
    done = 0
    t0 = time.time()
    lat = []
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        x = jnp.asarray(rng.standard_normal((args.batch, 1024)), jnp.float32)
        t1 = time.time()
        y = plan(x)
        y.block_until_ready()
        lat.append(time.time() - t1)
        done += n
    dt = time.time() - t0
    print(f"served {done} requests in {dt:.2f}s "
          f"(p50 batch latency {1e3*np.median(lat):.1f} ms, "
          f"{done/dt:.1f} req/s, {plan.calls} plan calls)")
    print("output sample:", np.asarray(y[0, :4]).round(3).tolist())


if __name__ == "__main__":
    main()
