"""End-to-end driver: serve a magnitude-pruned BERT-style FFNN through the
continuous-batching serving runtime (the paper's deployment scenario: sparse
FFNN inference under sustained request traffic).

    PYTHONPATH=src python examples/serve_sparse.py [--requests 64] [--density 0.1]

A request = one feature vector through the pruned 1024-4096-1024 FFNN (the
BERT encoder MLP the paper targets).  The whole network is compiled ONCE
(Theorem-1 ordered and CR-optimized offline) — or restored from a persistent
plan store with ``--plan-store DIR``, skipping the annealing entirely on the
second run — and fanned out across power-of-two batch buckets.  Requests
arrive in bursts; the SLO scheduler forms batches wait-or-fire and routes
each through the smallest bucket that fits, so tail batches don't pay
full-batch latency.  The plan's exact simulated I/O is reported next to the
Theorem-1 bounds alongside the serving metrics.

``--http`` serves the same traffic over the wire: the process opens the
stdlib JSON front door (``HttpFrontDoor``) and the client threads become
real HTTP clients (``urllib`` — no new dependencies) POSTing to
``/v1/infer``; a 429 (queue full) backs off and retries.  Combine with
``--workers N`` to run the staged pipeline behind the front door:

    PYTHONPATH=src python examples/serve_sparse.py --http --workers 2
"""

import argparse
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np

from repro.engine import Engine
from repro.serving import (
    BucketedPlanSet,
    HttpFrontDoor,
    PlanStore,
    SparseServer,
)
from repro.sparse import prune_dense_stack


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--reorder-iters", type=int, default=500)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--threads", type=int, default=0,
                    help="> 0: serve through the async scheduler thread "
                         "with this many concurrent client threads "
                         "(Future-style wait per request); 0 = the "
                         "deterministic step-driven loop")
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP: open the JSON front door on an "
                         "ephemeral port and drive the clients through "
                         "urllib POSTs to /v1/infer (implies async mode; "
                         "uses --threads connections, default 4)")
    ap.add_argument("--workers", type=int, default=0,
                    help="> 0: staged pipeline — the scheduler only forms "
                         "batches onto per-bucket dispatch lanes and this "
                         "many executor workers drain them concurrently")
    ap.add_argument("--plan-store", default=None,
                    help="persistent plan cache directory; rerun with the "
                         "same dir for a warm start with zero annealing")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "interpret", "jnp"))
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    w1 = rng.standard_normal((1024, 4096)).astype(np.float32) * 0.03
    w2 = rng.standard_normal((4096, 1024)).astype(np.float32) * 0.03
    b1 = np.zeros(4096, np.float32)
    b2 = np.zeros(1024, np.float32)

    print(f"pruning BERT FFNN to density {args.density} ...")
    layers = prune_dense_stack([w1, w2], [b1, b2], density=args.density,
                               block_m=128, block_n=128)
    engine = Engine(backend=args.backend, activation="gelu",
                    reorder=True, reorder_iters=args.reorder_iters)
    store = PlanStore(args.plan_store) if args.plan_store else None
    t0 = time.time()
    plans = BucketedPlanSet.compile(layers, engine=engine,
                                    max_batch=args.batch, plan_store=store)
    start = "warm start (plan-store hit, zero annealer iters)" \
        if plans.cache_hit else "cold compile (schedule + CR + lowering)"
    print(f"{start}: {time.time()-t0:.1f}s")
    print(plans.describe())
    plans.warmup()

    # bursty request traffic — the wait-or-fire scheduler forms batches and
    # the bucket router serves each through the smallest bucket that fits
    server = SparseServer(plans, slo_ms=args.slo_ms, engine=engine,
                          plan_store=store, executor_workers=args.workers)
    rids = []
    if args.http:
        # over-the-wire mode: same traffic, but each client thread is a
        # real HTTP connection into the front door; admission control
        # arrives as status codes (429 = queue full -> back off + retry)
        server.start()
        front = HttpFrontDoor(server, port=0).start()
        nclients = args.threads or 4
        print(f"http front door: {front.url} ({nclients} client threads"
              + (f", {args.workers} executor workers" if args.workers
                 else "") + ")")
        codes = {}
        samples = []
        lock = threading.Lock()

        def http_client(n, seed):
            crng = np.random.default_rng(seed)
            done = 0
            while done < n:
                x = crng.standard_normal(1024).astype(np.float32)
                req = urllib.request.Request(
                    front.url + "/v1/infer",
                    data=json.dumps({"x": x.tolist()}).encode("utf-8"),
                    headers={"Content-Type": "application/json"},
                    method="POST")
                retry_after = None
                try:
                    with urllib.request.urlopen(req, timeout=60) as resp:
                        code, payload = resp.status, json.load(resp)
                except urllib.error.HTTPError as e:
                    code = e.code
                    retry_after = e.headers.get("Retry-After")
                    payload = {}
                    e.read()
                with lock:
                    codes[code] = codes.get(code, 0) + 1
                if code == 429:          # queue full: back off, same request
                    time.sleep(float(retry_after or 0.05))
                    continue
                if code == 200:
                    with lock:
                        samples.append(payload["y"])
                done += 1

        per = args.requests // nclients
        ts = [threading.Thread(
                  target=http_client,
                  args=(per + (i < args.requests % nclients), 100 + i))
              for i in range(nclients)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        front.stop()
        server.shutdown()
        print(f"http status codes: {dict(sorted(codes.items()))}")
        y = np.asarray(samples[-1], np.float32) if samples else None
    elif args.threads > 0:
        # async mode: the scheduler thread forms batches while concurrent
        # clients submit and block on their own results (Future-style)
        server.start()
        outs = {}

        def client(n, seed):
            crng = np.random.default_rng(seed)   # per-thread generator
            for _ in range(n):
                rid = server.submit(
                    crng.standard_normal(1024).astype(np.float32))
                if rid is not None:
                    rids.append(rid)
                    outs[rid] = server.wait(rid, timeout=30.0)

        per = args.requests // args.threads
        ts = [threading.Thread(
                  target=client,
                  args=(per + (i < args.requests % args.threads), 100 + i))
              for i in range(args.threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        server.shutdown()
        y = outs[rids[-1]]
    else:
        pending = args.requests
        while pending:
            burst = min(int(rng.integers(1, args.batch + 1)), pending)
            for _ in range(burst):
                rid = server.submit(
                    rng.standard_normal(1024).astype(np.float32))
                if rid is not None:
                    rids.append(rid)
            pending -= burst
            server.poll()
        server.drain()
        y = server.result(rids[-1])
    print(server.metrics.summary())
    print(f"bucket calls: { {b: n for b, n in plans.bucket_calls.items() if n} }")
    if y is None:   # timed out waiting, or the uncollected result was evicted
        print("output sample: <not collected>")
    else:
        print("output sample:", np.asarray(y[:4]).round(3).tolist())


if __name__ == "__main__":
    main()
