"""Train a small LM for a few hundred steps with the full production stack:
sharded params, AdamW + cosine schedule, deterministic data pipeline, async
checkpointing, fault injection, and automatic restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --arch zamba2-1.2b

Uses the reduced config of the chosen architecture (CPU-friendly); the same
driver scales the full config on a real mesh (see repro.launch.train).
"""

import subprocess
import sys


def main():
    args = sys.argv[1:]
    if not any(a.startswith("--steps") for a in args):
        args += ["--steps", "120"]
    if not any(a.startswith("--arch") for a in args):
        args += ["--arch", "zamba2-1.2b"]
    cmd = [sys.executable, "-m", "repro.launch.train", "--reduced",
           "--batch", "8", "--seq", "64", "--ckpt-dir", "/tmp/repro_example",
           "--inject-fault-at", "40"] + args
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
