"""Quickstart: the paper's pipeline end to end on a small sparse FFNN.

    PYTHONPATH=src python examples/quickstart.py

1. generate a random sparse MLP (paper Appendix A);
2. bound its inference I/O with Theorem 1;
3. run Algorithm 1 under MIN/LRU/RR eviction with the 2-optimal order;
4. improve the order with Connection Reordering (simulated annealing);
5. generate an I/O-*optimal* network for this memory with Compact Growth;
6. lower the same ideas to TPU tile granularity and execute with the
   scheduled block-sparse Pallas kernel (interpret mode on CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    connection_reordering,
    generate,
    random_ffnn,
    simulate,
    theorem1_bounds,
)
from repro.engine import Engine
from repro.kernels.ops import bsr_layer_ref
from repro.sparse import prune_dense_stack

M = 64  # fast-memory budget (words)

print("== 1-2. random sparse FFNN + Theorem 1 bounds ==")
net = random_ffnn(width=200, depth=4, density=0.1, seed=0)
b = theorem1_bounds(net)
print(f"W={net.W} N={net.N} I={net.I} S={net.S}")
print(f"total I/O bounds: {b.total_lo} <= IOs <= {b.total_hi} "
      f"(upper/lower = {b.total_hi/b.total_lo:.2f} — Thm 1 guarantees <= 2)")

print("\n== 3. Algorithm 1 with the 2-optimal order ==")
order = net.theorem1_order()
for policy in ("min", "lru", "rr"):
    s = simulate(net, order, M, policy)
    print(f"  {policy.upper():3s}: reads={s.reads} writes={s.writes} "
          f"total={s.total}")

print("\n== 4. Connection Reordering (simulated annealing, T=2000) ==")
res = connection_reordering(net, order, M, T=2000, seed=0)
closed = 100 * (res.initial_ios - res.ios) / max(1, res.initial_ios - b.total_lo)
print(f"  {res.initial_ios} -> {res.ios} I/Os "
      f"({closed:.0f}% of the gap to the lower bound closed)")
x = np.random.default_rng(0).standard_normal(net.I)
np.testing.assert_allclose(net.forward(x, order), net.forward(x, res.order),
                           rtol=1e-5, atol=1e-5)
print("  (network function unchanged — checked)")

print("\n== 5. Compact Growth: an I/O-optimal architecture for M =", M, "==")
cg = generate(M_g=M, n_iters=400, in_degree=4, seed=1)
bb = theorem1_bounds(cg.net)
s = simulate(cg.net, cg.order, M, "min")
print(f"  grown net: W={cg.net.W} N={cg.net.N}; IOs={s.total} "
      f"== lower bound {bb.total_lo}: {s.total == bb.total_lo}")

print("\n== 6. TPU tile granularity: the fused inference engine ==")
rng = np.random.default_rng(0)
sizes = [256, 512, 256]
ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.05
      for i in range(2)]
bs = [np.zeros(sizes[i + 1], np.float32) for i in range(2)]
layers = prune_dense_stack(ws, bs, density=0.3, block_m=64, block_n=64)
# compile once: block DAG -> Theorem-1 order -> CR -> one fused plan
plan = Engine(reorder=True, reorder_iters=300).compile(layers)
print(f"  {plan.describe()}")
xb = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
y = plan(xb)  # run many: a single jitted dispatch for the whole net
ref = xb
for i, lay in enumerate(layers):
    ref = bsr_layer_ref(ref, lay, activation=jax.nn.relu if i < 1 else None)
err = float(jnp.max(jnp.abs(y - ref) / (1 + jnp.abs(ref))))
print(f"  engine vs dense oracle rel-err: {err:.2e}")
assert plan.io.within_bounds, "simulated I/O must sit inside Theorem 1"
print("\nquickstart OK")
