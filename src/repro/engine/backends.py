"""Execution backends for compiled plans.

Three ways to run the same flat cross-layer schedule:

  * ``pallas``    — the whole-network Pallas megakernel
                    (``kernels/bsr_matmul.bsr_megakernel``): ONE grid over
                    every nonzero block of every layer, hidden state resident
                    in VMEM across layer boundaries; the production path.
  * ``interpret`` — the identical megakernel body run in interpret mode;
                    exact kernel semantics on any host (the correctness path).
  * ``jnp``       — a pure-``jnp`` lowering of the same flat schedule: one
                    gather → batched block matmul → segment-sum pass per
                    layer segment of the flat arrays; runs fast on CPU/GPU
                    and is fully jittable.

All three consume the same ``FlatSchedule`` arrays, so the connection order —
the thing the paper is about — is identical across backends; only the
machinery that walks it differs.  ``auto`` resolves to ``pallas`` on TPU and
``jnp`` elsewhere.

Nets whose tile shapes cannot be flattened (non-uniform block sizes) fall
back to the per-layer dispatch path (``make_forward``), which is also what
``benchmarks/bench_engine.py`` uses as the layered baseline.

The TPU kernels tile the batch dimension, so ``B`` is padded up to the
sublane multiple of the dtype before a ``pallas``/``interpret`` launch and
the result is sliced back — odd batch sizes work on every backend.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map as compat_shard_map
from repro.core.blocksparse import BSRLayer
from repro.kernels.bsr_matmul import bsr_matmul, bsr_megakernel
from repro.kernels.ops import CompiledSchedule, FlatSchedule

BACKENDS = ("pallas", "interpret", "jnp")


def resolve_backend(name: str) -> str:
    """Resolve ``auto`` (and validate) to a concrete backend name."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; pick from {('auto',) + BACKENDS}")
    return name


def sublane_multiple(dtype) -> int:
    """Minimum TPU sublane count for ``dtype`` (second-to-last dim tiling)."""
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize == 2:
        return 16
    if itemsize == 1:
        return 32
    return 8


def pad_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pad the batch dim up to the sublane multiple (TPU tiling constraint)."""
    B = x.shape[0]
    m = sublane_multiple(x.dtype)
    pad = (-B) % m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


# --------------------------------------------------------------------------- #
# per-layer dispatch (layered baseline + fallback for non-uniform tiles)
# --------------------------------------------------------------------------- #

def _jnp_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
) -> jnp.ndarray:
    """One layer of the schedule as gather → block matmul → segment-sum.

    Accumulates in float32 (like the kernel's VMEM accumulator) and walks the
    blocks in schedule order, so the arithmetic is the schedule's.
    """
    return _jnp_segment(
        x, schedule.rows, schedule.cols, schedule.blocks,
        jnp.asarray(layer.bias), layer.block_m, layer.block_n,
        layer.grid_in, layer.grid_out, activation,
    )


def _jnp_segment(
    x: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    blocks: jnp.ndarray,
    bias: jnp.ndarray,
    bm: int,
    bn: int,
    grid_in: int,
    grid_out: int,
    activation: Optional[Callable],
    pad_segments: int = 0,
) -> jnp.ndarray:
    """One schedule segment as gather → block matmul → segment-sum.

    ``pad_segments`` > 0 reserves that many trailing sink segments: schedule
    steps with ``cols >= grid_out`` land there and are dropped before the
    bias/activation epilogue.  The sharded forward pads every shard's
    schedule to a uniform length with steps routed to the sink, so padding
    never perturbs a real output tile (not even by adding 0.0).
    """
    B = x.shape[0]
    xt = x.reshape(B, grid_in, bm).transpose(1, 0, 2)          # [gi, B, bm]
    gathered = jnp.take(xt, rows, axis=0)                      # [nnz, B, bm]
    contrib = jnp.einsum(
        "gbm,gmn->gbn",
        gathered.astype(jnp.float32),
        blocks.astype(jnp.float32),
    )                                                          # [nnz, B, bn]
    y = jax.ops.segment_sum(contrib, cols,
                            num_segments=grid_out + pad_segments)
    if pad_segments:
        y = y[:grid_out]                                       # [go, B, bn]
    y = y.transpose(1, 0, 2).reshape(B, grid_out * bn)
    y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(x.dtype)


def _pallas_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
    interpret: bool,
) -> jnp.ndarray:
    return bsr_matmul(
        x,
        schedule.blocks,
        schedule.rows,
        schedule.cols,
        schedule.first,
        schedule.last,
        jnp.asarray(layer.bias),
        grid_out=schedule.grid_out,
        activation=activation,
        interpret=interpret,
    )


def make_forward(
    layers: Sequence[BSRLayer],
    schedules: Sequence[CompiledSchedule],
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
) -> Callable:
    """Per-layer dispatch forward: x [B, n_in] -> [B, n_out].

    One ``pallas_call`` (or jnp pass) per layer inside one jitted program —
    the PR-1 call pattern, kept as the layered baseline the megakernel is
    benchmarked against and as the fallback for nets the flat schedule
    cannot express (non-uniform tile sizes).
    """
    layers = list(layers)
    schedules = list(schedules)
    activations = list(activations)

    def forward(x):
        B = x.shape[0]
        h = x
        if backend != "jnp":
            h = pad_batch(h)
        for layer, schedule, act in zip(layers, schedules, activations):
            if backend == "jnp":
                h = _jnp_layer(h, layer, schedule, act)
            else:
                h = _pallas_layer(h, layer, schedule, act,
                                  interpret=(backend == "interpret"))
        return h[:B]

    return jax.jit(forward) if jit else forward


# --------------------------------------------------------------------------- #
# fused dispatch: the whole net as one flat schedule
# --------------------------------------------------------------------------- #

def make_fused_forward(
    layers: Sequence[BSRLayer],
    flat: FlatSchedule,
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
) -> Callable:
    """Whole-network fused forward over one ``FlatSchedule``.

    ``pallas``/``interpret``: a single ``bsr_megakernel`` dispatch — one grid
    for all layers, hidden state in VMEM end to end.  ``jnp``: the identical
    flat arrays consumed segment-by-segment (segment views are materialized
    once here, outside the trace, so no per-call slicing of the big block
    array survives into the compiled program).
    """
    layers = list(layers)
    activations = list(activations)
    hidden = set(activations[:-1])
    if len(hidden) > 1:
        raise ValueError(
            "the megakernel fuses ONE hidden-layer activation; got "
            f"{len(hidden)} distinct hidden epilogues — use fuse=False "
            "(per-layer dispatch) for heterogeneous activations"
        )
    act = activations[0] if len(activations) > 1 else None
    fact = activations[-1]

    if backend == "jnp":
        bs = flat.block
        segs = []
        bias_row = 0
        for k, (s, e) in enumerate(flat.segments):
            lay = layers[k]
            bias = flat.bias_tiles[bias_row:bias_row + lay.grid_out] \
                .reshape(-1)
            segs.append((flat.rows[s:e], flat.cols[s:e], flat.blocks[s:e],
                         bias, lay.grid_in, lay.grid_out, activations[k]))
            bias_row += lay.grid_out

        def forward_jnp(x):
            h = x
            for rows, cols, blocks, bias, gi, go, a in segs:
                h = _jnp_segment(h, rows, cols, blocks, bias,
                                 bs, bs, gi, go, a)
            return h

        return jax.jit(forward_jnp) if jit else forward_jnp

    def forward(x):
        B = x.shape[0]
        xp = pad_batch(x)
        y = bsr_megakernel(
            xp, flat.blocks, flat.rows, flat.cols, flat.first, flat.last,
            flat.layer_id, flat.hbm_row, flat.out_tile, flat.bias_idx,
            flat.bias_tiles,
            n_layers=flat.n_layers,
            block=flat.block,
            grid_out_final=flat.grid_out_final,
            hidden_tiles=flat.hidden_tiles,
            activation=act,
            final_activation=fact,
            interpret=(backend == "interpret"),
        )
        return y[:B]

    return jax.jit(forward) if jit else forward


# --------------------------------------------------------------------------- #
# sharded dispatch: per-shard segments + an activation gather per boundary
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ShardedSegment:
    """One layer's schedule arrays stacked over the model-axis shards.

    Every shard's schedule is padded to a uniform step count (``shard_map``
    needs equal per-device shapes); padded steps carry zero blocks and route
    to the sink segment (``cols == tps``), so they touch no real output tile.
    ``perm[t]`` maps the layer's canonical output tile ``t`` to its flat
    ``shard * tps + local_pos`` position in the all-gathered activation.
    """

    rows: np.ndarray          # int32 [model, n_max] input tile (full grid)
    cols: np.ndarray          # int32 [model, n_max] local output tile or sink
    blocks: np.ndarray        # float32 [model, n_max, bm, bn]
    bias: np.ndarray          # float32 [model, tps * bn]
    perm: np.ndarray          # int32 [grid_out_full]
    grid_in: int              # full input grid of this layer
    tps: int                  # output tiles per shard
    block_m: int              # input-tile size
    block_n: int              # output-tile size
    activation: Optional[Callable]


def _shard_layer(h, seg: ShardedSegment, rows, cols, blocks, bias):
    """One shard's slice of one layer over the full gathered activation."""
    return _jnp_segment(h, rows, cols, blocks, bias, seg.block_m, seg.block_n,
                        seg.grid_in, seg.tps, seg.activation, pad_segments=1)


def _reassemble(gathered, seg: ShardedSegment):
    """[model, B, tps*bn] shard outputs -> [B, full] canonical tile order."""
    m, B, _ = gathered.shape
    tiles = gathered.reshape(m, B, seg.tps, seg.block_n).transpose(0, 2, 1, 3)
    tiles = tiles.reshape(m * seg.tps, B, seg.block_n)
    tiles = jnp.take(tiles, jnp.asarray(seg.perm), axis=0)
    return tiles.transpose(1, 0, 2).reshape(B, -1)


def make_sharded_forward(
    segments: Sequence[ShardedSegment],
    model: int,
    data: int,
    jax_mesh=None,
    base_forward: Optional[Callable] = None,
    jit: bool = True,
) -> Callable:
    """Collective forward over a model×data mesh: x [B, n_in] -> [B, n_out].

    Per layer, each model shard computes its owned output tiles from the
    full (gathered) previous activation, then an all-gather + tile
    permutation reassembles the full hidden state for the next layer.  The
    batch dim is split over ``data`` (``B`` must be divisible by it — the
    plan wrapper pads).

    Lowering: through :func:`repro.compat.shard_map` when ``jax_mesh`` is
    given (one device per mesh slot), else a sequential jnp loop over the
    shard index on this host — the same segment arithmetic, so the two
    lowerings agree bitwise.  A 1-shard model axis does not re-derive
    anything: the per-device body is ``base_forward`` — the very forward the
    unsharded plan builders produced — which is what makes the single-device
    path the 1×1-mesh special case rather than a parallel code path.
    """
    if model == 1 and base_forward is None:
        raise ValueError("model=1 requires the base (unsharded) forward")

    if model == 1:
        if jax_mesh is None:
            return jax.jit(base_forward) if jit else base_forward
        from jax.sharding import PartitionSpec as P

        fn = compat_shard_map(base_forward, jax_mesh,
                              in_specs=P("data", None),
                              out_specs=P("data", None))
        return jax.jit(fn) if jit else fn

    segments = list(segments)
    arrs = []
    for seg in segments:
        arrs.extend([jnp.asarray(seg.rows), jnp.asarray(seg.cols),
                     jnp.asarray(seg.blocks), jnp.asarray(seg.bias)])

    if jax_mesh is not None:
        from jax.sharding import PartitionSpec as P

        def device_fn(x, *flat):
            h = x
            for k, seg in enumerate(segments):
                rows, cols, blocks, bias = flat[4 * k:4 * k + 4]
                y = _shard_layer(h, seg, rows[0], cols[0], blocks[0], bias[0])
                g = jax.lax.all_gather(y, "model")
                h = _reassemble(g, seg)
            return h

        fn = compat_shard_map(
            device_fn, jax_mesh,
            in_specs=(P("data", None),) + (P("model"),) * len(arrs),
            out_specs=P("data", None),
        )

        def forward(x):
            return fn(x, *arrs)

        return jax.jit(forward) if jit else forward

    def forward_loop(x):
        h = x
        for k, seg in enumerate(segments):
            rows, cols, blocks, bias = arrs[4 * k:4 * k + 4]
            ys = [_shard_layer(h, seg, rows[s], cols[s], blocks[s], bias[s])
                  for s in range(model)]
            h = _reassemble(jnp.stack(ys), seg)
        return h

    return jax.jit(forward_loop) if jit else forward_loop
