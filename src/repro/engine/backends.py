"""Execution backends for compiled plans.

Three ways to run the same schedule:

  * ``pallas``    — the scheduled Pallas TPU kernel (``kernels/bsr_matmul``),
                    compiled; the production path.
  * ``interpret`` — the identical Pallas body run in interpret mode; exact
                    kernel semantics on any host (the correctness path).
  * ``jnp``       — a pure-``jnp`` lowering of the schedule (gather blocks →
                    batched block matmul → segment-sum by output tile); runs
                    fast on CPU/GPU and is fully jittable.

All three consume the same ``CompiledSchedule`` arrays, so the connection
order — the thing the paper is about — is identical across backends; only the
machinery that walks it differs.  ``auto`` resolves to ``pallas`` on TPU and
``jnp`` elsewhere.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.blocksparse import BSRLayer
from repro.kernels.bsr_matmul import bsr_matmul
from repro.kernels.ops import CompiledSchedule

BACKENDS = ("pallas", "interpret", "jnp")


def resolve_backend(name: str) -> str:
    """Resolve ``auto`` (and validate) to a concrete backend name."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; pick from {('auto',) + BACKENDS}")
    return name


def _jnp_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
) -> jnp.ndarray:
    """One layer of the schedule as gather → block matmul → segment-sum.

    Accumulates in float32 (like the kernel's VMEM accumulator) and walks the
    blocks in schedule order, so the arithmetic is the schedule's.
    """
    B = x.shape[0]
    bm, bn = layer.block_m, layer.block_n
    grid_in, grid_out = layer.grid_in, layer.grid_out
    xt = x.reshape(B, grid_in, bm).transpose(1, 0, 2)          # [gi, B, bm]
    gathered = jnp.take(xt, schedule.rows, axis=0)             # [nnz, B, bm]
    contrib = jnp.einsum(
        "gbm,gmn->gbn",
        gathered.astype(jnp.float32),
        schedule.blocks.astype(jnp.float32),
    )                                                          # [nnz, B, bn]
    y = jax.ops.segment_sum(contrib, schedule.cols,
                            num_segments=grid_out)             # [go, B, bn]
    y = y.transpose(1, 0, 2).reshape(B, grid_out * bn)
    y = y + jnp.asarray(layer.bias).astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(x.dtype)


def _pallas_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
    interpret: bool,
) -> jnp.ndarray:
    return bsr_matmul(
        x,
        schedule.blocks,
        schedule.rows,
        schedule.cols,
        schedule.first,
        schedule.last,
        jnp.asarray(layer.bias),
        grid_out=schedule.grid_out,
        activation=activation,
        interpret=interpret,
    )


def make_forward(
    layers: Sequence[BSRLayer],
    schedules: Sequence[CompiledSchedule],
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
) -> Callable:
    """Build the whole-network forward for one backend: x [B, n_in] -> [B, n_out].

    The per-layer loop is unrolled at trace time, so the chain of layers —
    including every activation epilogue — fuses into one compiled program:
    one dispatch per request instead of one per layer.
    """
    layers = list(layers)
    schedules = list(schedules)
    activations = list(activations)

    def forward(x):
        h = x
        for layer, schedule, act in zip(layers, schedules, activations):
            if backend == "jnp":
                h = _jnp_layer(h, layer, schedule, act)
            else:
                h = _pallas_layer(h, layer, schedule, act,
                                  interpret=(backend == "interpret"))
        return h

    return jax.jit(forward) if jit else forward
