"""Execution backends for compiled plans.

Three ways to run the same flat cross-layer schedule:

  * ``pallas``    — the whole-network Pallas megakernel
                    (``kernels/bsr_matmul.bsr_megakernel``): ONE grid over
                    every nonzero block of every layer, hidden state resident
                    in VMEM across layer boundaries; the production path.
  * ``interpret`` — the identical megakernel body run in interpret mode;
                    exact kernel semantics on any host (the correctness path).
  * ``jnp``       — a pure-``jnp`` lowering of the same flat schedule: one
                    gather → batched block matmul → segment-sum pass per
                    layer segment of the flat arrays; runs fast on CPU/GPU
                    and is fully jittable.

All three consume the same ``FlatSchedule`` arrays, so the connection order —
the thing the paper is about — is identical across backends; only the
machinery that walks it differs.  ``auto`` resolves to ``pallas`` on TPU and
``jnp`` elsewhere.

Nets whose tile shapes cannot be flattened (non-uniform block sizes) fall
back to the per-layer dispatch path (``make_forward``), which is also what
``benchmarks/bench_engine.py`` uses as the layered baseline.

The TPU kernels tile the batch dimension, so ``B`` is padded up to the
sublane multiple of the dtype before a ``pallas``/``interpret`` launch and
the result is sliced back — odd batch sizes work on every backend.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.blocksparse import BSRLayer
from repro.kernels.bsr_matmul import bsr_matmul, bsr_megakernel
from repro.kernels.ops import CompiledSchedule, FlatSchedule

BACKENDS = ("pallas", "interpret", "jnp")


def resolve_backend(name: str) -> str:
    """Resolve ``auto`` (and validate) to a concrete backend name."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; pick from {('auto',) + BACKENDS}")
    return name


def sublane_multiple(dtype) -> int:
    """Minimum TPU sublane count for ``dtype`` (second-to-last dim tiling)."""
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize == 2:
        return 16
    if itemsize == 1:
        return 32
    return 8


def pad_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pad the batch dim up to the sublane multiple (TPU tiling constraint)."""
    B = x.shape[0]
    m = sublane_multiple(x.dtype)
    pad = (-B) % m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


# --------------------------------------------------------------------------- #
# per-layer dispatch (layered baseline + fallback for non-uniform tiles)
# --------------------------------------------------------------------------- #

def _jnp_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
) -> jnp.ndarray:
    """One layer of the schedule as gather → block matmul → segment-sum.

    Accumulates in float32 (like the kernel's VMEM accumulator) and walks the
    blocks in schedule order, so the arithmetic is the schedule's.
    """
    return _jnp_segment(
        x, schedule.rows, schedule.cols, schedule.blocks,
        jnp.asarray(layer.bias), layer.block_m, layer.block_n,
        layer.grid_in, layer.grid_out, activation,
    )


def _jnp_segment(
    x: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    blocks: jnp.ndarray,
    bias: jnp.ndarray,
    bm: int,
    bn: int,
    grid_in: int,
    grid_out: int,
    activation: Optional[Callable],
) -> jnp.ndarray:
    B = x.shape[0]
    xt = x.reshape(B, grid_in, bm).transpose(1, 0, 2)          # [gi, B, bm]
    gathered = jnp.take(xt, rows, axis=0)                      # [nnz, B, bm]
    contrib = jnp.einsum(
        "gbm,gmn->gbn",
        gathered.astype(jnp.float32),
        blocks.astype(jnp.float32),
    )                                                          # [nnz, B, bn]
    y = jax.ops.segment_sum(contrib, cols,
                            num_segments=grid_out)             # [go, B, bn]
    y = y.transpose(1, 0, 2).reshape(B, grid_out * bn)
    y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(x.dtype)


def _pallas_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
    interpret: bool,
) -> jnp.ndarray:
    return bsr_matmul(
        x,
        schedule.blocks,
        schedule.rows,
        schedule.cols,
        schedule.first,
        schedule.last,
        jnp.asarray(layer.bias),
        grid_out=schedule.grid_out,
        activation=activation,
        interpret=interpret,
    )


def make_forward(
    layers: Sequence[BSRLayer],
    schedules: Sequence[CompiledSchedule],
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
) -> Callable:
    """Per-layer dispatch forward: x [B, n_in] -> [B, n_out].

    One ``pallas_call`` (or jnp pass) per layer inside one jitted program —
    the PR-1 call pattern, kept as the layered baseline the megakernel is
    benchmarked against and as the fallback for nets the flat schedule
    cannot express (non-uniform tile sizes).
    """
    layers = list(layers)
    schedules = list(schedules)
    activations = list(activations)

    def forward(x):
        B = x.shape[0]
        h = x
        if backend != "jnp":
            h = pad_batch(h)
        for layer, schedule, act in zip(layers, schedules, activations):
            if backend == "jnp":
                h = _jnp_layer(h, layer, schedule, act)
            else:
                h = _pallas_layer(h, layer, schedule, act,
                                  interpret=(backend == "interpret"))
        return h[:B]

    return jax.jit(forward) if jit else forward


# --------------------------------------------------------------------------- #
# fused dispatch: the whole net as one flat schedule
# --------------------------------------------------------------------------- #

def make_fused_forward(
    layers: Sequence[BSRLayer],
    flat: FlatSchedule,
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
) -> Callable:
    """Whole-network fused forward over one ``FlatSchedule``.

    ``pallas``/``interpret``: a single ``bsr_megakernel`` dispatch — one grid
    for all layers, hidden state in VMEM end to end.  ``jnp``: the identical
    flat arrays consumed segment-by-segment (segment views are materialized
    once here, outside the trace, so no per-call slicing of the big block
    array survives into the compiled program).
    """
    layers = list(layers)
    activations = list(activations)
    hidden = set(activations[:-1])
    if len(hidden) > 1:
        raise ValueError(
            "the megakernel fuses ONE hidden-layer activation; got "
            f"{len(hidden)} distinct hidden epilogues — use fuse=False "
            "(per-layer dispatch) for heterogeneous activations"
        )
    act = activations[0] if len(activations) > 1 else None
    fact = activations[-1]

    if backend == "jnp":
        bs = flat.block
        segs = []
        bias_row = 0
        for k, (s, e) in enumerate(flat.segments):
            lay = layers[k]
            bias = flat.bias_tiles[bias_row:bias_row + lay.grid_out] \
                .reshape(-1)
            segs.append((flat.rows[s:e], flat.cols[s:e], flat.blocks[s:e],
                         bias, lay.grid_in, lay.grid_out, activations[k]))
            bias_row += lay.grid_out

        def forward_jnp(x):
            h = x
            for rows, cols, blocks, bias, gi, go, a in segs:
                h = _jnp_segment(h, rows, cols, blocks, bias,
                                 bs, bs, gi, go, a)
            return h

        return jax.jit(forward_jnp) if jit else forward_jnp

    def forward(x):
        B = x.shape[0]
        xp = pad_batch(x)
        y = bsr_megakernel(
            xp, flat.blocks, flat.rows, flat.cols, flat.first, flat.last,
            flat.layer_id, flat.hbm_row, flat.out_tile, flat.bias_idx,
            flat.bias_tiles,
            n_layers=flat.n_layers,
            block=flat.block,
            grid_out_final=flat.grid_out_final,
            hidden_tiles=flat.hidden_tiles,
            activation=act,
            final_activation=fact,
            interpret=(backend == "interpret"),
        )
        return y[:B]

    return jax.jit(forward) if jit else forward
