"""Execution backends for compiled plans.

Three ways to run the same flat cross-layer schedule:

  * ``pallas``    — the whole-network Pallas megakernel
                    (``kernels/bsr_matmul.bsr_megakernel``): ONE grid over
                    every nonzero block of every layer, hidden state resident
                    in VMEM across layer boundaries; the production path.
  * ``interpret`` — the identical megakernel body run in interpret mode;
                    exact kernel semantics on any host (the correctness path).
  * ``jnp``       — a pure-``jnp`` lowering of the same flat schedule: one
                    gather → batched block matmul → segment-sum pass per
                    layer segment of the flat arrays; runs fast on CPU/GPU
                    and is fully jittable.

All three consume the same ``FlatSchedule`` arrays, so the connection order —
the thing the paper is about — is identical across backends; only the
machinery that walks it differs.  ``auto`` resolves to ``pallas`` on TPU and
``jnp`` elsewhere.

Nets whose tile shapes cannot be flattened (non-uniform block sizes) fall
back to the per-layer dispatch path (``make_forward``), which is also what
``benchmarks/bench_engine.py`` uses as the layered baseline.

The TPU kernels tile the batch dimension, so ``B`` is padded up to the
sublane multiple of the dtype before a ``pallas``/``interpret`` launch and
the result is sliced back — odd batch sizes work on every backend.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map as compat_shard_map
from repro.core.blocksparse import BSRLayer
from repro.kernels.bsr_matmul import bsr_matmul, bsr_megakernel
from repro.kernels.ops import CompiledSchedule, FlatSchedule

BACKENDS = ("pallas", "interpret", "jnp")


def resolve_backend(name: str) -> str:
    """Resolve ``auto`` (and validate) to a concrete backend name."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; pick from {('auto',) + BACKENDS}")
    return name


def sublane_multiple(dtype) -> int:
    """Minimum TPU sublane count for ``dtype`` (second-to-last dim tiling)."""
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize == 2:
        return 16
    if itemsize == 1:
        return 32
    return 8


def pad_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pad the batch dim up to the sublane multiple (TPU tiling constraint)."""
    B = x.shape[0]
    m = sublane_multiple(x.dtype)
    pad = (-B) % m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def tile_occupancy(
    h: jnp.ndarray,
    block: int,
    grid: int,
    valid: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Per-input-tile live-row counts of an activation: ``occ[t]`` is the
    number of batch rows with any nonzero in tile ``t``; a tile is *dead*
    (every consuming weight block skippable) exactly when ``occ[t] == 0``.

    ``valid`` ([B] bool) restricts the count to real batch rows — padded
    zero rows must be excluded from every batch-level reduction, because
    non-odd epilogues (sigmoid, gelu, softmax-style) turn them nonzero and
    would make dead tiles look live in the measured occupancy.  (Exclusion
    only ever *lowers* counts for rows whose outputs are sliced away, so it
    can never mark a tile dead that a real row needs.)
    """
    B = h.shape[0]
    live = h.reshape(B, grid, block) != 0
    if valid is not None:
        live = live & valid.reshape(B, 1, 1)
    return jnp.sum(jnp.any(live, axis=2), axis=0).astype(jnp.int32)


def activations_equal(a, b) -> bool:
    """Value-level equality for epilogue callables.

    Plain callables compare by identity (``==`` on functions), but
    ``functools.partial`` objects never do — two per-layer
    ``partial(leaky_relu, 0.1)`` instances are equal-but-distinct and used
    to silently lose the megakernel.  Compare partials structurally (same
    func, same bound args); anything unhashable/ambiguous in the bound args
    falls back to "not equal" rather than raising.
    """
    if a is b:
        return True
    if isinstance(a, functools.partial) and isinstance(b, functools.partial):
        try:
            return (activations_equal(a.func, b.func)
                    and bool(a.args == b.args)
                    and bool(a.keywords == b.keywords))
        except (TypeError, ValueError):
            return False
    try:
        return bool(a == b)
    except (TypeError, ValueError):
        return False


# --------------------------------------------------------------------------- #
# per-layer dispatch (layered baseline + fallback for non-uniform tiles)
# --------------------------------------------------------------------------- #

def _jnp_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
    occ: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One layer of the schedule as gather → block matmul → segment-sum.

    Accumulates in float32 (like the kernel's VMEM accumulator) and walks the
    blocks in schedule order, so the arithmetic is the schedule's.
    """
    return _jnp_segment(
        x, schedule.rows, schedule.cols, schedule.blocks,
        jnp.asarray(layer.bias), layer.block_m, layer.block_n,
        layer.grid_in, layer.grid_out, activation, occ=occ,
        scales=schedule.scales,
    )


def _jnp_segment(
    x: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    blocks: jnp.ndarray,
    bias: jnp.ndarray,
    bm: int,
    bn: int,
    grid_in: int,
    grid_out: int,
    activation: Optional[Callable],
    pad_segments: int = 0,
    occ: Optional[jnp.ndarray] = None,
    scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One schedule segment as gather → block matmul → segment-sum.

    ``pad_segments`` > 0 reserves that many trailing sink segments: schedule
    steps with ``cols >= grid_out`` land there and are dropped before the
    bias/activation epilogue.  The sharded forward pads every shard's
    schedule to a uniform length with steps routed to the sink, so padding
    never perturbs a real output tile (not even by adding 0.0).

    ``occ`` ([grid_in] int32, from :func:`tile_occupancy`) masks the gather:
    steps whose input tile is dead contribute a hard zero instead of their
    (already exactly-zero) tile values.  A dead tile's entries are all ±0,
    and ``(±0) * 0 = ±0`` preserves each bit pattern, so the masked segment
    is bit-identical to the unmasked one — the mask is how the jnp lowering
    *expresses* the skip an I/O-aware kernel would take.

    ``scales`` ([nnz] f32) marks a quantized weight stream: ``blocks`` is
    stored narrow (bf16/fp8) and dequantized here per block right before
    the einsum — the exact f32 values the megakernel's fused dequant
    produces, so quantized backends agree the same way f32 ones do.
    """
    B = x.shape[0]
    xt = x.reshape(B, grid_in, bm).transpose(1, 0, 2)          # [gi, B, bm]
    gathered = jnp.take(xt, rows, axis=0)                      # [nnz, B, bm]
    if occ is not None:
        gathered = gathered * (occ[rows] > 0).astype(
            gathered.dtype)[:, None, None]
    w = blocks.astype(jnp.float32)
    if scales is not None:
        w = w * scales[:, None, None]
    contrib = jnp.einsum(
        "gbm,gmn->gbn",
        gathered.astype(jnp.float32),
        w,
    )                                                          # [nnz, B, bn]
    y = jax.ops.segment_sum(contrib, cols,
                            num_segments=grid_out + pad_segments)
    if pad_segments:
        y = y[:grid_out]                                       # [go, B, bn]
    y = y.transpose(1, 0, 2).reshape(B, grid_out * bn)
    y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(x.dtype)


def _pallas_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: CompiledSchedule,
    activation: Optional[Callable],
    interpret: bool,
) -> jnp.ndarray:
    return bsr_matmul(
        x,
        schedule.blocks,
        schedule.rows,
        schedule.cols,
        schedule.first,
        schedule.last,
        jnp.asarray(layer.bias),
        grid_out=schedule.grid_out,
        activation=activation,
        interpret=interpret,
        scales=schedule.scales,
    )


def make_forward(
    layers: Sequence[BSRLayer],
    schedules: Sequence[CompiledSchedule],
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
    gate: bool = False,
) -> Callable:
    """Per-layer dispatch forward: x [B, n_in] -> [B, n_out].

    One ``pallas_call`` (or jnp pass) per layer inside one jitted program —
    the PR-1 call pattern, kept as the layered baseline the megakernel is
    benchmarked against and as the fallback for nets the flat schedule
    cannot express (non-uniform tile sizes).

    ``gate`` masks each layer's gather on runtime tile occupancy — honored
    on the ``jnp`` path only (the per-layer Pallas kernel has no occupancy
    predication; the engine records that on the plan's fallback reason).
    """
    layers = list(layers)
    schedules = list(schedules)
    activations = list(activations)
    gate = gate and backend == "jnp"

    def forward(x):
        B = x.shape[0]
        h = x
        if backend != "jnp":
            h = pad_batch(h)
        for layer, schedule, act in zip(layers, schedules, activations):
            if backend == "jnp":
                occ = tile_occupancy(h, layer.block_m, layer.grid_in) \
                    if gate else None
                h = _jnp_layer(h, layer, schedule, act, occ=occ)
            else:
                h = _pallas_layer(h, layer, schedule, act,
                                  interpret=(backend == "interpret"))
        return h[:B]

    return jax.jit(forward) if jit else forward


# --------------------------------------------------------------------------- #
# fused dispatch: the whole net as one flat schedule
# --------------------------------------------------------------------------- #

def _check_fusible_activations(activations: Sequence[Optional[Callable]]):
    """The megakernel fuses ONE hidden epilogue; equal-but-distinct
    callables (per-layer partials with the same bound args) count as one."""
    hidden = list(activations[:-1])
    distinct = sum(1 for a in hidden[1:] if not activations_equal(hidden[0], a))
    if distinct:
        raise ValueError(
            "the megakernel fuses ONE hidden-layer activation; got "
            f"{distinct + 1} distinct hidden epilogues — use fuse=False "
            "(per-layer dispatch) for heterogeneous activations"
        )


def _flat_segments(layers, flat: FlatSchedule, activations):
    """Materialize per-layer views of the flat arrays once, outside any
    trace, so no per-call slicing of the big block array survives into the
    compiled program (shared by the fused jnp forward and its instrumented
    measurement twin)."""
    segs = []
    bias_row = 0
    for k, (s, e) in enumerate(flat.segments):
        lay = layers[k]
        bias = flat.bias_tiles[bias_row:bias_row + lay.grid_out].reshape(-1)
        scales = None if flat.scales is None else flat.scales[s:e]
        segs.append((flat.rows[s:e], flat.cols[s:e], flat.blocks[s:e],
                     scales, bias, lay.grid_in, lay.grid_out,
                     activations[k]))
        bias_row += lay.grid_out
    return segs


def make_fused_forward(
    layers: Sequence[BSRLayer],
    flat: FlatSchedule,
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
    gate: bool = False,
) -> Callable:
    """Whole-network fused forward over one ``FlatSchedule``.

    ``pallas``/``interpret``: a single ``bsr_megakernel`` dispatch — one grid
    for all layers, hidden state in VMEM end to end.  ``jnp``: the identical
    flat arrays consumed segment-by-segment.

    ``gate`` turns on runtime tile-occupancy gating: every segment's gather
    (jnp) or grid step (megakernel) is predicated on its input tile holding
    any nonzero activation, skipping work that would contribute exactly
    zero — outputs stay bit-identical to the ungated forward.
    """
    layers = list(layers)
    activations = list(activations)
    _check_fusible_activations(activations)
    act = activations[0] if len(activations) > 1 else None
    fact = activations[-1]

    if backend == "jnp":
        bs = flat.block
        segs = _flat_segments(layers, flat, activations)

        def forward_jnp(x):
            h = x
            for rows, cols, blocks, scales, bias, gi, go, a in segs:
                occ = tile_occupancy(h, bs, gi) if gate else None
                h = _jnp_segment(h, rows, cols, blocks, bias,
                                 bs, bs, gi, go, a, occ=occ, scales=scales)
            return h

        return jax.jit(forward_jnp) if jit else forward_jnp

    grid_in0 = layers[0].grid_in

    def forward(x):
        B = x.shape[0]
        xp = pad_batch(x)
        kw = dict(
            n_layers=flat.n_layers,
            block=flat.block,
            grid_out_final=flat.grid_out_final,
            hidden_tiles=flat.hidden_tiles,
            activation=act,
            final_activation=fact,
            interpret=(backend == "interpret"),
        )
        kw["scales"] = flat.scales
        args = (xp, flat.blocks, flat.rows, flat.cols, flat.first,
                flat.last, flat.layer_id, flat.hbm_row, flat.out_tile,
                flat.bias_idx, flat.bias_tiles)
        if gate:
            # layer-0 occupancy over the UNPADDED rows (pad rows are zero
            # anyway there, but valid_b also scopes the kernel's own
            # hidden-layer occupancy counts to real rows)
            occ0 = tile_occupancy(x, flat.block, grid_in0)
            y, _ = bsr_megakernel(*args, occ0=occ0, gate=True, valid_b=B,
                                  **kw)
        else:
            y = bsr_megakernel(*args, **kw)
        return y[:B]

    return jax.jit(forward) if jit else forward


def make_fused_measure(
    layers: Sequence[BSRLayer],
    flat: FlatSchedule,
    activations: Sequence[Optional[Callable]],
    backend: str,
    jit: bool = True,
) -> Callable:
    """Instrumented gated fused forward: ``x -> (y, occs)``.

    ``occs[k]`` ([grid_in_k] int32) is the live-row count per input tile of
    layer ``k`` — the exact counts the gated forward's predicates consumed
    (the jnp lowering recomputes them identically; the kernel lowering reads
    layer 0's from the same ``tile_occupancy`` and layers ≥ 1 from the
    megakernel's own occupancy output, so the kernel's padded-row masking is
    observable from the outside).  ``ExecutionPlan.measure_dynamic`` turns
    these into the measured dynamic I/O report.
    """
    layers = list(layers)
    activations = list(activations)
    _check_fusible_activations(activations)
    act = activations[0] if len(activations) > 1 else None
    fact = activations[-1]
    bs = flat.block

    if backend == "jnp":
        segs = _flat_segments(layers, flat, activations)

        def measure_jnp(x):
            h = x
            occs = []
            for rows, cols, blocks, scales, bias, gi, go, a in segs:
                occ = tile_occupancy(h, bs, gi)
                occs.append(occ)
                h = _jnp_segment(h, rows, cols, blocks, bias,
                                 bs, bs, gi, go, a, occ=occ, scales=scales)
            return h, tuple(occs)

        return jax.jit(measure_jnp) if jit else measure_jnp

    grid_ins = [lay.grid_in for lay in layers]

    def measure(x):
        B = x.shape[0]
        occ0 = tile_occupancy(x, bs, grid_ins[0])
        xp = pad_batch(x)
        y, occ = bsr_megakernel(
            xp, flat.blocks, flat.rows, flat.cols, flat.first, flat.last,
            flat.layer_id, flat.hbm_row, flat.out_tile, flat.bias_idx,
            flat.bias_tiles, occ0=occ0, scales=flat.scales,
            n_layers=flat.n_layers,
            block=flat.block,
            grid_out_final=flat.grid_out_final,
            hidden_tiles=flat.hidden_tiles,
            activation=act,
            final_activation=fact,
            interpret=(backend == "interpret"),
            gate=True,
            valid_b=B,
        )
        occs = (occ0,) + tuple(occ[k, :grid_ins[k + 1]]
                               for k in range(flat.n_layers - 1))
        return y[:B], occs

    return jax.jit(measure) if jit else measure


# --------------------------------------------------------------------------- #
# sharded dispatch: per-shard segments + an activation gather per boundary
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ShardedSegment:
    """One layer's schedule arrays stacked over the model-axis shards.

    Every shard's schedule is padded to a uniform step count (``shard_map``
    needs equal per-device shapes); padded steps carry zero blocks and route
    to the sink segment (``cols == tps``), so they touch no real output tile.
    ``perm[t]`` maps the layer's canonical output tile ``t`` to its flat
    ``shard * tps + local_pos`` position in the all-gathered activation.
    """

    rows: np.ndarray          # int32 [model, n_max] input tile (full grid)
    cols: np.ndarray          # int32 [model, n_max] local output tile or sink
    blocks: np.ndarray        # [model, n_max, bm, bn] in the storage dtype
    bias: np.ndarray          # float32 [model, tps * bn]
    perm: np.ndarray          # int32 [grid_out_full]
    grid_in: int              # full input grid of this layer
    tps: int                  # output tiles per shard
    block_m: int              # input-tile size
    block_n: int              # output-tile size
    activation: Optional[Callable]
    # quantized weight stream: per-block f32 dequant scales (None for f32;
    # padded sink steps carry scale 1.0 so they dequantize to exact zero)
    scales: Optional[np.ndarray] = None   # float32 [model, n_max]


def _shard_layer(h, seg: ShardedSegment, rows, cols, blocks, bias,
                 occ=None, scales=None):
    """One shard's slice of one layer over the full gathered activation."""
    return _jnp_segment(h, rows, cols, blocks, bias, seg.block_m, seg.block_n,
                        seg.grid_in, seg.tps, seg.activation, pad_segments=1,
                        occ=occ, scales=scales)


def _reassemble(gathered, seg: ShardedSegment):
    """[model, B, tps*bn] shard outputs -> [B, full] canonical tile order."""
    m, B, _ = gathered.shape
    tiles = gathered.reshape(m, B, seg.tps, seg.block_n).transpose(0, 2, 1, 3)
    tiles = tiles.reshape(m * seg.tps, B, seg.block_n)
    tiles = jnp.take(tiles, jnp.asarray(seg.perm), axis=0)
    return tiles.transpose(1, 0, 2).reshape(B, -1)


def make_sharded_forward(
    segments: Sequence[ShardedSegment],
    model: int,
    data: int,
    jax_mesh=None,
    base_forward: Optional[Callable] = None,
    jit: bool = True,
    gate: bool = False,
) -> Callable:
    """Collective forward over a model×data mesh: x [B, n_in] -> [B, n_out].

    Per layer, each model shard computes its owned output tiles from the
    full (gathered) previous activation, then an all-gather + tile
    permutation reassembles the full hidden state for the next layer.  The
    batch dim is split over ``data`` (``B`` must be divisible by it — the
    plan wrapper pads).

    Lowering: through :func:`repro.compat.shard_map` when ``jax_mesh`` is
    given (one device per mesh slot), else a sequential jnp loop over the
    shard index on this host — the same segment arithmetic, so the two
    lowerings agree bitwise.  A 1-shard model axis does not re-derive
    anything: the per-device body is ``base_forward`` — the very forward the
    unsharded plan builders produced — which is what makes the single-device
    path the 1×1-mesh special case rather than a parallel code path.

    With ``gate=True`` and ``model > 1`` the forward takes ``(x, valid)``:
    ``valid`` ([B] bool) marks the real batch rows, because the sharded plan
    pads the batch to the data-axis multiple *outside* this trace, and
    occupancy must be computed over real rows only.  Every shard computes
    the same occupancy from the same gathered activation, so gating composes
    with per-shard schedules without any extra collective.  (``model == 1``
    keeps the ``(x)`` signature: the base forward gates internally.)
    """
    if model == 1 and base_forward is None:
        raise ValueError("model=1 requires the base (unsharded) forward")

    if model == 1:
        if jax_mesh is None:
            return jax.jit(base_forward) if jit else base_forward
        from jax.sharding import PartitionSpec as P

        fn = compat_shard_map(base_forward, jax_mesh,
                              in_specs=P("data", None),
                              out_specs=P("data", None))
        return jax.jit(fn) if jit else fn

    segments = list(segments)
    quant = any(seg.scales is not None for seg in segments)
    stride = 5 if quant else 4
    arrs = []
    for seg in segments:
        arrs.extend([jnp.asarray(seg.rows), jnp.asarray(seg.cols),
                     jnp.asarray(seg.blocks), jnp.asarray(seg.bias)])
        if quant:
            arrs.append(jnp.asarray(seg.scales))

    if jax_mesh is not None:
        from jax.sharding import PartitionSpec as P

        def device_fn(x, valid, *flat):
            h = x
            for k, seg in enumerate(segments):
                vals = flat[stride * k:stride * k + stride]
                rows, cols, blocks, bias = vals[:4]
                scales = vals[4][0] if quant else None
                occ = tile_occupancy(h, seg.block_m, seg.grid_in,
                                     valid=valid) if gate else None
                y = _shard_layer(h, seg, rows[0], cols[0], blocks[0],
                                 bias[0], occ=occ, scales=scales)
                g = jax.lax.all_gather(y, "model")
                h = _reassemble(g, seg)
            return h

        if gate:
            fn = compat_shard_map(
                device_fn, jax_mesh,
                in_specs=(P("data", None), P("data"))
                + (P("model"),) * len(arrs),
                out_specs=P("data", None),
            )

            def forward(x, valid):
                return fn(x, valid, *arrs)
        else:
            def device_fn_ungated(x, *flat):
                return device_fn(x, None, *flat)

            fn = compat_shard_map(
                device_fn_ungated, jax_mesh,
                in_specs=(P("data", None),) + (P("model"),) * len(arrs),
                out_specs=P("data", None),
            )

            def forward(x):
                return fn(x, *arrs)

        return jax.jit(forward) if jit else forward

    def forward_loop(x, valid=None):
        h = x
        for k, seg in enumerate(segments):
            vals = arrs[stride * k:stride * k + stride]
            rows, cols, blocks, bias = vals[:4]
            scales = vals[4] if quant else None
            # one occupancy per layer: every shard reads the same gathered
            # activation, so the mask is shared across the shard loop
            occ = tile_occupancy(h, seg.block_m, seg.grid_in,
                                 valid=valid) if gate else None
            ys = [_shard_layer(h, seg, rows[s], cols[s], blocks[s], bias[s],
                               occ=occ,
                               scales=None if scales is None else scales[s])
                  for s in range(model)]
            h = _reassemble(jnp.stack(ys), seg)
        return h

    if not gate:
        def forward_ungated(x):
            return forward_loop(x)
        return jax.jit(forward_ungated) if jit else forward_ungated
    return jax.jit(forward_loop) if jit else forward_loop
