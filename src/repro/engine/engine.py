"""The fused multi-layer sparse inference engine.

The paper's headline numbers come from executing one 2-optimal connection
schedule over the *whole* network — not from dispatching layer-by-layer.
``Engine`` is that idea as an API:

    engine = Engine(reorder=True)
    plan = engine.compile(layers)        # offline: schedule + CR + lowering
    y = plan(x)                          # online: one fused jitted program
    print(plan.io.summary())             # predicted I/O vs Theorem-1 bounds

``compile`` builds the block DAG of all layers, takes the Theorem-1
(grouped-by-output) order, optionally improves it with Connection Reordering
over the *entire* DAG (so the annealer can trade locality across layer
boundaries), re-groups the result into the kernel-compatible 2-optimal
family, validates/packs per-layer schedule arrays, and lowers everything into
a single jitted forward for the chosen backend.  Plans are cached: compiling
the same layers with the same settings returns the same plan object.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.blocksparse import (
    BlockFFNN,
    BSRLayer,
    regroup_by_output,
    schedule_arrays,
    to_block_ffnn,
)
from repro.core.bounds import theorem1_bounds
from repro.core.graph import drop_isolated
from repro.core.iosim import simulate
from repro.core.reorder import connection_reordering
from repro.kernels.ops import (
    compile_flat_schedule,
    compile_schedule,
    resolve_weight_dtype,
)
from repro.models.common import ACTIVATIONS as _MODEL_ACTIVATIONS
from repro.obs.trace import NULL_TRACER

from .backends import (
    make_forward,
    make_fused_forward,
    make_fused_measure,
    resolve_backend,
)
from .plan import ExecutionPlan, IOReport
from .sharding import Mesh, ShardedExecutionPlan, build_sharded_plan

# name -> activation callable (None = identity / linear output); extends the
# shared model registry rather than duplicating it.
ACTIVATIONS: Dict[Optional[str], Optional[Callable]] = {
    None: None,
    "none": None,
    "linear": None,
    "tanh": jax.numpy.tanh,
    "sigmoid": jax.nn.sigmoid,
    **_MODEL_ACTIVATIONS,
}


def _resolve_activation(act) -> Optional[Callable]:
    if act is None or callable(act):
        return act
    try:
        return ACTIVATIONS[act]
    except KeyError:
        raise ValueError(
            f"unknown activation {act!r}; pick from "
            f"{sorted(k for k in ACTIVATIONS if isinstance(k, str))} "
            "or pass a callable"
        ) from None


@dataclasses.dataclass
class Engine:
    """Compile-once/run-many driver for scheduled block-sparse inference.

    Args:
      backend: ``auto`` | ``pallas`` | ``interpret`` | ``jnp``.  ``auto``
        picks the Pallas TPU kernel on TPU hosts and the pure-``jnp``
        lowering elsewhere, so the same engine code runs (and is testable)
        on any machine.
      activation: epilogue fused into every layer but the last (name or
        callable or None).  A list/tuple gives each *hidden* layer its own
        epilogue (length must be ``len(layers) - 1``); the megakernel fuses
        only when all hidden epilogues compare equal (``functools.partial``
        instances are compared structurally), otherwise the plan falls back
        to layered dispatch and records why in ``plan.fallback_reason``.
      final_activation: epilogue of the last layer (default linear).
      reorder: run Connection Reordering over the whole block DAG.
      M_tiles: VMEM budget (in tiles) used as the CR objective and for the
        plan's I/O report; 3 matches the kernel's single-resident-tile model.
      reorder_iters / seed: annealing budget and RNG seed.
      max_move_span: cap on how far an annealer proposal may carry any
        connection (None = the paper's unbounded nearest-dependency scan).
        On 10k+-block DAGs a cap keeps the incremental delta evaluator's
        changed window small; schedule-affecting, so it is part of the plan
        cache key.
      policy: eviction policy for the simulated I/O report.
      fuse: lower the whole net into ONE flat cross-layer dispatch (the
        Pallas megakernel on pallas/interpret; one segment pass on jnp) with
        the hidden state VMEM-resident across layer boundaries.  Nets whose
        tile shapes cannot be flattened (non-uniform block sizes) silently
        fall back to per-layer dispatch; ``fuse=False`` forces that layered
        path.
      gate: runtime tile-occupancy gating.  The compiled forward computes a
        per-batch nonzero-tile bitmap over each activation and skips the
        weight blocks whose input tile is dead for the whole batch — the
        jnp lowering masks its gather/einsum, the megakernel predicates the
        matching grid steps (no-op steps still advance the double-buffered
        weight stream).  Bit-exact with the ungated forward; gated plans
        additionally expose :meth:`ExecutionPlan.measure_dynamic`.
      weight_dtype: storage dtype of the streamed weight blocks —
        ``"f32"`` (default, bit-exact), ``"bf16"`` or ``"fp8"``.  Narrow
        modes quantize each scheduled block once at compile time with one
        f32 dequant scale per block and fuse dequant (``block * scale``)
        into every backend right before the dot, cutting the dominant
        weight-stream I/O 2x/4x at the identical schedule.  Quantized
        plans are not bit-exact vs f32 (bf16 agrees within ~1e-2 relative,
        fp8 within ~1e-1 — see ``docs/engine.md``), but all backends of
        one quantized plan dequantize to identical f32 values, so
        cross-backend agreement and ``safe_twin`` degradation behave
        exactly as in f32.  ``"fp8"`` raises a clear ``ValueError`` at
        compile time when ``ml_dtypes`` lacks ``float8_e4m3fn``.
    """

    backend: str = "auto"
    activation: Union[str, Callable, None] = "relu"
    final_activation: Union[str, Callable, None] = None
    reorder: bool = False
    M_tiles: int = 3
    reorder_iters: int = 2000
    seed: int = 0
    max_move_span: Optional[int] = None
    policy: str = "min"
    fuse: bool = True
    gate: bool = False
    weight_dtype: str = "f32"
    jit: bool = True
    # a repro.obs.Tracer recording compile-phase spans (Theorem-1 schedule,
    # CR/annealing, packing, backend lowering, I/O simulation).  Not part
    # of _plan_key — tracing never changes what gets compiled or cached.
    tracer: Optional[object] = dataclasses.field(default=None, repr=False,
                                                 compare=False)
    _cache: Dict[Tuple, Union[ExecutionPlan, ShardedExecutionPlan]] = \
        dataclasses.field(default_factory=dict, repr=False)

    @property
    def _tr(self):
        tr = self.tracer
        return tr if tr is not None else NULL_TRACER

    # ------------------------------------------------------------------ #
    def compile(
        self,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        backend: Optional[str] = None,
        mesh: Optional[Mesh] = None,
    ) -> Union[ExecutionPlan, ShardedExecutionPlan]:
        """Lower a whole network into one cached plan.

        Without ``mesh`` this is the single-device path: one whole-network
        :class:`ExecutionPlan`.  With ``mesh=Mesh(model, data)`` the block
        DAG is partitioned tile-parallel over ``model`` and the batch over
        ``data`` into a :class:`ShardedExecutionPlan` — each shard's
        schedule is built by the same ``_build`` the unsharded path uses
        (Theorem-1 order + independent Connection Reordering per shard),
        and ``Mesh(1, 1)`` shares the unsharded plan's forward outright.
        """
        bffnn = net if isinstance(net, BlockFFNN) else to_block_ffnn(list(net))
        backend = resolve_backend(backend or self.backend)
        key = self._plan_key(bffnn, backend) + self._mesh_key(mesh)
        plan = self._cache.get(key)
        if plan is not None:
            return plan
        if mesh is None:
            plan = self._build(bffnn, backend)
        else:
            plan = build_sharded_plan(self, bffnn, backend, mesh)
        self._cache[key] = plan
        return plan

    def compile_with_order(
        self,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        order: np.ndarray,
        backend: Optional[str] = None,
        io: Optional[IOReport] = None,
    ) -> ExecutionPlan:
        """Lower a network onto a *precomputed* whole-DAG connection order.

        This is the warm-start path of the plan store
        (``repro.serving.plancache``): the expensive offline steps —
        Theorem-1 grouping and Connection Reordering — are skipped entirely
        (``plan.annealer_iters == 0``); only validation, packing, and
        backend lowering run.  Passing a stored ``io`` report also skips the
        I/O re-simulation.  The rebuild is deterministic, so the resulting
        plan is bit-identical to the cold compile the order came from.
        """
        bffnn = net if isinstance(net, BlockFFNN) else to_block_ffnn(list(net))
        backend = resolve_backend(backend or self.backend)
        return self._build(bffnn, backend, order=np.asarray(order), io=io)

    def compile_sharded_with_orders(
        self,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        mesh: Mesh,
        orders: Sequence[np.ndarray],
        backend: Optional[str] = None,
        ios: Optional[Sequence[IOReport]] = None,
    ) -> ShardedExecutionPlan:
        """Sharded analogue of :meth:`compile_with_order`: rebuild a
        sharded plan from one *stored* per-shard connection order each —
        zero annealer iterations, deterministic, bit-identical to the cold
        compile the orders came from (the plan store's warm path)."""
        bffnn = net if isinstance(net, BlockFFNN) else to_block_ffnn(list(net))
        backend = resolve_backend(backend or self.backend)
        return build_sharded_plan(self, bffnn, backend, mesh,
                                  orders=list(orders), ios=ios)

    @staticmethod
    def _mesh_key(mesh: Optional[Mesh]) -> Tuple:
        return ("mesh", None) if mesh is None \
            else ("mesh", mesh.model, mesh.data)

    @staticmethod
    def _act_key(act):
        # plans (hence their activations) stay strongly referenced by the
        # cache, so object ids cannot be recycled while an entry is alive.
        if isinstance(act, (str, type(None))):
            return act
        if isinstance(act, (list, tuple)):
            return tuple(Engine._act_key(a) for a in act)
        if isinstance(act, functools.partial):
            try:
                kw = tuple(sorted(act.keywords.items()))
                key = ("partial", Engine._act_key(act.func), act.args, kw)
                hash(key)
                return key
            except TypeError:
                return id(act)
        return id(act)

    def _plan_key(self, bffnn: BlockFFNN, backend: str) -> Tuple:
        return (
            tuple(id(l) for l in bffnn.layers), backend,
            self._act_key(self.activation),
            self._act_key(self.final_activation),
            self.reorder, self.M_tiles, self.reorder_iters, self.seed,
            self.max_move_span, self.policy, self.fuse, self.gate,
            resolve_weight_dtype(self.weight_dtype), self.jit,
        )

    # ------------------------------------------------------------------ #
    def _build(self, bffnn: BlockFFNN, backend: str,
               order: Optional[np.ndarray] = None,
               io: Optional[IOReport] = None) -> ExecutionPlan:
        t0 = time.perf_counter()
        tr = self._tr
        layers = bffnn.layers
        # resolve up front: an unavailable fp8 fails here with a clear
        # ValueError, never a deep kernel TypeError
        wdt = resolve_weight_dtype(self.weight_dtype)
        annealer_iters = 0
        if order is None:
            order = self.schedule_order(bffnn)
            annealer_iters = self.reorder_iters if self.reorder else 0
        with tr.span("compile.pack", layers=len(layers)):
            schedules = []
            for k in range(len(layers)):
                perm, _, _, _, _ = schedule_arrays(bffnn, order, k)
                schedules.append(compile_schedule(layers[k], perm,
                                                  weight_dtype=wdt))

        if isinstance(self.activation, (list, tuple)):
            if len(self.activation) != len(layers) - 1:
                raise ValueError(
                    f"per-layer activation sequence has {len(self.activation)} "
                    f"entries but the net has {len(layers) - 1} hidden layers"
                )
            hidden = [_resolve_activation(a) for a in self.activation]
        else:
            hidden = [_resolve_activation(self.activation)] * (len(layers) - 1)
        fact = _resolve_activation(self.final_activation)
        activations: List[Optional[Callable]] = hidden + [fact]

        with tr.span("compile.lower", backend=backend,
                     gate=self.gate) as sp:
            flat = None
            fallback_reason: Optional[str] = None
            if self.fuse:
                try:
                    flat = compile_flat_schedule(layers, schedules)
                except ValueError as e:
                    flat = None  # non-uniform tiles: per-layer fallback
                    fallback_reason = str(e)
            measure = None
            if flat is not None:
                try:
                    forward = make_fused_forward(layers, flat, activations,
                                                 backend, jit=self.jit,
                                                 gate=self.gate)
                    if self.gate:
                        measure = make_fused_measure(layers, flat,
                                                     activations, backend,
                                                     jit=self.jit)
                except ValueError as e:
                    # e.g. heterogeneous hidden epilogues: the megakernel
                    # fuses exactly one — record why instead of failing
                    # silently.
                    flat = None
                    fallback_reason = str(e)
            if flat is None:
                forward = make_forward(layers, schedules, activations,
                                       backend, jit=self.jit, gate=self.gate)
                if self.gate and backend != "jnp":
                    note = ("occupancy gating inactive on the layered "
                            "pallas path")
                    fallback_reason = f"{fallback_reason}; {note}" \
                        if fallback_reason else note
            sp["fused"] = flat is not None
        if io is None:
            with tr.span("compile.io_report", policy=self.policy,
                         M_tiles=self.M_tiles):
                io = self.io_report(bffnn, order, schedules,
                                    fused=flat is not None)
        return ExecutionPlan(
            layers=list(layers),
            schedules=schedules,
            activations=activations,
            backend=backend,
            order=order,
            block_ffnn=bffnn,
            io=io,
            flat=flat,
            gate=self.gate,
            fallback_reason=fallback_reason,
            _forward=forward,
            _measure=measure,
            compile_s=time.perf_counter() - t0,
            annealer_iters=annealer_iters,
        )

    def schedule_order(self, bffnn: BlockFFNN) -> np.ndarray:
        """Whole-DAG connection order: Theorem-1 grouping, then optional CR
        re-grouped back into the kernel-compatible 2-optimal family."""
        tr = self._tr
        with tr.span("compile.theorem1") as sp:
            order = bffnn.net.theorem1_order()
            sp["connections"] = int(len(order))
        if self.reorder:
            with tr.span("compile.reorder", iters=self.reorder_iters,
                         M_tiles=self.M_tiles,
                         max_move_span=self.max_move_span):
                res = connection_reordering(
                    bffnn.net, order, M=self.M_tiles, policy=self.policy,
                    T=self.reorder_iters, seed=self.seed,
                    max_move_span=self.max_move_span,
                )
                order = regroup_by_output(bffnn.net, res.order)
        return order

    def io_report(self, bffnn: BlockFFNN, order: np.ndarray,
                  schedules: Optional[List] = None,
                  fused: bool = False) -> IOReport:
        """Exact simulated tile traffic of ``order`` next to Theorem 1.

        Theorem 1 assumes a connected FFNN, so isolated tiles (dead blocks
        left by pruning) are dropped from the analysis — connection indices
        are unaffected.  With per-layer ``schedules`` the report carries the
        per-dtype byte traffic of the weight stream (blocks + dequant
        scales, at the storage dtype); with ``fused=True`` it additionally
        carries the layered-dispatch traffic (each boundary round-trips the
        hidden state through HBM) so the fused plan's cross-layer savings
        are visible next to the Theorem-1 bounds."""
        net = drop_isolated(bffnn.net)
        sim = simulate(net, order, self.M_tiles, self.policy)
        layered_reads = layered_writes = 0
        hidden_tiles = hidden_bytes = 0
        weight_dtype = "f32"
        weight_bytes = scale_bytes = act_bytes = 0
        if schedules is not None:
            weight_dtype = schedules[0].weight_dtype
            weight_bytes = sum(s.weight_bytes for s in schedules)
            scale_bytes = sum(s.scale_bytes for s in schedules)
            # f32 activations crossing HBM per batch row: input + output
            # always; each layer boundary round-trips the hidden state only
            # on the layered path (the fused plan keeps it VMEM-resident)
            act_bytes = 4 * (bffnn.layers[0].n_in + bffnn.layers[-1].n_out)
            if not fused:
                act_bytes += sum(2 * lay.n_out * 4
                                 for lay in bffnn.layers[:-1])
        if schedules is not None and fused:
            layered_reads = sum(s.sim_reads for s in schedules)
            layered_writes = sum(s.sim_writes for s in schedules)
            for lay in bffnn.layers[:-1]:
                hidden_tiles += lay.grid_out
                # one write out plus one read back avoided per feature
                hidden_bytes += 2 * lay.n_out * 4
        return IOReport(
            simulated=sim,
            bounds=theorem1_bounds(net),
            M_tiles=self.M_tiles,
            policy=self.policy,
            layered_reads=layered_reads,
            layered_writes=layered_writes,
            hidden_tiles_kept=hidden_tiles,
            hidden_bytes_kept_per_row=hidden_bytes,
            weight_dtype=weight_dtype,
            weight_bytes_streamed=weight_bytes,
            scale_bytes_streamed=scale_bytes,
            activation_bytes_per_row=act_bytes,
        )
