"""Fused multi-layer sparse inference engine (compile once, run many).

    from repro.engine import Engine

    plan = Engine(reorder=True).compile(layers)
    y = plan(x)
    print(plan.describe())
"""

from .backends import (
    BACKENDS,
    make_forward,
    make_fused_forward,
    pad_batch,
    resolve_backend,
)
from .engine import ACTIVATIONS, Engine
from .plan import ExecutionPlan, IOReport

__all__ = [
    "ACTIVATIONS",
    "BACKENDS",
    "Engine",
    "ExecutionPlan",
    "IOReport",
    "make_forward",
    "make_fused_forward",
    "pad_batch",
    "resolve_backend",
]
