"""Fused multi-layer sparse inference engine (compile once, run many).

    from repro.engine import Engine, Mesh

    plan = Engine(reorder=True).compile(layers)
    y = plan(x)
    print(plan.describe())

    sharded = Engine().compile(layers, mesh=Mesh(model=4, data=2))
    y = sharded(x)                      # same function, partitioned
    print(sharded.io_report().summary())
"""

from .backends import (
    BACKENDS,
    activations_equal,
    make_forward,
    make_fused_forward,
    make_fused_measure,
    make_sharded_forward,
    pad_batch,
    resolve_backend,
    tile_occupancy,
)
from .engine import ACTIVATIONS, Engine
from .plan import DynamicIOReport, ExecutionPlan, IOReport
from .sharding import (
    Mesh,
    ShardedExecutionPlan,
    ShardedIOReport,
    partition_model,
)

__all__ = [
    "ACTIVATIONS",
    "BACKENDS",
    "DynamicIOReport",
    "Engine",
    "ExecutionPlan",
    "IOReport",
    "Mesh",
    "ShardedExecutionPlan",
    "ShardedIOReport",
    "activations_equal",
    "make_forward",
    "make_fused_forward",
    "make_fused_measure",
    "make_sharded_forward",
    "pad_batch",
    "partition_model",
    "resolve_backend",
    "tile_occupancy",
]
