"""Execution plans: the compile-once/run-many artifact of ``Engine.compile``.

A plan owns everything derived offline from a ``BlockFFNN``:

  * the whole-network connection order (Theorem-1 grouped, optionally
    Connection-Reordered) and its per-layer kernel schedules;
  * the fused per-layer activation epilogues;
  * a jitted forward function for the chosen backend;
  * an :class:`IOReport` — the exact simulated tile traffic of the compiled
    order next to the Theorem-1 bounds it must sit inside.

Calling the plan runs inference; nothing is re-derived per call.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.blocksparse import BlockFFNN, BSRLayer
from repro.core.bounds import Bounds
from repro.core.iosim import IOStats
from repro.kernels.ops import CompiledSchedule, FlatSchedule


@dataclasses.dataclass(frozen=True)
class DynamicIOReport:
    """Measured dynamic I/O of one gated forward on one concrete batch.

    The static Theorem-1 schedule reads every scheduled weight block; a
    gated forward only *consumes* the blocks whose input tile held a nonzero
    activation for some real batch row.  ``per_layer_dynamic[k]`` counts the
    scheduled layer-``k`` blocks that survived gating (the dynamic I/O a
    demand-driven weight stream pays), next to the full
    ``per_layer_static[k]`` schedule length; the per-block lower bound of
    any schedule is the dynamic count itself, since each surviving block
    must stream at least once.  Occupancy fields describe *why*:
    ``per_layer_live_tiles[k]`` of ``per_layer_in_tiles[k]`` input tiles
    were live, ``per_layer_row_occupancy[k]`` is the mean live-row fraction
    per tile, and ``per_layer_hist[k]`` buckets tiles by live-row fraction
    as ``(dead, (0,.25), [.25,.5), [.5,.75), [.75,1])``.

    Counts are computed over *real* batch rows only — engine batch padding
    is excluded, so sigmoid-style epilogues turning padded zero rows
    nonzero cannot make a dead tile look live.
    """

    batch: int
    per_layer_static: Tuple[int, ...]
    per_layer_dynamic: Tuple[int, ...]
    per_layer_in_tiles: Tuple[int, ...]
    per_layer_live_tiles: Tuple[int, ...]
    per_layer_row_occupancy: Tuple[float, ...]
    per_layer_hist: Tuple[Tuple[int, int, int, int, int], ...]
    # byte accounting: bytes one weight block (plus its dequant scale when
    # quantized) costs in the storage dtype — turns the block counts above
    # into the byte traffic a demand-driven stream actually pays.  0 in
    # reports persisted before byte accounting existed.
    bytes_per_block: int = 0
    weight_dtype: str = "f32"

    @property
    def static_total(self) -> int:
        return sum(self.per_layer_static)

    @property
    def dynamic_total(self) -> int:
        return sum(self.per_layer_dynamic)

    @property
    def blocks_skipped(self) -> int:
        return self.static_total - self.dynamic_total

    @property
    def dynamic_weight_bytes(self) -> int:
        """Weight-stream bytes the gated forward actually consumed."""
        return self.dynamic_total * self.bytes_per_block

    @property
    def static_weight_bytes(self) -> int:
        """Weight-stream bytes of the full static schedule."""
        return self.static_total * self.bytes_per_block

    @property
    def read_fraction(self) -> float:
        """dynamic / static block reads (1.0 = nothing was skippable)."""
        return self.dynamic_total / max(1, self.static_total)

    def summary(self) -> str:
        occ = "/".join(f"{f:.2f}" for f in self.per_layer_row_occupancy)
        return (f"dynamic I/O at B={self.batch}: read "
                f"{self.dynamic_total}/{self.static_total} scheduled weight "
                f"blocks ({100 * self.read_fraction:.0f}%, "
                f"{self.blocks_skipped} skipped); per-layer row occupancy "
                f"[{occ}]")

    def to_dict(self) -> dict:
        return {
            "batch": int(self.batch),
            "per_layer_static": [int(v) for v in self.per_layer_static],
            "per_layer_dynamic": [int(v) for v in self.per_layer_dynamic],
            "per_layer_in_tiles": [int(v) for v in self.per_layer_in_tiles],
            "per_layer_live_tiles": [int(v)
                                     for v in self.per_layer_live_tiles],
            "per_layer_row_occupancy": [float(v) for v in
                                        self.per_layer_row_occupancy],
            "per_layer_hist": [[int(v) for v in h]
                               for h in self.per_layer_hist],
            "bytes_per_block": int(self.bytes_per_block),
            "weight_dtype": self.weight_dtype,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DynamicIOReport":
        return cls(
            batch=d["batch"],
            per_layer_static=tuple(d["per_layer_static"]),
            per_layer_dynamic=tuple(d["per_layer_dynamic"]),
            per_layer_in_tiles=tuple(d["per_layer_in_tiles"]),
            per_layer_live_tiles=tuple(d["per_layer_live_tiles"]),
            per_layer_row_occupancy=tuple(d["per_layer_row_occupancy"]),
            per_layer_hist=tuple(tuple(h) for h in d["per_layer_hist"]),
            # byte fields are absent from pre-quantization manifests
            bytes_per_block=int(d.get("bytes_per_block", 0)),
            weight_dtype=d.get("weight_dtype", "f32"),
        )


@dataclasses.dataclass(frozen=True)
class IOReport:
    """Predicted I/O of a compiled plan vs. the paper's Theorem-1 window.

    ``simulated`` is the exact tile traffic of the plan's connection order
    under the single-resident-tile VMEM model (``core.iosim.simulate`` on the
    block DAG); ``bounds`` are Theorem 1's bounds for the same (connected)
    DAG.  A correct plan always satisfies ``within_bounds``.

    The cross-layer fields quantify what fusing the whole net into one
    kernel saves over per-layer dispatch: ``layered_reads``/``layered_writes``
    are the summed per-layer simulated tile traffic (each layer boundary
    forces the hidden state through HBM there), ``hidden_tiles_kept`` is the
    number of intermediate activation tiles that stay VMEM-resident in the
    fused plan, and ``hidden_bytes_kept_per_row`` the HBM bytes that saves
    per batch row (one write plus one read-back per intermediate feature, at
    the kernel's float32 accumulator/hidden-buffer precision — 4 B/feature).

    The per-dtype byte fields restate the dominant I/O term in the unit the
    hardware pays: ``weight_bytes_streamed`` is the bytes of weight blocks
    one forward streams in the storage dtype (``weight_dtype``; halved for
    bf16, quartered for fp8 at the identical schedule),
    ``scale_bytes_streamed`` the f32 dequant-scale bytes riding along (0
    when unquantized), and ``activation_bytes_per_row`` the f32 activation
    bytes crossing HBM per batch row.  Tile counts and byte counts disagree
    exactly when dtypes differ: quantization changes bytes while the
    schedule — and so every tile count and Theorem-1 bound — is unchanged.
    All byte fields default to 0 so reports persisted before byte
    accounting existed still load.
    """

    simulated: IOStats
    bounds: Bounds
    M_tiles: int
    policy: str
    layered_reads: int = 0
    layered_writes: int = 0
    hidden_tiles_kept: int = 0
    hidden_bytes_kept_per_row: int = 0
    weight_dtype: str = "f32"
    weight_bytes_streamed: int = 0
    scale_bytes_streamed: int = 0
    activation_bytes_per_row: int = 0
    # measured dynamic I/O of the latest gated measurement run (None until
    # ExecutionPlan.measure_dynamic records one) — the static fields above
    # are schedule properties; this one is a property of actual data
    dynamic: Optional[DynamicIOReport] = None

    @property
    def within_total_bound(self) -> bool:
        return self.simulated.total <= self.bounds.total_hi

    @property
    def within_write_bounds(self) -> bool:
        return (self.bounds.writes_lo <= self.simulated.writes
                <= self.bounds.writes_hi)

    @property
    def within_bounds(self) -> bool:
        return self.within_total_bound and self.within_write_bounds

    @property
    def optimality_ratio(self) -> float:
        """simulated / lower bound — Theorem 1 guarantees ≤ 2 is achievable.

        An empty DAG (no connections survive pruning) moves no tiles and has
        a zero lower bound; it is vacuously optimal, so the ratio is 1.0
        rather than a 0/0.
        """
        if self.simulated.total == 0 and self.bounds.total_lo == 0:
            return 1.0
        return self.simulated.total / max(1, self.bounds.total_lo)

    @property
    def weight_stream_bytes(self) -> int:
        """Total weight-stream bytes per forward: narrow blocks + scales."""
        return self.weight_bytes_streamed + self.scale_bytes_streamed

    @property
    def layered_total(self) -> int:
        return self.layered_reads + self.layered_writes

    @property
    def cross_layer_savings(self) -> int:
        """Tile transfers the fused whole-net schedule avoids vs per-layer
        dispatch (hidden state kept in VMEM across layer boundaries)."""
        return max(0, self.layered_total - self.simulated.total)

    def summary(self) -> str:
        s, b = self.simulated, self.bounds
        msg = (f"tile I/O {s.total} (r={s.reads} w={s.writes}) in "
               f"[{b.total_lo}, {b.total_hi}] "
               f"(x{self.optimality_ratio:.2f} of lower bound, "
               f"M={self.M_tiles} tiles, {self.policy.upper()})")
        if self.weight_bytes_streamed:
            msg += (f"; weight stream {self.weight_stream_bytes} B "
                    f"as {self.weight_dtype}")
        if self.layered_total:
            msg += (f"; fused saves {self.cross_layer_savings} tile I/Os vs "
                    f"layered ({self.hidden_tiles_kept} hidden tiles / "
                    f"{self.hidden_bytes_kept_per_row} B/row VMEM-resident)")
        if self.dynamic is not None:
            msg += "; " + self.dynamic.summary()
        return msg

    def to_dict(self) -> dict:
        """JSON-serializable form (the plan store persists this alongside the
        schedule arrays so warm starts skip the I/O re-simulation too)."""
        return {
            "simulated": {"reads": int(self.simulated.reads),
                          "writes": int(self.simulated.writes)},
            "bounds": {
                "reads_lo": int(self.bounds.reads_lo),
                "reads_hi": int(self.bounds.reads_hi),
                "writes_lo": int(self.bounds.writes_lo),
                "writes_hi": int(self.bounds.writes_hi),
            },
            "M_tiles": int(self.M_tiles),
            "policy": self.policy,
            "layered_reads": int(self.layered_reads),
            "layered_writes": int(self.layered_writes),
            "hidden_tiles_kept": int(self.hidden_tiles_kept),
            "hidden_bytes_kept_per_row": int(self.hidden_bytes_kept_per_row),
            "weight_dtype": self.weight_dtype,
            "weight_bytes_streamed": int(self.weight_bytes_streamed),
            "scale_bytes_streamed": int(self.scale_bytes_streamed),
            "activation_bytes_per_row": int(self.activation_bytes_per_row),
            "dynamic": None if self.dynamic is None
            else self.dynamic.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "IOReport":
        dyn = d.get("dynamic")
        return cls(
            simulated=IOStats(**d["simulated"]),
            bounds=Bounds(**d["bounds"]),
            M_tiles=d["M_tiles"],
            policy=d["policy"],
            layered_reads=d.get("layered_reads", 0),
            layered_writes=d.get("layered_writes", 0),
            hidden_tiles_kept=d.get("hidden_tiles_kept", 0),
            hidden_bytes_kept_per_row=d.get("hidden_bytes_kept_per_row", 0),
            # byte fields are absent from pre-quantization manifests
            weight_dtype=d.get("weight_dtype", "f32"),
            weight_bytes_streamed=d.get("weight_bytes_streamed", 0),
            scale_bytes_streamed=d.get("scale_bytes_streamed", 0),
            activation_bytes_per_row=d.get("activation_bytes_per_row", 0),
            dynamic=None if dyn is None else DynamicIOReport.from_dict(dyn),
        )


@dataclasses.dataclass
class ExecutionPlan:
    """A compiled whole-network inference plan.  Call it on inputs."""

    layers: List[BSRLayer]
    schedules: List[CompiledSchedule]
    activations: List[Optional[Callable]]   # fused epilogue per layer
    backend: str                            # resolved backend name
    order: np.ndarray                       # block-DAG connection order
    block_ffnn: BlockFFNN
    io: IOReport
    flat: Optional[FlatSchedule] = None     # cross-layer schedule (fused)
    _forward: Callable = dataclasses.field(repr=False, default=None)
    calls: int = dataclasses.field(default=0, compare=False)
    compile_s: float = 0.0                  # wall time of Engine._build
    annealer_iters: int = 0                 # CR proposals paid for this plan
    gate: bool = False                      # runtime tile-occupancy gating
    # why the plan is not (fully) what was asked for: flat-schedule /
    # megakernel fallbacks no longer degrade silently — the builder records
    # the reason here and describe() surfaces it
    fallback_reason: Optional[str] = None
    _measure: Optional[Callable] = dataclasses.field(repr=False,
                                                     default=None)

    @property
    def fused(self) -> bool:
        """True when the plan executes as one flat cross-layer dispatch (the
        megakernel on pallas/interpret, one segment pass on jnp)."""
        return self.flat is not None

    @property
    def n_in(self) -> int:
        return self.layers[0].n_in

    @property
    def n_out(self) -> int:
        return self.layers[-1].n_out

    @property
    def dtype(self) -> np.dtype:
        """The plan's input dtype: what its forward was traced (and should
        always be called) with.  Feeding any other dtype retraces a second
        program per batch shape — serving callers cast to this first.
        Independent of ``weight_dtype`` — activations stay f32."""
        return np.dtype(self.layers[0].blocks.dtype)

    @property
    def weight_dtype(self) -> str:
        """Storage dtype of the streamed weight blocks (f32/bf16/fp8)."""
        return self.schedules[0].weight_dtype if self.schedules else "f32"

    def __call__(self, x) -> jnp.ndarray:
        """Run inference.  ``x`` is ``[n_in]`` or batched ``[B, n_in]``."""
        x = jnp.asarray(x)
        single = x.ndim == 1
        if single:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] != self.n_in:
            raise ValueError(
                f"expected input [B, {self.n_in}] or [{self.n_in}], "
                f"got {tuple(x.shape)}"
            )
        y = self._forward(x)
        self.calls += 1
        return y[0] if single else y

    def with_fresh_forward(self, jit: bool = True) -> "ExecutionPlan":
        """A copy of this plan with a newly lowered forward (call count 0).

        The schedule substrate — layers, schedules, flat arrays, order, I/O
        report — is shared by reference; only the jitted dispatch (and the
        gated plan's instrumented measurement twin) is rebuilt.  This is how
        ``repro.serving.bucketing`` fans one compiled schedule out across
        batch buckets without ever re-deriving it.
        """
        from .backends import (
            make_forward,
            make_fused_forward,
            make_fused_measure,
        )

        measure = None
        if self.flat is not None:
            fwd = make_fused_forward(self.layers, self.flat, self.activations,
                                     self.backend, jit=jit, gate=self.gate)
            if self.gate:
                measure = make_fused_measure(self.layers, self.flat,
                                             self.activations, self.backend,
                                             jit=jit)
        else:
            fwd = make_forward(self.layers, self.schedules, self.activations,
                               self.backend, jit=jit, gate=self.gate)
        return dataclasses.replace(self, _forward=fwd, _measure=measure,
                                   calls=0)

    def safe_twin(self, jit: bool = True) -> "ExecutionPlan":
        """The plan's safe-mode twin: same schedule, jnp backend, gate off.

        The jnp segment lowering is the bit-exact reference the megakernel
        is checked against (PR 2), and the ungated forward is bit-exact
        with the gated one (PR 6) — so this twin computes the *identical*
        function through the simplest code path available, just without
        the fast-path machinery that can misbehave.  The serving runtime
        degrades to it when the circuit breaker trips (see
        ``repro.serving.resilience``).  The schedule substrate is shared
        by reference; only the forward is re-lowered.
        """
        twin = dataclasses.replace(self, backend="jnp", gate=False)
        return twin.with_fresh_forward(jit=jit)

    def measure_dynamic(self, x) -> DynamicIOReport:
        """Run one instrumented gated forward on ``x`` and report measured
        dynamic I/O: scheduled weight blocks actually consumed per layer vs
        the static Theorem-1 schedule, plus per-layer occupancy histograms.
        The report is also recorded on ``self.io.dynamic`` (so ``describe``
        and the plan store's serialized report carry it).
        """
        if self._measure is None:
            raise RuntimeError(
                "dynamic I/O measurement needs a gated fused plan — compile "
                "with Engine(gate=True) on a net the flat schedule can "
                "express (uniform square tiles)"
            )
        x = jnp.asarray(x)
        if x.ndim == 1:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] != self.n_in:
            raise ValueError(
                f"expected input [B, {self.n_in}] or [{self.n_in}], "
                f"got {tuple(x.shape)}"
            )
        _, occs = self._measure(x)
        B = int(x.shape[0])
        bs = self.flat.block
        bpb = bs * bs * np.dtype(np.asarray(self.flat.blocks).dtype).itemsize
        if self.flat.scales is not None:
            bpb += 4                     # the per-block f32 dequant scale
        rows = np.asarray(self.flat.rows)
        stat, dyn, in_tiles, live, row_occ, hists = [], [], [], [], [], []
        for k, (s, e) in enumerate(self.flat.segments):
            occ = np.asarray(occs[k])
            stat.append(int(e - s))
            dyn.append(int(np.sum(occ[rows[s:e]] > 0)))
            in_tiles.append(int(occ.size))
            live.append(int(np.sum(occ > 0)))
            frac = occ.astype(np.float64) / max(1, B)
            row_occ.append(float(frac.mean()) if frac.size else 0.0)
            alive = frac[occ > 0]
            hist = np.histogram(alive, bins=[0.0, 0.25, 0.5, 0.75,
                                             1.0 + 1e-9])[0]
            hists.append((int(np.sum(occ == 0)),)
                         + tuple(int(n) for n in hist))
        report = DynamicIOReport(
            batch=B,
            per_layer_static=tuple(stat),
            per_layer_dynamic=tuple(dyn),
            per_layer_in_tiles=tuple(in_tiles),
            per_layer_live_tiles=tuple(live),
            per_layer_row_occupancy=tuple(row_occ),
            per_layer_hist=tuple(hists),
            bytes_per_block=int(bpb),
            weight_dtype=self.flat.weight_dtype,
        )
        self.io = dataclasses.replace(self.io, dynamic=report)
        return report

    def trace_attrs(self) -> dict:
        """Flat span-attribute dict describing this plan's I/O profile —
        backend, fusion/gating, simulated tile I/O vs the Theorem-1 lower
        bound, and the latest measured dynamic read counts when present
        (:func:`repro.obs.telemetry.plan_io_attrs`).  This is what the
        serving runtime stamps on every ``batch.execute`` span."""
        from repro.obs.telemetry import plan_io_attrs
        return plan_io_attrs(self)

    def describe(self) -> str:
        shapes = " -> ".join(
            [str(self.n_in)] + [str(l.n_out) for l in self.layers])
        nnz = sum(l.nnz_blocks for l in self.layers)
        mode = "fused" if self.fused else "layered"
        if self.gate:
            mode += "+gated"
        if self.weight_dtype != "f32":
            mode += f"+{self.weight_dtype}"
        fallback = "" if self.fallback_reason is None \
            else f" [fallback: {self.fallback_reason}]"
        return (f"ExecutionPlan[{self.backend}/{mode}]{fallback} {shapes} "
                f"({len(self.layers)} layers, {nnz} nonzero blocks); "
                + self.io.summary()
                + f"; compiled in {self.compile_s:.2f}s "
                  f"({self.annealer_iters} annealer iters), "
                  f"{self.calls} calls")

    def artifact_arrays(self) -> dict:
        """The plan's persistable schedule arrays, as host numpy.

        ``order`` (the whole-DAG connection order) is the artifact everything
        else re-derives from deterministically; the flat-schedule prefetch
        arrays ride along so a loader can verify the rebuilt schedule matches
        the stored one bit-for-bit (``repro.serving.plancache``).
        """
        out = {"order": np.asarray(self.order, dtype=np.int64)}
        if self.flat is not None:
            f = self.flat
            for name in ("rows", "cols", "first", "last", "layer_id",
                         "hbm_row", "out_tile", "bias_idx"):
                out[f"flat_{name}"] = np.asarray(getattr(f, name),
                                                 dtype=np.int32)
            if f.scales is not None:
                # quantized stream: persist the narrow blocks + scales so a
                # warm start verifies the stored quantization byte-for-byte
                # (narrow dtypes ride the checkpoint void-view path)
                out["flat_qblocks"] = np.asarray(f.blocks)
                out["flat_scales"] = np.asarray(f.scales, dtype=np.float32)
        return out
