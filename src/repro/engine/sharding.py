"""Sharded execution plans: the block DAG partitioned across a device mesh.

The paper's I/O model is *per device* — each accelerator has its own small
fast memory — so the way to scale past one device is not a bigger schedule
but **one independent Theorem-1 schedule per shard**:

    from repro.engine import Engine, Mesh

    plan = Engine().compile(layers, mesh=Mesh(model=4, data=2))
    y = plan(x)
    print(plan.io_report().summary())   # per-shard traffic + imbalance

``Mesh(model, data)`` partitions the block-column DAG **tile-parallel** over
``model`` (each shard owns an equal-count, load-balanced subset of every
layer's output tiles — ``core.graph.partition_columns_balanced``) and
**batch-parallel** over ``data``.  Each model shard gets its own shard DAG:
its connections are every weight block targeting an owned tile; tiles it
reads but does not produce (inputs and remote boundary tiles that arrive by
all-gather) are the shard DAG's *inputs*, and every owned tile is an
*output* (it must reach HBM to be gathered).  The shard DAG is a perfectly
ordinary paper-FFNN, so the whole single-device machinery applies per shard
unchanged: Theorem-1 grouping, Connection Reordering (embarrassingly
parallel — each shard anneals independently), schedule packing, exact I/O
simulation and Theorem-1 bounds.  EIE distributes a sparse network over
processing elements exactly this way (per-PE queues + activation
broadcast); SparseNN's observation that *load balance*, not total traffic,
governs end-to-end throughput is why :class:`ShardedIOReport` exposes a
load-imbalance ratio next to the aggregate.

Execution lowers through ``compat.shard_map`` when the host has a device
per mesh slot, and through a sequential jnp loop over the shard index
otherwise — the same segment arithmetic either way, so both lowerings (and
the unsharded plan) agree bitwise under the default (un-annealed) schedule.
A 1-shard ``model`` axis does not build any of this: its per-device body is
the unsharded plan's own forward, which makes the single-device path the
1×1-mesh special case rather than a parallel code path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import host_mesh
from repro.core.blocksparse import BlockFFNN, BSRLayer
from repro.core.graph import FFNN, partition_columns_balanced

from .backends import ShardedSegment, make_sharded_forward
from .plan import ExecutionPlan, IOReport


@dataclasses.dataclass(frozen=True)
class Mesh:
    """Logical device mesh for a sharded plan: tile-parallel ``model`` axis
    × batch-parallel ``data`` axis.

    This is a *spec*, not a device object: compiling against ``Mesh(4, 2)``
    on a 1-device host is legal — the plan lowers to the sequential shard
    loop instead of ``shard_map`` and computes the identical function (the
    CI multi-device lane runs the same tests under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to cover the
    collective lowering).
    """

    model: int = 1
    data: int = 1

    def __post_init__(self):
        if self.model < 1 or self.data < 1:
            raise ValueError(f"mesh axes must be >= 1, got {self}")

    @property
    def size(self) -> int:
        return self.model * self.data

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.model, self.data)

    @classmethod
    def parse(cls, spec: str) -> "Mesh":
        """Parse a CLI mesh spec: ``"4x2"`` = 4 model shards × 2 data
        replicas; ``"4"`` means ``4x1``.  One parser (and one error
        message) for every mesh-taking command line."""
        model, _, data = spec.strip().lower().partition("x")
        try:
            return cls(model=int(model), data=int(data) if data else 1)
        except ValueError:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected MODELxDATA, e.g. 4x2"
            ) from None

    def jax_mesh(self):
        """The physical ``(data, model)`` mesh, or None to use the loop
        fallback (single-slot mesh, or fewer host devices than slots)."""
        if self.size <= 1 or jax.device_count() < self.size:
            return None
        return host_mesh((self.data, self.model), ("data", "model"))


@dataclasses.dataclass
class ShardSpec:
    """One model shard's view of the network.

    ``layers[k]`` keeps the full layer-``k`` input width (the shard reads
    the gathered activation) but only the owned output tiles, re-indexed to
    local column ids.  ``owned[k][p]`` is the global output tile behind
    local tile ``p`` — the reassembly permutation of the all-gather.
    ``bffnn`` is the shard DAG described in the module docstring.
    """

    bffnn: BlockFFNN
    owned: List[np.ndarray]


def partition_model(bffnn: BlockFFNN, model: int) -> List[ShardSpec]:
    """Partition the block-column DAG into ``model`` balanced shards.

    Every layer's output tiles are split into equal-count groups (a
    ``shard_map`` shape requirement) balancing per-shard nonzero-block load;
    raises ``ValueError`` when a layer's tile grid is not divisible by
    ``model``.  ``model=1`` returns the whole network as the single shard —
    the unsharded compile *is* this special case.
    """
    layers = bffnn.layers
    if model == 1:
        return [ShardSpec(bffnn=bffnn,
                          owned=[np.arange(l.grid_out) for l in layers])]

    offsets = [0, layers[0].grid_in]
    for lay in layers:
        offsets.append(offsets[-1] + lay.grid_out)
    n_tiles = offsets[-1]

    assigns = []
    for k, lay in enumerate(layers):
        if lay.grid_out % model:
            raise ValueError(
                f"layer {k} has {lay.grid_out} output tiles, not divisible "
                f"by the model axis ({model}); pick a mesh whose model size "
                "divides every layer's tile grid"
            )
        loads = np.bincount(lay.cols, minlength=lay.grid_out)
        assigns.append(partition_columns_balanced(loads, model))

    shards = []
    for s in range(model):
        owned_s: List[np.ndarray] = []
        shard_layers: List[BSRLayer] = []
        src_l, dst_l, lay_l, blk_l = [], [], [], []
        owned_mask = np.zeros(n_tiles, dtype=bool)
        for k, lay in enumerate(layers):
            owned = np.flatnonzero(assigns[k] == s)
            owned_s.append(owned)
            owned_mask[offsets[k + 1] + owned] = True
            local = np.full(lay.grid_out, -1, dtype=np.int64)
            local[owned] = np.arange(len(owned))
            sel = np.flatnonzero(local[lay.cols] >= 0)
            bias = np.ascontiguousarray(
                lay.bias.reshape(lay.grid_out, lay.block_n)[owned]
            ).reshape(-1)
            shard_layers.append(BSRLayer(
                n_in=lay.n_in,
                n_out=len(owned) * lay.block_n,
                block_m=lay.block_m,
                block_n=lay.block_n,
                rows=lay.rows[sel].astype(np.int32),
                cols=local[lay.cols[sel]].astype(np.int32),
                blocks=lay.blocks[sel],
                bias=bias.astype(np.float32),
            ))
            src_l.append(lay.rows[sel].astype(np.int64) + offsets[k])
            dst_l.append(lay.cols[sel].astype(np.int64) + offsets[k + 1])
            lay_l.append(np.full(len(sel), k, dtype=np.int32))
            blk_l.append(np.arange(len(sel), dtype=np.int64))
        src = np.concatenate(src_l)
        dst = np.concatenate(dst_l)
        # outputs = owned tiles this shard actually *produces* (the gather
        # reads them back from HBM).  Owned tiles with no incoming block are
        # bias-patched dead code — dropped from the I/O analysis exactly
        # like the unsharded path drops them (see ``drop_isolated``).
        produced = np.zeros(n_tiles, dtype=bool)
        produced[dst] = True
        net = FFNN(
            n_neurons=n_tiles, src=src, dst=dst,
            weight=np.ones(len(src), dtype=np.float32),
            is_input=~owned_mask,     # inputs + tiles arriving by all-gather
            is_output=owned_mask & produced,
            bias=np.zeros(n_tiles, dtype=np.float32),
        )
        shards.append(ShardSpec(
            bffnn=BlockFFNN(layers=shard_layers, net=net,
                            conn_layer=np.concatenate(lay_l),
                            conn_block=np.concatenate(blk_l)),
            owned=owned_s,
        ))
    return shards


# --------------------------------------------------------------------------- #
# aggregate I/O report
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ShardedIOReport:
    """Per-shard Theorem-1 I/O reports + the cross-shard aggregates.

    Each entry of ``per_shard`` is the exact simulated tile traffic of that
    shard's independent schedule next to *that shard DAG's* Theorem-1
    bounds (the model is per-device, so the bounds are too).  The aggregate
    is the sum; ``load_imbalance`` = max shard traffic / mean shard traffic
    (1.0 = perfectly balanced) — the number that actually bounds end-to-end
    throughput, since every shard's gather waits for the slowest shard.
    ``data`` replicas stream the same tiles for different batch rows, so
    per-shard counts are per data replica.
    """

    per_shard: Tuple[IOReport, ...]
    model: int = 1
    data: int = 1

    @property
    def reads(self) -> int:
        return sum(r.simulated.reads for r in self.per_shard)

    @property
    def writes(self) -> int:
        return sum(r.simulated.writes for r in self.per_shard)

    @property
    def total(self) -> int:
        return self.reads + self.writes

    @property
    def within_bounds(self) -> bool:
        return all(r.within_bounds for r in self.per_shard)

    @property
    def load_imbalance(self) -> float:
        totals = [r.simulated.total for r in self.per_shard]
        mean = sum(totals) / max(1, len(totals))
        if mean == 0:
            return 1.0
        return max(totals) / mean

    @property
    def max_shard_total(self) -> int:
        return max(r.simulated.total for r in self.per_shard)

    @property
    def weight_dtype(self) -> str:
        return self.per_shard[0].weight_dtype if self.per_shard else "f32"

    @property
    def weight_bytes_streamed(self) -> int:
        return sum(r.weight_bytes_streamed for r in self.per_shard)

    @property
    def scale_bytes_streamed(self) -> int:
        return sum(r.scale_bytes_streamed for r in self.per_shard)

    @property
    def weight_stream_bytes(self) -> int:
        """Aggregate weight-stream bytes (blocks + scales) per data replica."""
        return sum(r.weight_stream_bytes for r in self.per_shard)

    def summary(self) -> str:
        return (f"sharded tile I/O {self.total} over {self.model} model "
                f"shard(s) x {self.data} data (max shard "
                f"{self.max_shard_total}, imbalance "
                f"x{self.load_imbalance:.2f}, "
                f"{'within' if self.within_bounds else 'OUTSIDE'} per-shard "
                "Theorem-1 bounds)")

    def to_dict(self) -> dict:
        return {"model": self.model, "data": self.data,
                "per_shard": [r.to_dict() for r in self.per_shard]}

    @classmethod
    def from_dict(cls, d: dict) -> "ShardedIOReport":
        return cls(per_shard=tuple(IOReport.from_dict(r)
                                   for r in d["per_shard"]),
                   model=d["model"], data=d["data"])


# --------------------------------------------------------------------------- #
# the sharded plan
# --------------------------------------------------------------------------- #

def _shard_not_runnable(*_a, **_k):
    raise RuntimeError(
        "a model-parallel shard plan is not standalone-runnable — its "
        "layers read the all-gathered activation; call the "
        "ShardedExecutionPlan instead"
    )


@dataclasses.dataclass
class ShardedExecutionPlan:
    """A compiled plan partitioned over a ``Mesh``.  Call it on inputs.

    ``shards[s]`` is a full :class:`ExecutionPlan` built by the same
    single-device builder (``Engine._build``) on shard ``s``'s DAG — its
    ``order``, ``schedules``, ``flat`` arrays and ``io`` report are the
    per-shard artifacts the plan store persists.  The collective forward
    consumes those per-shard schedule arrays directly.
    """

    mesh: Mesh
    shards: List[ExecutionPlan]
    owned: List[List[np.ndarray]]   # [shard][layer] global output-tile ids
    backend: str
    gate: bool = False              # runtime tile-occupancy gating
    block_ffnn: BlockFFNN = None    # the unpartitioned network
    _forward: Callable = dataclasses.field(repr=False, default=None)
    _rebuild: Callable = dataclasses.field(repr=False, default=None)
    calls: int = dataclasses.field(default=0, compare=False)
    compile_s: float = 0.0

    @property
    def n_in(self) -> int:
        return self.shards[0].n_in

    @property
    def n_out(self) -> int:
        return sum(s.layers[-1].n_out for s in self.shards)

    @property
    def n_layers(self) -> int:
        return len(self.shards[0].layers)

    @property
    def dtype(self) -> np.dtype:
        """Input dtype the collective forward was traced with (the sharded
        analogue of :attr:`ExecutionPlan.dtype`)."""
        return self.shards[0].dtype

    @property
    def weight_dtype(self) -> str:
        """Storage dtype of the streamed weight blocks (all shards agree)."""
        return self.shards[0].weight_dtype

    @property
    def annealer_iters(self) -> int:
        return sum(s.annealer_iters for s in self.shards)

    @property
    def io(self) -> ShardedIOReport:
        return self.io_report()

    def io_report(self) -> ShardedIOReport:
        """Aggregate per-shard traffic + load-imbalance ratio."""
        return ShardedIOReport(per_shard=tuple(s.io for s in self.shards),
                               model=self.mesh.model, data=self.mesh.data)

    def __call__(self, x) -> jnp.ndarray:
        """Run inference.  ``x`` is ``[n_in]`` or batched ``[B, n_in]``;
        the batch is padded up to a multiple of the data-axis size and
        sliced back (zero rows never perturb real rows)."""
        x = jnp.asarray(x)
        single = x.ndim == 1
        if single:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] != self.n_in:
            raise ValueError(
                f"expected input [B, {self.n_in}] or [{self.n_in}], "
                f"got {tuple(x.shape)}"
            )
        B = x.shape[0]
        pad = (-B) % self.mesh.data
        if pad:
            x = jnp.pad(x, ((0, pad), (0, 0)))
        if self.gate and self.mesh.model > 1:
            # padding happened outside the collective trace, so the gated
            # forward takes the real-row mask explicitly (occupancy is
            # computed over real rows only)
            valid = jnp.arange(x.shape[0]) < B
            y = self._forward(x, valid)[:B]
        else:
            y = self._forward(x)[:B]
        self.calls += 1
        return y[0] if single else y

    def with_fresh_forward(self, jit: bool = True) -> "ShardedExecutionPlan":
        """A copy with a newly lowered collective forward (call count 0);
        the per-shard schedule substrate is shared by reference — this is
        the sharded analogue of :meth:`ExecutionPlan.with_fresh_forward`
        that ``repro.serving.bucketing`` fans over batch buckets."""
        return dataclasses.replace(self, _forward=self._rebuild(jit), calls=0)

    def safe_twin(self, jit: bool = True) -> "ShardedExecutionPlan":
        """The sharded analogue of :meth:`ExecutionPlan.safe_twin`: the
        same per-shard schedules lowered through the jnp collective path
        with gating off — bit-exact (the model>1 collective already lowers
        segments through jnp; the gated/ungated forwards agree bitwise per
        PR 6), just without the fast-path machinery.  Used by the serving
        runtime's circuit breaker."""
        rebuild = self._rebuild
        return dataclasses.replace(
            self, backend="jnp", gate=False,
            _forward=rebuild(jit, safe=True),
            _rebuild=lambda j=True, safe=True: rebuild(j, safe=True),
            calls=0)

    def describe(self) -> str:
        shapes = " -> ".join(
            [str(self.n_in)]
            + [str(sum(s.layers[k].n_out for s in self.shards))
               for k in range(self.n_layers)])
        nnz = sum(l.nnz_blocks for s in self.shards for l in s.layers)
        # with >1 model shard the collective forward lowers per-shard
        # segments through the jnp path regardless of backend — say so
        # instead of letting the backend name imply the megakernel ran
        mode = self.backend if len(self.shards) == 1 \
            else f"{self.backend}/jnp-collective"
        if self.gate:
            mode += "+gated"
        return (f"ShardedExecutionPlan[{mode}] "
                f"mesh(model={self.mesh.model}, data={self.mesh.data}) "
                f"{shapes} ({self.n_layers} layers, {nnz} nonzero blocks); "
                + self.io_report().summary()
                + f"; compiled in {self.compile_s:.2f}s "
                  f"({self.annealer_iters} annealer iters), "
                  f"{self.calls} calls")

    def artifact_arrays(self) -> dict:
        """Persistable arrays: the partition assignment per layer plus each
        shard's own artifact (order + flat-schedule verification arrays),
        prefixed ``s{i}_`` — the plan-store entry for a sharded plan."""
        out = {}
        for k in range(self.n_layers):
            grid = sum(len(owned_s[k]) for owned_s in self.owned)
            assign = np.zeros(grid, dtype=np.int32)
            for s, owned_s in enumerate(self.owned):
                assign[owned_s[k]] = s
            out[f"assign_l{k}"] = assign
        for s, plan in enumerate(self.shards):
            for name, arr in plan.artifact_arrays().items():
                out[f"s{s}_{name}"] = arr
        return out


# --------------------------------------------------------------------------- #
# builder (called by Engine.compile — one shard through Engine._build each)
# --------------------------------------------------------------------------- #

def _sharded_segments(
    specs: Sequence[ShardSpec],
    shard_plans: Sequence[ExecutionPlan],
) -> List[ShardedSegment]:
    """Stack every shard's per-layer schedule arrays into uniform-shape
    ``ShardedSegment``s (padding routed to the sink segment)."""
    model = len(specs)
    n_layers = len(specs[0].bffnn.layers)
    segments = []
    for k in range(n_layers):
        full_lay = specs[0].bffnn.layers[k]
        tps = len(specs[0].owned[k])
        bm, bn = full_lay.block_m, full_lay.block_n
        scheds = [np.asarray(p.schedules[k].rows) for p in shard_plans]
        n_max = max(len(r) for r in scheds)
        rows = np.zeros((model, n_max), dtype=np.int32)
        cols = np.full((model, n_max), tps, dtype=np.int32)   # sink segment
        # keep the storage dtype: a quantized plan's shards stream the same
        # narrow blocks the unsharded plan does (pad steps are zero blocks
        # with scale 1.0, so they dequantize to exact zero)
        store_dtype = np.asarray(shard_plans[0].schedules[k].blocks).dtype
        quant = shard_plans[0].schedules[k].scales is not None
        blocks = np.zeros((model, n_max, bm, bn), dtype=store_dtype)
        scales = np.ones((model, n_max), dtype=np.float32) if quant else None
        bias = np.zeros((model, tps * bn), dtype=np.float32)
        grid_out_full = sum(len(sp.owned[k]) for sp in specs)
        perm = np.zeros(grid_out_full, dtype=np.int32)
        for s, (sp, plan) in enumerate(zip(specs, shard_plans)):
            sch = plan.schedules[k]
            n = len(np.asarray(sch.rows))
            rows[s, :n] = np.asarray(sch.rows)
            cols[s, :n] = np.asarray(sch.cols)
            blocks[s, :n] = np.asarray(sch.blocks)
            if quant:
                scales[s, :n] = np.asarray(sch.scales, dtype=np.float32)
            bias[s] = np.asarray(sp.bffnn.layers[k].bias, dtype=np.float32)
            perm[sp.owned[k]] = s * tps + np.arange(tps)
        segments.append(ShardedSegment(
            rows=rows, cols=cols, blocks=blocks, bias=bias, perm=perm,
            grid_in=full_lay.grid_in, tps=tps, block_m=bm, block_n=bn,
            activation=shard_plans[0].activations[k],
            scales=scales,
        ))
    return segments


def build_sharded_plan(
    engine,                      # repro.engine.Engine (duck-typed)
    bffnn: BlockFFNN,
    backend: str,
    mesh: Mesh,
    orders: Optional[Sequence[np.ndarray]] = None,
    ios: Optional[Sequence[IOReport]] = None,
) -> ShardedExecutionPlan:
    """Partition, build one per-shard plan each through ``engine._build``
    (the exact single-device builder: Theorem-1 order + independent CR +
    schedule packing + I/O report), then lower the collective forward.

    ``orders``/``ios`` are the plan-store warm path: one stored connection
    order (and optionally I/O report) per shard, skipping the annealing and
    re-simulation exactly like ``Engine.compile_with_order`` does.
    """
    t0 = time.perf_counter()
    gate = bool(getattr(engine, "gate", False))
    specs = partition_model(bffnn, mesh.model)
    if orders is not None and len(orders) != len(specs):
        raise ValueError(
            f"got {len(orders)} stored orders for {len(specs)} shards")
    shard_plans = []
    for s, spec in enumerate(specs):
        if orders is not None:
            plan = engine._build(spec.bffnn, backend,
                                 order=np.asarray(orders[s]),
                                 io=None if ios is None else ios[s])
        else:
            plan = engine._build(spec.bffnn, backend)
        if mesh.model > 1:
            # shard layers read the gathered activation; the standalone
            # forward _build lowered would mis-chain them
            plan = dataclasses.replace(plan, _forward=_shard_not_runnable)
        shard_plans.append(plan)

    segments = _sharded_segments(specs, shard_plans) if mesh.model > 1 \
        else []

    def rebuild(jit: bool = True, safe: bool = False) -> Callable:
        # safe=True lowers the safe-mode twin: jnp per-shard body, gate
        # off — the degraded path the serving circuit breaker swaps to
        jm = mesh.jax_mesh()
        base = None
        if mesh.model == 1:
            shard0 = shard_plans[0].safe_twin(jit=False) if safe \
                else shard_plans[0]
            if jm is None:
                return shard0.with_fresh_forward(jit=jit)._forward
            base = shard0.with_fresh_forward(jit=False)._forward
        return make_sharded_forward(segments, mesh.model, mesh.data, jm,
                                    base_forward=base, jit=jit,
                                    gate=False if safe else gate)

    if mesh.model == 1 and mesh.jax_mesh() is None:
        # the 1×1 (or device-starved model=1) case IS the unsharded path:
        # share the very forward the single-device builder produced
        forward = shard_plans[0]._forward
    else:
        forward = rebuild(engine.jit)

    return ShardedExecutionPlan(
        mesh=mesh,
        shards=shard_plans,
        owned=[spec.owned for spec in specs],
        backend=backend,
        gate=gate,
        block_ffnn=bffnn,
        _forward=forward,
        _rebuild=rebuild,
        compile_s=time.perf_counter() - t0,
    )
