"""Scheduled block-sparse matmul — the paper's contribution as a TPU kernel.

``y = act(x @ W + b)`` where W is block-sparse (BSR).  The Pallas grid *is* the
paper's topological order of the connections: one grid step per nonzero weight
block, executed in the (reordered) schedule produced by
``repro.core.blocksparse.schedule_arrays``.

I/O behaviour (the paper's model realized in hardware):
  * the weight block of step g streams HBM->VMEM exactly once        (W reads);
  * the input tile x[:, rows[g]] is fetched only when ``rows[g]`` differs from
    ``rows[g-1]`` — Pallas keeps the block in VMEM across grid steps whose
    index_map result is unchanged                     (input-neuron reads);
  * the f32 accumulator tile lives in VMEM scratch for the *contiguous* run of
    steps sharing ``cols[g]`` (Theorem-1 grouped order), is written back once
    per output tile                                   (writes = S exactly).

The schedule MUST be contiguous-by-output (checked in ops.py) — that is
precisely the Theorem-1 2-optimal family the paper proves sufficient; within
it, Connection Reordering minimizes the input-tile re-fetches.

Scalar-prefetch arrays feed the index maps:
  rows[g], cols[g] — input/output tile of step g,
  first[g]         — 1 iff step g is the first visiting its output tile
                     (zero-initialize the accumulator),
  last[g]          — 1 iff step g is the last (add bias, activate, emit).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(
    # scalar prefetch
    rows_ref, cols_ref, first_ref, last_ref,
    # inputs
    x_ref, w_ref, b_ref,
    # outputs
    o_ref,
    # scratch
    acc_ref,
    *,
    activation: Optional[Callable],
):
    g = pl.program_id(0)

    @pl.when(first_ref[g] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(last_ref[g] == 1)
    def _emit():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if activation is not None:
            y = activation(y)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("grid_out", "activation", "interpret"),
)
def bsr_matmul(
    x: jnp.ndarray,        # [B, n_in]
    blocks: jnp.ndarray,   # [nnz, bm, bn] scheduled order
    rows: jnp.ndarray,     # int32 [nnz]
    cols: jnp.ndarray,     # int32 [nnz]
    first: jnp.ndarray,    # int32 [nnz]
    last: jnp.ndarray,     # int32 [nnz]
    bias: jnp.ndarray,     # [n_out]
    grid_out: int,
    activation: Optional[Callable] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Run the scheduled BSR matmul.  See module docstring for the schedule contract."""
    B, n_in = x.shape
    nnz, bm, bn = blocks.shape
    n_out = grid_out * bn
    if n_in % bm:
        raise ValueError("n_in must be a multiple of the block size")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nnz,),
        in_specs=[
            # input tile: revisits keep it in VMEM while rows[g] is unchanged
            pl.BlockSpec((B, bm), lambda g, rows, cols, first, last: (0, rows[g])),
            # weight block: streamed, one per step
            pl.BlockSpec((1, bm, bn), lambda g, rows, cols, first, last: (g, 0, 0)),
            # bias tile of the current output tile
            pl.BlockSpec((1, bn), lambda g, rows, cols, first, last: (0, cols[g])),
        ],
        out_specs=pl.BlockSpec(
            (B, bn), lambda g, rows, cols, first, last: (0, cols[g])
        ),
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_out), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )
    return fn(rows, cols, first, last, x, blocks, bias.reshape(1, -1))


# --------------------------------------------------------------------------- #
# the whole-network megakernel
# --------------------------------------------------------------------------- #

def _megakernel(
    # scalar prefetch
    layer_ref, rows_ref, cols_ref, first_ref, last_ref,
    hbm_row_ref, out_tile_ref, bias_idx_ref,
    # inputs
    x_ref, w_ref, b_ref,
    # outputs
    o_ref,
    # scratch
    acc_ref, h0_ref, h1_ref,
    *,
    n_layers: int,
    activation: Optional[Callable],
    final_activation: Optional[Callable],
):
    """One grid step per nonzero block of ANY layer, in whole-net Theorem-1
    order.  The hidden state ping-pongs between two VMEM buffers across layer
    boundaries (layer k reads h[(k-1) % 2], writes h[k % 2]); activations
    never touch HBM between layers.  Weight blocks stream through the Pallas
    pipeline, which double-buffers the ``w_ref`` fetch of step g+1 behind the
    multiply of step g."""
    g = pl.program_id(0)
    lid = layer_ref[g]

    @pl.when(first_ref[g] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # multiply-accumulate from this step's input tile
    @pl.when(lid == 0)
    def _from_hbm():
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[0], preferred_element_type=jnp.float32
        )

    if n_layers > 1:
        r = rows_ref[g]

        @pl.when((lid > 0) & (lid % 2 == 1))
        def _from_h0():
            acc_ref[...] += jnp.dot(
                h0_ref[r], w_ref[0], preferred_element_type=jnp.float32
            )

        @pl.when((lid > 0) & (lid % 2 == 0))
        def _from_h1():
            acc_ref[...] += jnp.dot(
                h1_ref[r], w_ref[0], preferred_element_type=jnp.float32
            )

    # epilogue on the last visit of the current output tile
    is_final = lid == n_layers - 1

    @pl.when((last_ref[g] == 1) & is_final)
    def _emit():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if final_activation is not None:
            y = final_activation(y)
        o_ref[...] = y.astype(o_ref.dtype)

    if n_layers > 1:
        c = cols_ref[g]

        @pl.when((last_ref[g] == 1) & ~is_final & (lid % 2 == 0))
        def _stash_h0():
            y = acc_ref[...] + b_ref[...].astype(jnp.float32)
            if activation is not None:
                y = activation(y)
            h0_ref[c] = y

        @pl.when((last_ref[g] == 1) & ~is_final & (lid % 2 == 1))
        def _stash_h1():
            y = acc_ref[...] + b_ref[...].astype(jnp.float32)
            if activation is not None:
                y = activation(y)
            h1_ref[c] = y


@functools.partial(
    jax.jit,
    static_argnames=("n_layers", "block", "grid_out_final", "hidden_tiles",
                     "activation", "final_activation", "interpret"),
)
def bsr_megakernel(
    x: jnp.ndarray,           # [B, n_in]
    blocks: jnp.ndarray,      # [nnz_total, bs, bs] flat scheduled order
    rows: jnp.ndarray,        # int32 [nnz_total] layer-local input tile
    cols: jnp.ndarray,        # int32 [nnz_total] layer-local output tile
    first: jnp.ndarray,       # int32 [nnz_total]
    last: jnp.ndarray,        # int32 [nnz_total]
    layer_id: jnp.ndarray,    # int32 [nnz_total]
    hbm_row: jnp.ndarray,     # int32 [nnz_total] x-BlockSpec index
    out_tile: jnp.ndarray,    # int32 [nnz_total] out-BlockSpec index
    bias_idx: jnp.ndarray,    # int32 [nnz_total] bias-tile index
    bias_tiles: jnp.ndarray,  # [total_out_tiles, bs]
    n_layers: int,
    block: int,
    grid_out_final: int,
    hidden_tiles: int,
    activation: Optional[Callable] = None,
    final_activation: Optional[Callable] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Run a whole multi-layer net as ONE ``pallas_call``.

    The grid is the flat cross-layer schedule (``kernels.ops.FlatSchedule``);
    see ``_megakernel`` for the VMEM residency story.  The batch dimension
    must already be padded to the sublane multiple (the engine does this).
    """
    B, n_in = x.shape
    nnz = blocks.shape[0]
    bs = block
    n_out = grid_out_final * bs
    if n_in % bs:
        raise ValueError("n_in must be a multiple of the block size")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8,
        grid=(nnz,),
        in_specs=[
            # input tile: only layer-0 steps move this index; afterwards it
            # is frozen, so the block stays in VMEM untouched
            pl.BlockSpec(
                (B, bs),
                lambda g, lid, r, c, f, l, hbm, out, bidx: (0, hbm[g])),
            # weight block of step g: streamed, double-buffered by the
            # Pallas pipeline
            pl.BlockSpec(
                (1, bs, bs),
                lambda g, lid, r, c, f, l, hbm, out, bidx: (g, 0, 0)),
            # bias tile of the current output tile (any layer)
            pl.BlockSpec(
                (1, bs),
                lambda g, lid, r, c, f, l, hbm, out, bidx: (bidx[g], 0)),
        ],
        out_specs=pl.BlockSpec(
            (B, bs),
            lambda g, lid, r, c, f, l, hbm, out, bidx: (0, out[g])),
        scratch_shapes=[
            pltpu.VMEM((B, bs), jnp.float32),                  # accumulator
            pltpu.VMEM((hidden_tiles, B, bs), jnp.float32),    # hidden ping
            pltpu.VMEM((hidden_tiles, B, bs), jnp.float32),    # hidden pong
        ],
    )
    fn = pl.pallas_call(
        functools.partial(
            _megakernel,
            n_layers=n_layers,
            activation=activation,
            final_activation=final_activation,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_out), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )
    return fn(layer_id, rows, cols, first, last, hbm_row, out_tile, bias_idx,
              x, blocks, bias_tiles)
