"""Scheduled block-sparse matmul — the paper's contribution as a TPU kernel.

``y = act(x @ W + b)`` where W is block-sparse (BSR).  The Pallas grid *is* the
paper's topological order of the connections: one grid step per nonzero weight
block, executed in the (reordered) schedule produced by
``repro.core.blocksparse.schedule_arrays``.

I/O behaviour (the paper's model realized in hardware):
  * the weight block of step g streams HBM->VMEM exactly once        (W reads);
  * the input tile x[:, rows[g]] is fetched only when ``rows[g]`` differs from
    ``rows[g-1]`` — Pallas keeps the block in VMEM across grid steps whose
    index_map result is unchanged                     (input-neuron reads);
  * the f32 accumulator tile lives in VMEM scratch for the *contiguous* run of
    steps sharing ``cols[g]`` (Theorem-1 grouped order), is written back once
    per output tile                                   (writes = S exactly).

The schedule MUST be contiguous-by-output (checked in ops.py) — that is
precisely the Theorem-1 2-optimal family the paper proves sufficient; within
it, Connection Reordering minimizes the input-tile re-fetches.

Scalar-prefetch arrays feed the index maps:
  rows[g], cols[g] — input/output tile of step g,
  first[g]         — 1 iff step g is the first visiting its output tile
                     (zero-initialize the accumulator),
  last[g]          — 1 iff step g is the last (add bias, activate, emit).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(
    # scalar prefetch
    rows_ref, cols_ref, first_ref, last_ref,
    # inputs: x, w, bias [, scale when quant] / outputs / scratch
    x_ref, w_ref, b_ref,
    *rest,
    activation: Optional[Callable],
    quant: bool,
):
    if quant:
        s_ref, o_ref, acc_ref = rest
    else:
        o_ref, acc_ref = rest
    g = pl.program_id(0)

    @pl.when(first_ref[g] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dequant fused right before the dot: the block streamed HBM->VMEM in
    # the narrow dtype; only the VMEM-resident copy is widened
    w = w_ref[0]
    if quant:
        w = w.astype(jnp.float32) * s_ref[0, 0]
    acc_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )

    @pl.when(last_ref[g] == 1)
    def _emit():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if activation is not None:
            y = activation(y)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("grid_out", "activation", "interpret"),
)
def bsr_matmul(
    x: jnp.ndarray,        # [B, n_in]
    blocks: jnp.ndarray,   # [nnz, bm, bn] scheduled order
    rows: jnp.ndarray,     # int32 [nnz]
    cols: jnp.ndarray,     # int32 [nnz]
    first: jnp.ndarray,    # int32 [nnz]
    last: jnp.ndarray,     # int32 [nnz]
    bias: jnp.ndarray,     # [n_out]
    grid_out: int,
    activation: Optional[Callable] = None,
    interpret: bool = False,
    scales: Optional[jnp.ndarray] = None,  # f32 [nnz] dequant (quantized)
) -> jnp.ndarray:
    """Run the scheduled BSR matmul.  See module docstring for the schedule contract."""
    B, n_in = x.shape
    nnz, bm, bn = blocks.shape
    n_out = grid_out * bn
    if n_in % bm:
        raise ValueError("n_in must be a multiple of the block size")
    quant = scales is not None

    in_specs = [
        # input tile: revisits keep it in VMEM while rows[g] is unchanged
        pl.BlockSpec((B, bm), lambda g, rows, cols, first, last: (0, rows[g])),
        # weight block: streamed, one per step
        pl.BlockSpec((1, bm, bn), lambda g, rows, cols, first, last: (g, 0, 0)),
        # bias tile of the current output tile
        pl.BlockSpec((1, bn), lambda g, rows, cols, first, last: (0, cols[g])),
    ]
    if quant:
        # per-block dequant scale of step g: a (1, 1) SMEM scalar
        in_specs.append(pl.BlockSpec(
            (1, 1), lambda g, rows, cols, first, last: (g, 0),
            memory_space=pltpu.SMEM,
        ))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nnz,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (B, bn), lambda g, rows, cols, first, last: (0, cols[g])
        ),
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, activation=activation, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_out), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )
    args = (rows, cols, first, last, x, blocks, bias.reshape(1, -1))
    if quant:
        args += (scales.reshape(-1, 1),)
    return fn(*args)


# --------------------------------------------------------------------------- #
# the whole-network megakernel
# --------------------------------------------------------------------------- #

def _megakernel(
    # scalar prefetch (``occ0_ref`` is appended when gating is on)
    layer_ref, rows_ref, cols_ref, first_ref, last_ref,
    hbm_row_ref, out_tile_ref, bias_idx_ref,
    # inputs / outputs / scratch (layout depends on ``gate``; see below)
    *rest,
    n_layers: int,
    activation: Optional[Callable],
    final_activation: Optional[Callable],
    gate: bool,
    quant: bool,
    valid_b: int,
):
    """One grid step per nonzero block of ANY layer, in whole-net Theorem-1
    order.  The hidden state ping-pongs between two VMEM buffers across layer
    boundaries (layer k reads h[(k-1) % 2], writes h[k % 2]); activations
    never touch HBM between layers.  Weight blocks stream through the Pallas
    pipeline, which double-buffers the ``w_ref`` fetch of step g+1 behind the
    multiply of step g.

    With ``gate=True`` the kernel additionally predicates every
    multiply-accumulate on runtime tile occupancy: a step whose input tile
    holds no nonzero activation in any of the first ``valid_b`` batch rows
    skips its dot (the skipped contribution is exactly ±0, so outputs are
    bit-identical) while everything else — accumulator init, epilogues, the
    streamed ``w_ref`` fetch of the next step — proceeds unchanged, so the
    double-buffered weight pipeline never stalls.  Layer-0 occupancy arrives
    precomputed as the ``occ0_ref`` scalar-prefetch array; hidden-layer
    occupancy is produced *by the kernel itself*: each non-final epilogue
    counts the valid rows with a nonzero in the tile it just activated and
    records the count in the ``occ_ref`` output (constant index map, so the
    buffer is readable across grid steps — the flat schedule guarantees all
    of layer k's epilogues precede any layer k+1 step).  Rows past
    ``valid_b`` are engine batch padding and are excluded from the counts:
    non-odd activation epilogues (sigmoid-style) turn padded zero rows
    nonzero, which must not make a dead tile look live in the measured
    occupancy.

    With ``quant=True`` the streamed ``w_ref`` block is stored in a narrow
    dtype (bf16/fp8) and an extra ``s_ref`` input carries its per-block f32
    scale as a (1, 1) SMEM scalar; dequant (``astype(f32) * scale``) is
    fused right before the dot, so only the VMEM-resident copy is ever
    widened — HBM traffic stays at the narrow width."""
    if gate and quant:
        (occ0_ref, x_ref, w_ref, b_ref, s_ref, o_ref, occ_ref,
         acc_ref, h0_ref, h1_ref) = rest
    elif gate:
        (occ0_ref, x_ref, w_ref, b_ref, o_ref, occ_ref,
         acc_ref, h0_ref, h1_ref) = rest
    elif quant:
        x_ref, w_ref, b_ref, s_ref, o_ref, acc_ref, h0_ref, h1_ref = rest
    else:
        x_ref, w_ref, b_ref, o_ref, acc_ref, h0_ref, h1_ref = rest
    g = pl.program_id(0)
    lid = layer_ref[g]
    r = rows_ref[g]
    w = w_ref[0]
    if quant:
        w = w.astype(jnp.float32) * s_ref[0, 0]

    @pl.when(first_ref[g] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if gate:
        # occupancy of this step's input tile (clamped reads: the occ0 /
        # occ_ref rows not addressed by this layer are never selected)
        alive = occ0_ref[jnp.minimum(r, occ0_ref.shape[0] - 1)] > 0
        if n_layers > 1:
            prev = occ_ref[jnp.maximum(lid - 1, 0),
                           jnp.minimum(r, occ_ref.shape[1] - 1)]
            alive = jnp.where(lid == 0, alive, prev > 0)
    else:
        alive = True

    # multiply-accumulate from this step's input tile (skipped when gating
    # proves the tile dead — the contribution would be exactly zero)
    @pl.when((lid == 0) & alive)
    def _from_hbm():
        acc_ref[...] += jnp.dot(
            x_ref[...], w, preferred_element_type=jnp.float32
        )

    if n_layers > 1:
        @pl.when((lid > 0) & (lid % 2 == 1) & alive)
        def _from_h0():
            acc_ref[...] += jnp.dot(
                h0_ref[r], w, preferred_element_type=jnp.float32
            )

        @pl.when((lid > 0) & (lid % 2 == 0) & alive)
        def _from_h1():
            acc_ref[...] += jnp.dot(
                h1_ref[r], w, preferred_element_type=jnp.float32
            )

    # epilogue on the last visit of the current output tile
    is_final = lid == n_layers - 1

    @pl.when((last_ref[g] == 1) & is_final)
    def _emit():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if final_activation is not None:
            y = final_activation(y)
        o_ref[...] = y.astype(o_ref.dtype)

    if n_layers > 1:
        c = cols_ref[g]

        def _stash(h_ref):
            y = acc_ref[...] + b_ref[...].astype(jnp.float32)
            if activation is not None:
                y = activation(y)
            h_ref[c] = y
            if gate:
                row = jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
                live = jnp.any((y != 0.0) & (row < valid_b),
                               axis=1, keepdims=True)
                occ_ref[lid, c] = jnp.sum(live.astype(jnp.int32))

        @pl.when((last_ref[g] == 1) & ~is_final & (lid % 2 == 0))
        def _stash_h0():
            _stash(h0_ref)

        @pl.when((last_ref[g] == 1) & ~is_final & (lid % 2 == 1))
        def _stash_h1():
            _stash(h1_ref)


@functools.partial(
    jax.jit,
    static_argnames=("n_layers", "block", "grid_out_final", "hidden_tiles",
                     "activation", "final_activation", "interpret",
                     "gate", "valid_b"),
)
def bsr_megakernel(
    x: jnp.ndarray,           # [B, n_in]
    blocks: jnp.ndarray,      # [nnz_total, bs, bs] flat scheduled order
    rows: jnp.ndarray,        # int32 [nnz_total] layer-local input tile
    cols: jnp.ndarray,        # int32 [nnz_total] layer-local output tile
    first: jnp.ndarray,       # int32 [nnz_total]
    last: jnp.ndarray,        # int32 [nnz_total]
    layer_id: jnp.ndarray,    # int32 [nnz_total]
    hbm_row: jnp.ndarray,     # int32 [nnz_total] x-BlockSpec index
    out_tile: jnp.ndarray,    # int32 [nnz_total] out-BlockSpec index
    bias_idx: jnp.ndarray,    # int32 [nnz_total] bias-tile index
    bias_tiles: jnp.ndarray,  # [total_out_tiles, bs]
    occ0: Optional[jnp.ndarray] = None,  # int32 [grid_in_0] (gate only)
    scales: Optional[jnp.ndarray] = None,  # f32 [nnz_total] dequant (quant)
    n_layers: int = 1,
    block: int = 0,
    grid_out_final: int = 0,
    hidden_tiles: int = 1,
    activation: Optional[Callable] = None,
    final_activation: Optional[Callable] = None,
    interpret: bool = False,
    gate: bool = False,
    valid_b: int = 0,
):
    """Run a whole multi-layer net as ONE ``pallas_call``.

    The grid is the flat cross-layer schedule (``kernels.ops.FlatSchedule``);
    see ``_megakernel`` for the VMEM residency story.  The batch dimension
    must already be padded to the sublane multiple (the engine does this).

    With ``gate=True`` the call takes ``occ0`` (the per-input-tile live-row
    counts of ``x``, over its first ``valid_b`` rows — rows past that are
    engine padding) as a ninth scalar-prefetch array and returns
    ``(y, occ)`` where ``occ[k, t]`` is the kernel-measured live-row count
    of hidden activation ``k``'s tile ``t`` — the very counts the gating
    predicates consumed, exported so dynamic I/O is measurable (and the
    padded-row exclusion testable) from outside the kernel.
    """
    B, n_in = x.shape
    nnz = blocks.shape[0]
    bs = block
    n_out = grid_out_final * bs
    if n_in % bs:
        raise ValueError("n_in must be a multiple of the block size")
    quant = scales is not None

    in_specs = [
        # input tile: only layer-0 steps move this index; afterwards it
        # is frozen, so the block stays in VMEM untouched
        pl.BlockSpec((B, bs), lambda g, *s: (0, s[5][g])),
        # weight block of step g: streamed, double-buffered by the
        # Pallas pipeline (gated no-op steps still advance it)
        pl.BlockSpec((1, bs, bs), lambda g, *s: (g, 0, 0)),
        # bias tile of the current output tile (any layer)
        pl.BlockSpec((1, bs), lambda g, *s: (s[7][g], 0)),
    ]
    if quant:
        # per-block dequant scale of step g: a (1, 1) SMEM scalar riding
        # the same pipeline as the narrow weight block it rescales
        in_specs.append(pl.BlockSpec((1, 1), lambda g, *s: (g, 0),
                                     memory_space=pltpu.SMEM))

    # index maps take (g, *scalar_prefetch); variadic so the same lambdas
    # serve both the 8-array and the gated 9-array prefetch layout
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=9 if gate else 8,
        grid=(nnz,),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((B, bs), lambda g, *s: (0, s[6][g])),
            # measured hidden occupancy: whole array SMEM-resident across
            # every grid step (written by epilogues, read by later layers)
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ) if gate else pl.BlockSpec((B, bs), lambda g, *s: (0, s[6][g])),
        scratch_shapes=[
            pltpu.VMEM((B, bs), jnp.float32),                  # accumulator
            pltpu.VMEM((hidden_tiles, B, bs), jnp.float32),    # hidden ping
            pltpu.VMEM((hidden_tiles, B, bs), jnp.float32),    # hidden pong
        ],
    )
    out_shape = jax.ShapeDtypeStruct((B, n_out), x.dtype)
    if gate:
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((max(1, n_layers - 1),
                                           hidden_tiles), jnp.int32))
    fn = pl.pallas_call(
        functools.partial(
            _megakernel,
            n_layers=n_layers,
            activation=activation,
            final_activation=final_activation,
            gate=gate,
            quant=quant,
            valid_b=valid_b,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )
    prefetch = (layer_id, rows, cols, first, last, hbm_row, out_tile,
                bias_idx)
    if gate:
        prefetch += (occ0,)
    args = (x, blocks, bias_tiles)
    if quant:
        args += (scales.reshape(-1, 1),)
    return fn(*prefetch, *args)
