"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""

from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np


def bsr_to_dense(rows, cols, blocks, grid_in: int, grid_out: int) -> jnp.ndarray:
    """Scatter BSR blocks into the dense [n_in, n_out] weight matrix."""
    bm, bn = blocks.shape[1], blocks.shape[2]
    w = jnp.zeros((grid_in * bm, grid_out * bn), dtype=blocks.dtype)
    for r, c, b in zip(np.asarray(rows), np.asarray(cols), blocks):
        w = w.at[int(r) * bm:(int(r) + 1) * bm, int(c) * bn:(int(c) + 1) * bn].set(b)
    return w


def bsr_matmul_ref(
    x: jnp.ndarray,
    rows: np.ndarray,
    cols: np.ndarray,
    blocks: jnp.ndarray,
    bias: jnp.ndarray,
    grid_in: int,
    grid_out: int,
    activation: Optional[Callable] = None,
) -> jnp.ndarray:
    """Oracle: y = act(x @ dense(W) + b), accumulated in float32."""
    w = bsr_to_dense(rows, cols, blocks, grid_in, grid_out)
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = activation(y)
    return y.astype(x.dtype)


def moe_gemm_ref(
    x: jnp.ndarray,          # [tokens, d]
    w_up: jnp.ndarray,       # [experts, d, f]
    w_down: jnp.ndarray,     # [experts, f, d]
    assign: jnp.ndarray,     # [tokens, k] expert ids
    gates: jnp.ndarray,      # [tokens, k]
    activation: Callable,
) -> jnp.ndarray:
    """Oracle for the grouped expert FFN: sum_k g_k * FFN_{e_k}(x)."""
    x32 = x.astype(jnp.float32)
    out = jnp.zeros_like(x32)
    for k in range(assign.shape[1]):
        e = assign[:, k]
        up = jnp.einsum("td,tdf->tf", x32, w_up.astype(jnp.float32)[e])
        h = activation(up)
        dn = jnp.einsum("tf,tfd->td", h, w_down.astype(jnp.float32)[e])
        out = out + gates[:, k:k + 1].astype(jnp.float32) * dn
    return out.astype(x.dtype)
