"""Jitted public wrappers around the Pallas kernels.

``scheduled_bsr_layer`` is the user-facing op: it takes a ``BSRLayer`` plus a
block schedule (from ``core.blocksparse.schedule_arrays``), enforces the
Theorem-1 contiguity contract, patches empty output tiles, and dispatches to
the Pallas kernel (TPU) or the jnp oracle (non-TPU backends).

``compile_flat_schedule`` concatenates the per-layer schedules of a whole
network into one cross-layer :class:`FlatSchedule` — the input of the
megakernel (``bsr_matmul.bsr_megakernel``), which walks every nonzero block
of every layer in one Pallas grid and keeps the hidden state VMEM-resident
across layer boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocksparse import BSRLayer, is_contiguous_by_output
from . import ref
from .bsr_matmul import bsr_matmul

try:  # narrow weight-stream dtypes (already a jax dependency)
    import ml_dtypes as _ml_dtypes
except ImportError:  # pragma: no cover - jax always ships it
    _ml_dtypes = None

#: fp8 storage dtype of the quantized weight stream; None when the installed
#: ml_dtypes predates float8 support (tests monkeypatch this to exercise the
#: graceful compile-time guard).
FP8_DTYPE = getattr(_ml_dtypes, "float8_e4m3fn", None)
BF16_DTYPE = getattr(_ml_dtypes, "bfloat16", None)

#: largest finite magnitude representable in float8_e4m3fn — the per-block
#: scale maps each block's absmax onto it (the DeepSeek-V3 block-128 scheme
#: at our tile granularity).
FP8_MAX = 448.0

WEIGHT_DTYPES = ("f32", "bf16", "fp8")

_WEIGHT_DTYPE_ALIASES = {
    None: "f32", "f32": "f32", "float32": "f32", "fp32": "f32",
    "bf16": "bf16", "bfloat16": "bf16",
    "fp8": "fp8", "f8": "fp8", "float8": "fp8", "float8_e4m3fn": "fp8",
}


def resolve_weight_dtype(name) -> str:
    """Normalize a weight-stream dtype spec to ``f32`` | ``bf16`` | ``fp8``.

    Raises a clear ``ValueError`` at compile time when fp8 is requested but
    the installed ``ml_dtypes`` lacks ``float8_e4m3fn`` — never a deep
    kernel ``TypeError`` later.
    """
    key = name.lower() if isinstance(name, str) else name
    try:
        wdt = _WEIGHT_DTYPE_ALIASES[key]
    except KeyError:
        raise ValueError(
            f"unknown weight_dtype {name!r}; pick from {WEIGHT_DTYPES}"
        ) from None
    if wdt == "fp8" and FP8_DTYPE is None:
        raise ValueError(
            "weight_dtype='fp8' needs ml_dtypes with float8_e4m3fn; this "
            "installation lacks it — use 'bf16' or 'f32'"
        )
    if wdt == "bf16" and BF16_DTYPE is None:
        raise ValueError(
            "weight_dtype='bf16' needs ml_dtypes with bfloat16; this "
            "installation lacks it — use 'f32'"
        )
    return wdt


def weight_itemsize(weight_dtype: str) -> int:
    """Bytes per weight element in the streamed (storage) dtype."""
    return {"f32": 4, "bf16": 2, "fp8": 1}[resolve_weight_dtype(weight_dtype)]


def quantize_blocks(
    blocks: np.ndarray, weight_dtype: str
) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Quantize ``[nnz, bm, bn]`` f32 blocks to the narrow storage dtype.

    Returns ``(qblocks, scales)`` where ``scales`` is one f32 factor per
    block (``None`` for f32: identity).  Dequant is ``q.astype(f32) *
    scale``.  bf16 keeps unit scales (its exponent range matches f32);
    fp8 maps each block's absmax onto ``FP8_MAX`` so the 4-bit mantissa is
    spent on the block's actual dynamic range.  All-zero blocks (including
    the bias-patch blocks) get scale 1.0, so they dequantize to exact zero.
    """
    wdt = resolve_weight_dtype(weight_dtype)
    blocks = np.asarray(blocks, dtype=np.float32)
    if wdt == "f32":
        return blocks, None
    nnz = blocks.shape[0]
    if wdt == "bf16":
        return blocks.astype(BF16_DTYPE), np.ones(nnz, dtype=np.float32)
    amax = np.max(np.abs(blocks), axis=(1, 2))
    scales = np.where(amax > 0, amax / FP8_MAX, 1.0).astype(np.float32)
    q = (blocks / scales[:, None, None]).astype(FP8_DTYPE)
    return q, scales


@dataclasses.dataclass
class CompiledSchedule:
    """A validated, kernel-ready block schedule for one BSR layer."""

    blocks: jnp.ndarray   # [nnz', bm, bn] in schedule order (incl. patch blocks)
    rows: jnp.ndarray     # int32 [nnz']
    cols: jnp.ndarray     # int32 [nnz']
    first: jnp.ndarray
    last: jnp.ndarray
    grid_out: int
    # simulated tile traffic of this schedule (reads, writes) under the
    # single-resident-tile VMEM model — the paper's I/O count for M=3.
    sim_reads: int
    sim_writes: int
    # quantized weight stream: ``blocks`` is stored in the narrow dtype and
    # ``scales`` holds one f32 dequant factor per block (None for f32)
    scales: Optional[jnp.ndarray] = None
    weight_dtype: str = "f32"

    @property
    def weight_bytes(self) -> int:
        """Bytes the kernel streams for this layer's weight blocks."""
        return int(np.asarray(self.blocks).nbytes)

    @property
    def scale_bytes(self) -> int:
        return 0 if self.scales is None else int(np.asarray(self.scales).nbytes)


def compile_schedule(
    layer: BSRLayer,
    perm: Optional[np.ndarray] = None,
    weight_dtype: str = "f32",
) -> CompiledSchedule:
    """Validate + pack a schedule.  ``perm`` permutes the layer's block storage
    (default: as stored).  Raises if the schedule is not contiguous-by-output —
    the Theorem-1 family the kernel's VMEM-resident accumulator requires."""
    if perm is None:
        perm = np.arange(layer.nnz_blocks)
    perm = np.asarray(perm, dtype=np.int64)
    rows = layer.rows[perm].astype(np.int32)
    cols = layer.cols[perm].astype(np.int32)
    blocks = layer.blocks[perm]
    if not is_contiguous_by_output(cols):
        raise ValueError(
            "schedule is not contiguous by output tile; use a Theorem-1 "
            "(grouped-by-output) order — see core.blocksparse.schedule_arrays"
        )
    # patch: output tiles with no nonzero block still need bias+activation.
    present = np.zeros(layer.grid_out, dtype=bool)
    present[cols] = True
    missing = np.flatnonzero(~present).astype(np.int32)
    if len(missing):
        zero = np.zeros((len(missing), layer.block_m, layer.block_n), blocks.dtype)
        blocks = np.concatenate([blocks, zero])
        rows = np.concatenate([rows, np.zeros(len(missing), np.int32)])
        cols = np.concatenate([cols, missing])
    nnz = len(rows)
    first = np.zeros(nnz, np.int32)
    last = np.zeros(nnz, np.int32)
    first[0] = 1
    first[1:] = (cols[1:] != cols[:-1]).astype(np.int32)
    last[-1] = 1
    last[:-1] = (cols[1:] != cols[:-1]).astype(np.int32)
    # simulated tile I/O: weight blocks stream once each; an input tile is
    # re-read whenever rows[] changes; one write per output tile.
    row_changes = 1 + int((rows[1:] != rows[:-1]).sum()) if nnz else 0
    sim_reads = nnz + row_changes + layer.grid_out  # + bias tiles
    sim_writes = layer.grid_out
    qblocks, scales = quantize_blocks(blocks, weight_dtype)
    return CompiledSchedule(
        blocks=jnp.asarray(qblocks),
        rows=jnp.asarray(rows),
        cols=jnp.asarray(cols),
        first=jnp.asarray(first),
        last=jnp.asarray(last),
        grid_out=layer.grid_out,
        sim_reads=sim_reads,
        sim_writes=sim_writes,
        scales=None if scales is None else jnp.asarray(scales),
        weight_dtype=resolve_weight_dtype(weight_dtype),
    )


@dataclasses.dataclass
class FlatSchedule:
    """One whole-network block schedule: all layers' steps in one flat grid.

    The per-step arrays are the per-layer ``CompiledSchedule`` arrays
    concatenated in layer order (each layer segment keeps its Theorem-1
    contiguous-by-output grouping), plus the cross-layer scalar-prefetch
    arrays the megernel's index maps need:

      * ``layer_id[g]`` — which layer step ``g`` belongs to;
      * ``hbm_row[g]``  — the HBM input tile to map into VMEM: ``rows[g]``
        during layer 0, then frozen (no index change => no re-fetch) since
        later layers read the VMEM-resident hidden state instead;
      * ``out_tile[g]`` — the HBM output tile to map: ``cols[g]`` during the
        final layer, else pinned to the final layer's first output tile so
        the out buffer is never flushed before it holds real data;
      * ``bias_idx[g]`` — row of ``bias_tiles`` ([total output tiles, bs])
        holding the bias of step ``g``'s output tile.

    ``segments[k] = (start, end)`` delimits layer ``k``'s steps; the ``jnp``
    lowering consumes exactly these flat arrays one segment at a time, so
    all backends execute the identical connection order.
    """

    blocks: jnp.ndarray       # [nnz_total, bs, bs] scheduled order
    rows: jnp.ndarray         # int32 [nnz_total] layer-local input tile
    cols: jnp.ndarray         # int32 [nnz_total] layer-local output tile
    first: jnp.ndarray        # int32 [nnz_total]
    last: jnp.ndarray         # int32 [nnz_total]
    layer_id: jnp.ndarray     # int32 [nnz_total]
    hbm_row: jnp.ndarray      # int32 [nnz_total]
    out_tile: jnp.ndarray     # int32 [nnz_total]
    bias_idx: jnp.ndarray     # int32 [nnz_total]
    bias_tiles: jnp.ndarray   # [sum(grid_out_k), bs]
    segments: Tuple[Tuple[int, int], ...]
    n_layers: int
    block: int                # uniform tile size
    grid_out_final: int
    n_out: int
    hidden_tiles: int         # max tile count of any intermediate activation
    # simulated per-layer tile traffic (reads, writes) — flat totals are the
    # sums, which tests check against the per-layer reports
    per_layer_io: Tuple[Tuple[int, int], ...]
    # quantized weight stream: ``blocks`` is stored narrow, ``scales`` is one
    # f32 dequant factor per flat step (None for f32)
    scales: Optional[jnp.ndarray] = None
    weight_dtype: str = "f32"

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def weight_bytes(self) -> int:
        """Bytes of weight blocks the megakernel streams per forward."""
        return int(np.asarray(self.blocks).nbytes)

    @property
    def scale_bytes(self) -> int:
        return 0 if self.scales is None else int(np.asarray(self.scales).nbytes)

    @property
    def sim_reads(self) -> int:
        return sum(r for r, _ in self.per_layer_io)

    @property
    def sim_writes(self) -> int:
        return sum(w for _, w in self.per_layer_io)


def compile_flat_schedule(
    layers: Sequence[BSRLayer],
    schedules: Sequence[CompiledSchedule],
) -> FlatSchedule:
    """Concatenate per-layer schedules into one megakernel-ready flat schedule.

    Requires a uniform tile size: all layers must share ``block_m`` /
    ``block_n`` (and square tiles when depth > 1, since layer k's output
    tiles are layer k+1's input tiles).  Raises ``ValueError`` otherwise —
    the engine falls back to per-layer dispatch in that case.
    """
    if not layers or len(layers) != len(schedules):
        raise ValueError("need one schedule per layer")
    bs = layers[0].block_m
    for lay in layers:
        if lay.block_m != bs or lay.block_n != bs:
            raise ValueError(
                "flat schedule requires one uniform square tile size across "
                f"layers; got ({lay.block_m}, {lay.block_n}) vs {bs}"
            )

    rows_l: List[np.ndarray] = []
    cols_l: List[np.ndarray] = []
    first_l: List[np.ndarray] = []
    last_l: List[np.ndarray] = []
    lid_l: List[np.ndarray] = []
    segments: List[Tuple[int, int]] = []
    per_layer_io: List[Tuple[int, int]] = []
    off = 0
    for k, sch in enumerate(schedules):
        n = int(sch.rows.shape[0])
        rows_l.append(np.asarray(sch.rows, dtype=np.int32))
        cols_l.append(np.asarray(sch.cols, dtype=np.int32))
        first_l.append(np.asarray(sch.first, dtype=np.int32))
        last_l.append(np.asarray(sch.last, dtype=np.int32))
        lid_l.append(np.full(n, k, dtype=np.int32))
        segments.append((off, off + n))
        per_layer_io.append((sch.sim_reads, sch.sim_writes))
        off += n
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    first = np.concatenate(first_l)
    last = np.concatenate(last_l)
    layer_id = np.concatenate(lid_l)

    # hbm_row: live during layer 0, frozen afterwards (constant index map
    # result => Pallas keeps the current block in VMEM, no extra fetch)
    n0 = segments[0][1]
    hbm_row = rows.copy()
    if off > n0:
        hbm_row[n0:] = hbm_row[n0 - 1]
    # out_tile: live during the final layer, pinned to its first output tile
    # before that (the buffer holds garbage until the final layer's first
    # epilogue overwrites it in place, so nothing bogus is ever flushed)
    fs, fe = segments[-1]
    out_tile = np.full(off, int(cols[fs]), dtype=np.int32)
    out_tile[fs:fe] = cols[fs:fe]
    # flat bias tiles + per-step bias row
    bias_off = np.zeros(len(layers) + 1, dtype=np.int64)
    for k, lay in enumerate(layers):
        bias_off[k + 1] = bias_off[k] + lay.grid_out
    bias_idx = (bias_off[layer_id] + cols).astype(np.int32)
    bias_tiles = np.concatenate(
        [np.asarray(lay.bias, dtype=np.float32).reshape(lay.grid_out, -1)
         for lay in layers])

    wdt = schedules[0].weight_dtype
    for sch in schedules:
        if sch.weight_dtype != wdt:
            raise ValueError(
                "flat schedule requires one weight_dtype across layers; got "
                f"{sch.weight_dtype!r} vs {wdt!r}"
            )
    scales = None if wdt == "f32" else \
        jnp.concatenate([sch.scales for sch in schedules])

    hidden_tiles = max([lay.grid_out for lay in layers[:-1]] or [1])
    return FlatSchedule(
        blocks=jnp.concatenate([sch.blocks for sch in schedules]),
        rows=jnp.asarray(rows),
        cols=jnp.asarray(cols),
        first=jnp.asarray(first),
        last=jnp.asarray(last),
        layer_id=jnp.asarray(layer_id),
        hbm_row=jnp.asarray(hbm_row),
        out_tile=jnp.asarray(out_tile),
        bias_idx=jnp.asarray(bias_idx),
        bias_tiles=jnp.asarray(bias_tiles),
        segments=tuple(segments),
        n_layers=len(layers),
        block=bs,
        grid_out_final=layers[-1].grid_out,
        n_out=layers[-1].n_out,
        hidden_tiles=int(hidden_tiles),
        per_layer_io=tuple(per_layer_io),
        scales=scales,
        weight_dtype=wdt,
    )


def scheduled_bsr_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: Optional[CompiledSchedule] = None,
    activation: Optional[Callable] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = act(x @ W_bsr + b) via the scheduled Pallas kernel.

    On non-TPU backends ``interpret`` defaults to True (the Pallas body runs
    in Python — the correctness path used by tests on CPU).
    """
    if schedule is None:
        schedule = compile_schedule(layer)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return bsr_matmul(
        x,
        schedule.blocks,
        schedule.rows,
        schedule.cols,
        schedule.first,
        schedule.last,
        jnp.asarray(layer.bias),
        grid_out=schedule.grid_out,
        activation=activation,
        interpret=interpret,
        scales=schedule.scales,
    )


def bsr_layer_ref(
    x: jnp.ndarray,
    layer: BSRLayer,
    activation: Optional[Callable] = None,
) -> jnp.ndarray:
    """Oracle wrapper with the same signature family as scheduled_bsr_layer."""
    return ref.bsr_matmul_ref(
        x, layer.rows, layer.cols, jnp.asarray(layer.blocks),
        jnp.asarray(layer.bias), layer.grid_in, layer.grid_out, activation,
    )
