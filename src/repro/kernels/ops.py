"""Jitted public wrappers around the Pallas kernels.

``scheduled_bsr_layer`` is the user-facing op: it takes a ``BSRLayer`` plus a
block schedule (from ``core.blocksparse.schedule_arrays``), enforces the
Theorem-1 contiguity contract, patches empty output tiles, and dispatches to
the Pallas kernel (TPU) or the jnp oracle (non-TPU backends).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocksparse import BSRLayer, is_contiguous_by_output
from . import ref
from .bsr_matmul import bsr_matmul


@dataclasses.dataclass
class CompiledSchedule:
    """A validated, kernel-ready block schedule for one BSR layer."""

    blocks: jnp.ndarray   # [nnz', bm, bn] in schedule order (incl. patch blocks)
    rows: jnp.ndarray     # int32 [nnz']
    cols: jnp.ndarray     # int32 [nnz']
    first: jnp.ndarray
    last: jnp.ndarray
    grid_out: int
    # simulated tile traffic of this schedule (reads, writes) under the
    # single-resident-tile VMEM model — the paper's I/O count for M=3.
    sim_reads: int
    sim_writes: int


def compile_schedule(
    layer: BSRLayer,
    perm: Optional[np.ndarray] = None,
) -> CompiledSchedule:
    """Validate + pack a schedule.  ``perm`` permutes the layer's block storage
    (default: as stored).  Raises if the schedule is not contiguous-by-output —
    the Theorem-1 family the kernel's VMEM-resident accumulator requires."""
    if perm is None:
        perm = np.arange(layer.nnz_blocks)
    perm = np.asarray(perm, dtype=np.int64)
    rows = layer.rows[perm].astype(np.int32)
    cols = layer.cols[perm].astype(np.int32)
    blocks = layer.blocks[perm]
    if not is_contiguous_by_output(cols):
        raise ValueError(
            "schedule is not contiguous by output tile; use a Theorem-1 "
            "(grouped-by-output) order — see core.blocksparse.schedule_arrays"
        )
    # patch: output tiles with no nonzero block still need bias+activation.
    present = np.zeros(layer.grid_out, dtype=bool)
    present[cols] = True
    missing = np.flatnonzero(~present).astype(np.int32)
    if len(missing):
        zero = np.zeros((len(missing), layer.block_m, layer.block_n), blocks.dtype)
        blocks = np.concatenate([blocks, zero])
        rows = np.concatenate([rows, np.zeros(len(missing), np.int32)])
        cols = np.concatenate([cols, missing])
    nnz = len(rows)
    first = np.zeros(nnz, np.int32)
    last = np.zeros(nnz, np.int32)
    first[0] = 1
    first[1:] = (cols[1:] != cols[:-1]).astype(np.int32)
    last[-1] = 1
    last[:-1] = (cols[1:] != cols[:-1]).astype(np.int32)
    # simulated tile I/O: weight blocks stream once each; an input tile is
    # re-read whenever rows[] changes; one write per output tile.
    row_changes = 1 + int((rows[1:] != rows[:-1]).sum()) if nnz else 0
    sim_reads = nnz + row_changes + layer.grid_out  # + bias tiles
    sim_writes = layer.grid_out
    return CompiledSchedule(
        blocks=jnp.asarray(blocks),
        rows=jnp.asarray(rows),
        cols=jnp.asarray(cols),
        first=jnp.asarray(first),
        last=jnp.asarray(last),
        grid_out=layer.grid_out,
        sim_reads=sim_reads,
        sim_writes=sim_writes,
    )


def scheduled_bsr_layer(
    x: jnp.ndarray,
    layer: BSRLayer,
    schedule: Optional[CompiledSchedule] = None,
    activation: Optional[Callable] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = act(x @ W_bsr + b) via the scheduled Pallas kernel.

    On non-TPU backends ``interpret`` defaults to True (the Pallas body runs
    in Python — the correctness path used by tests on CPU).
    """
    if schedule is None:
        schedule = compile_schedule(layer)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return bsr_matmul(
        x,
        schedule.blocks,
        schedule.rows,
        schedule.cols,
        schedule.first,
        schedule.last,
        jnp.asarray(layer.bias),
        grid_out=schedule.grid_out,
        activation=activation,
        interpret=interpret,
    )


def bsr_layer_ref(
    x: jnp.ndarray,
    layer: BSRLayer,
    activation: Optional[Callable] = None,
) -> jnp.ndarray:
    """Oracle wrapper with the same signature family as scheduled_bsr_layer."""
    return ref.bsr_matmul_ref(
        x, layer.rows, layer.cols, jnp.asarray(layer.blocks),
        jnp.asarray(layer.bias), layer.grid_in, layer.grid_out, activation,
    )
