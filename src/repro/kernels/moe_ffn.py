"""Fused grouped expert-FFN kernel (MoE) — out[e] = act(x[e] @ Wu[e]) @ Wd[e].

The MoE FFN is the paper's sparse FFNN at datacenter scale: each token uses
only top-k of E experts, i.e. a block-sparse weight structure.  The I/O win of
this kernel is the paper's theme applied one level up: the hidden activation
tile h = act(x @ Wu) never leaves VMEM (no HBM round-trip of [C, f] per
expert), mirroring how Algorithm 1 keeps partial sums in fast memory for the
whole contiguous interval of their connections.

Grid: (experts, f_tiles).  The f dimension is tiled so the per-step VMEM
working set (x tile, Wu/Wd slices, f32 accumulator) fits the budget; the
accumulator persists across the f_tiles of one expert (contiguous — the
Theorem-1 pattern) and is emitted once.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(x_ref, wu_ref, wd_ref, o_ref, acc_ref, *, activation: Callable,
            f_tiles: int):
    ft = pl.program_id(1)

    @pl.when(ft == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = jnp.dot(x_ref[0], wu_ref[0], preferred_element_type=jnp.float32)
    h = activation(h).astype(x_ref.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(ft == f_tiles - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "f_tile", "interpret"))
def moe_ffn(
    x: jnp.ndarray,       # [E, C, d]   capacity-grouped tokens
    w_up: jnp.ndarray,    # [E, d, f]
    w_down: jnp.ndarray,  # [E, f, d]
    activation: Callable = jax.nn.gelu,
    f_tile: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    E, C, d = x.shape
    f = w_up.shape[2]
    if f % f_tile:
        raise ValueError("f must be a multiple of f_tile")
    f_tiles = f // f_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(E, f_tiles),
        in_specs=[
            pl.BlockSpec((1, C, d), lambda e, ft: (e, 0, 0)),        # x[e]: reused across ft
            pl.BlockSpec((1, d, f_tile), lambda e, ft: (e, 0, ft)),  # Wu slice
            pl.BlockSpec((1, f_tile, d), lambda e, ft: (e, ft, 0)),  # Wd slice
        ],
        out_specs=pl.BlockSpec((1, C, d), lambda e, ft: (e, 0, 0)),
        scratch_shapes=[pltpu.VMEM((C, d), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, activation=activation, f_tiles=f_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, C, d), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )
    return fn(x, w_up, w_down)
