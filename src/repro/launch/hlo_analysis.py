"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis`` yields per-device HLO FLOPs and bytes (the module is the
post-SPMD per-device program); collective bytes are parsed from the compiled
HLO text by summing *operand* bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.  Terms:

    compute    = flops_per_device / 197e12          (= global/(chips*peak))
    memory     = bytes_per_device / 819e9
    collective = coll_bytes_per_device / 50e9
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[^\]]*\]\S*)\s+([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"(%[\w\.\-]+)")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string: 'f32[128,64]{1,0}' or a tuple thereof."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_op: Dict[str, int]
    count: int


# ---------------------------------------------------------------------------
# trip-count-aware HLO module analysis
#
# XLA's HloCostAnalysis (compiled.cost_analysis()) visits while/scan bodies
# ONCE, so flops and collective bytes of layer stacks expressed as lax.scan
# are undercounted by the trip count.  We parse the compiled module text into
# computations, infer while trip counts from the loop-condition constant, and
# aggregate dot-FLOPs and collective operand bytes bottom-up with multipliers.
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALL_ATTR = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"\{?(%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)\}?")
_DOT_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_SHAPE_OF = re.compile(r"^([a-z0-9]+)\[([\d,]*)\]")
_CONSTANT = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _parse_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _dims_of(type_str: str):
    m = _SHAPE_OF.match(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class ModuleCost:
    flops: float
    coll_by_op: Dict[str, float]
    n_whiles: int
    trip_counts: list

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll_by_op.values())


def analyze_module(hlo_text: str) -> ModuleCost:
    # strip /*...*/ comments: tuple types embed "/*index=N*/" markers whose '='
    # breaks the type-string regex
    hlo_text = re.sub(r"/\*.*?\*/", "", hlo_text)
    comps = _parse_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
    memo: Dict[str, Tuple[float, Dict[str, float]]] = {}
    whiles = []

    def trip_count(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for c in _CONSTANT.findall(line):
                best = max(best, int(c))
        return best

    def cost(name: str) -> Tuple[float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        memo[name] = (0.0, {})  # cycle guard
        flops = 0.0
        coll: Dict[str, float] = {}
        sizes: Dict[str, int] = {}
        lines = comps.get(name, [])
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            opname, type_str, op = m.groups()
            sizes[opname] = shape_bytes(type_str)
            if op == "dot":
                res = _dims_of(type_str)
                cd = _DOT_DIMS.search(line)
                k = 1
                if cd:
                    ops = _OPERAND_RE.findall(line[m.end():])
                    lhs = ops[0] if ops else None
                    lhs_dims = None
                    if lhs is not None:
                        for l2 in lines:
                            m2 = _DEF_RE.match(l2)
                            if m2 and m2.group(1) == lhs:
                                lhs_dims = _dims_of(m2.group(2))
                                break
                        if lhs_dims is None:
                            mm = re.search(re.escape(lhs) +
                                           r"\s*=\s*([a-z0-9]+\[[\d,]*\])", "\n".join(lines))
                            if mm:
                                lhs_dims = _dims_of(mm.group(1))
                    if lhs_dims and cd.group(1):
                        for idx in cd.group(1).split(","):
                            i = int(idx)
                            if i < len(lhs_dims):
                                k *= lhs_dims[i]
                if res is not None:
                    n = 1
                    for d in res:
                        n *= d
                    flops += 2.0 * n * k
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in COLLECTIVES and not op.endswith("-done"):
                args = line[m.end():]
                depth, out = 1, []
                for ch in args:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    out.append(ch)
                onames = _OPERAND_RE.findall("".join(out))
                b = sum(sizes.get(o, shape_bytes(o_lookup(lines, o))) for o in onames)
                coll[base_op] = coll.get(base_op, 0.0) + b
            if op == "while":
                mm = re.search(r"condition=(%[\w\.\-]+),?\s*body=(%[\w\.\-]+)", line)
                if not mm:
                    mm = re.search(r"body=(%[\w\.\-]+),?\s*condition=(%[\w\.\-]+)", line)
                    cond, body = (mm.group(2), mm.group(1)) if mm else (None, None)
                else:
                    cond, body = mm.group(1), mm.group(2)
                if body:
                    t = trip_count(cond) if cond else 1
                    whiles.append(t)
                    bf, bc = cost(body)
                    cf, cc = cost(cond) if cond else (0.0, {})
                    flops += t * (bf + cf)
                    for k2, v in bc.items():
                        coll[k2] = coll.get(k2, 0.0) + t * v
                    for k2, v in cc.items():
                        coll[k2] = coll.get(k2, 0.0) + t * v
            elif op in ("fusion", "call", "conditional", "map", "reduce",
                        "reduce-window", "scatter", "sort", "select-and-scatter",
                        "all-reduce", "reduce-scatter"):
                mm = _CALL_ATTR.search(line)
                if mm:
                    for sub in mm.group(1).split(","):
                        sub = sub.strip()
                        sf, sc = cost(sub)
                        flops += sf
                        for k2, v in sc.items():
                            coll[k2] = coll.get(k2, 0.0) + v
        memo[name] = (flops, coll)
        return memo[name]

    def o_lookup(lines, name):
        for l2 in lines:
            m2 = _DEF_RE.match(l2)
            if m2 and m2.group(1) == name:
                return m2.group(2)
        return ""

    if entry is None:
        return ModuleCost(0.0, {}, 0, [])
    f, c = cost(entry)
    return ModuleCost(flops=f, coll_by_op=c, n_whiles=len(whiles),
                      trip_counts=whiles)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Trip-count-aware collective operand bytes of a per-device module."""
    mc = analyze_module(hlo_text)
    return CollectiveStats(
        total_bytes=int(mc.coll_bytes),
        by_op={k: int(v) for k, v in mc.coll_by_op.items()},
        count=mc.n_whiles)


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float     # MODEL_FLOPS / (HLO_FLOPs * chips)
    coll_by_op: Dict[str, int]
    arg_bytes: int = 0
    temp_bytes: int = 0
    out_bytes: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(module_cost: "ModuleCost", coll: CollectiveStats, n_chips: int,
             model_flops: float, mem_stats=None,
             xla_cost: Optional[Dict] = None) -> Roofline:
    """Three-term roofline from the trip-aware module analysis.

    compute: dot-FLOPs per device (while bodies x trip count) / peak;
    memory: per-device HBM bytes touched — arguments + outputs + temp buffers
    from the real buffer assignment (a one-pass lower bound on HBM traffic);
    collective: per-device collective operand bytes / per-chip link bw."""
    flops = float(module_cost.flops)
    if mem_stats is not None:
        byt = float(mem_stats.argument_size_in_bytes
                    + mem_stats.output_size_in_bytes
                    + mem_stats.temp_size_in_bytes)
    else:
        byt = float((xla_cost or {}).get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = byt / HBM_BW
    coll_s = coll.total_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / max(1.0, flops * n_chips)
    kw = {}
    if mem_stats is not None:
        kw = dict(arg_bytes=mem_stats.argument_size_in_bytes,
                  temp_bytes=mem_stats.temp_size_in_bytes,
                  out_bytes=mem_stats.output_size_in_bytes)
    return Roofline(
        flops_per_dev=flops, bytes_per_dev=byt,
        coll_bytes_per_dev=float(coll.total_bytes),
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        coll_by_op=coll.by_op, **kw)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (fwd only);
    N = active params for MoE.  Enc-dec splits N between the encoder (sees
    B·S source frames) and the decoder (sees B·S/tgt_frac target tokens)."""
    B, S = shape.global_batch, shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    if cfg.family == "encdec":
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
        att = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
            + cfg.n_heads * hd * d
        mlp_p = 2 * d * f
        n_enc = cfg.n_enc_layers * (att + mlp_p)
        n_dec = cfg.n_dec_layers * (2 * att + mlp_p) + 2 * cfg.vocab * d
        if shape.kind == "decode":
            return 2.0 * n_dec * B
        d_src = B * S
        d_tgt = B * S // cfg.tgt_frac
        return mult * (n_enc * d_src + n_dec * d_tgt)
    n = cfg.n_active_params()
    if shape.kind == "decode":
        return 2.0 * n * B
    return mult * n * B * S
