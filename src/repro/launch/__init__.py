"""Launch layer: meshes, partitioning, steps, dry-run, drivers."""
