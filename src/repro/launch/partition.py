"""Parameter / optimizer / cache / batch partition rules.

Megatron-style TP over the ``model`` axis, DP over (``pod``, ``data``),
expert-parallel MoE weights over ``model``, vocab-sharded embeddings, and
ZeRO-1-style extra data-axis sharding on optimizer-state leaves.

Rules are name-based over the pytree paths produced by the model inits; dims
that are only conditionally shardable (kv heads < tp, odd feature packs) fall
back to replication via divisibility checks against the concrete mesh.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.sharding import div_or_none

from .mesh import dp_axes, dp_size, tp_size


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _in_layers(path) -> bool:
    keys = [str(getattr(e, "key", "")) for e in path]
    return any(k in ("layers", "enc_layers", "dec_layers") for k in keys)


def _div(mesh, axis: Optional[str], n: int) -> Optional[str]:
    # one divisibility rule for the whole tree: the shared helper in
    # repro.models.sharding (argument order flipped for the rule table)
    return div_or_none(n, axis, mesh)


def param_spec(mesh, path, shape) -> P:
    """PartitionSpec for one parameter leaf (shape WITHOUT accounting for the
    stacked layer dim — pass the real leaf shape; stacking handled here)."""
    name = _leaf_name(path)
    stacked = _in_layers(path)
    core = tuple(shape[1:]) if stacked else tuple(shape)
    tp = "model" if "model" in mesh.axis_names else None

    def spec(*axes):
        axes = tuple(axes)
        if stacked:
            axes = (None,) + axes
        return P(*axes)

    nd = len(core)
    if name == "embed":
        return spec(_div(mesh, tp, core[0]), None)
    if name == "unembed":
        return spec(None, _div(mesh, tp, core[1]))
    if name in ("wq", "wk", "wv"):
        return spec(None, _div(mesh, tp, core[1]))
    if name == "wo":
        return spec(_div(mesh, tp, core[0]), None)
    if name in ("up", "gate"):
        if nd == 3:   # MoE experts [E, d, f] — expert parallel
            return spec(_div(mesh, tp, core[0]), None, None)
        return spec(None, _div(mesh, tp, core[1]))
    if name == "down":
        if nd == 3:
            return spec(_div(mesh, tp, core[0]), None, None)
        return spec(_div(mesh, tp, core[0]), None)
    if name == "router":
        return spec(None, None)
    if name == "in_proj":
        return spec(None, _div(mesh, tp, core[1]))
    if name == "out_proj":
        return spec(_div(mesh, tp, core[0]), None)
    if name in ("conv", "conv_bias"):
        return spec(*([None] * (nd - 1) + [_div(mesh, tp, core[-1])]))
    # norms, biases, scalars: replicate
    return spec(*([None] * nd))


def params_specs(mesh, params_shape) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(mesh, path, leaf.shape), params_shape)


def opt_specs(mesh, opt_shape, p_specs) -> Any:
    """Optimizer-state specs: parameter spec + one extra data-axis dim (ZeRO-1)."""
    dpa = dp_axes(mesh)
    dsz = dp_size(mesh)

    def zero1(path, leaf):
        name = _leaf_name(path)
        if name == "step":
            return P()
        # find this leaf's param spec by stripping the master/mu/nu prefix
        sub = path[1:]
        try:
            pspec = _lookup(p_specs, sub)
        except (KeyError, TypeError):
            pspec = P()
        axes = list(pspec) + [None] * (len(leaf.shape) - len(tuple(pspec)))
        for i, ax in enumerate(axes):
            if ax is None and leaf.shape[i] % dsz == 0 and leaf.shape[i] >= dsz:
                axes[i] = dpa if len(dpa) > 1 else dpa[0]
                break
        return P(*axes)

    return jax.tree_util.tree_map_with_path(zero1, opt_shape)


def _lookup(tree, path):
    node = tree
    for entry in path:
        key = getattr(entry, "key", None)
        if key is None:
            key = getattr(entry, "idx", None)
        node = node[key]
    return node


def batch_specs(mesh, batch_shape) -> Any:
    dpa = dp_axes(mesh)
    dsz = dp_size(mesh)

    def one(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        first = (dpa if len(dpa) > 1 else dpa[0]) if (b % dsz == 0 and b >= dsz) else None
        return P(*([first] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_specs(mesh, cfg, caches_shape) -> Any:
    """KV/SSM cache specs for decode: batch over dp when divisible, the long
    sequence window over ``model``, ssm heads over ``model`` when divisible."""
    dpa = dp_axes(mesh)
    dsz = dp_size(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    dp_ax = dpa if len(dpa) > 1 else dpa[0]

    def one(path, leaf):
        name = _leaf_name(path)
        sh = leaf.shape
        if name == "pos" or leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))
        if name in ("k", "v", "k_scale", "v_scale"):
            # stacked [L(, G), B, S, K, hd|1] or unstacked [B, S, K, hd|1]
            lead = leaf.ndim - 4
            batch = sh[lead]
            seq = sh[lead + 1]
            return P(*([None] * lead
                       + [dp_ax if batch % dsz == 0 and batch >= dsz else None,
                          _div(mesh, tp, seq), None, None]))
        if name == "state":
            # [..., B, H, P, N]
            lead = leaf.ndim - 4
            batch = sh[lead]
            return P(*([None] * lead
                       + [dp_ax if batch % dsz == 0 and batch >= dsz else None,
                          _div(mesh, tp, sh[lead + 1]), None, None]))
        if name == "conv":
            # [..., B, Kw-1, Ch]
            lead = leaf.ndim - 3
            batch = sh[lead]
            return P(*([None] * lead
                       + [dp_ax if batch % dsz == 0 and batch >= dsz else None,
                          None, _div(mesh, tp, sh[lead + 2])]))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, caches_shape)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
