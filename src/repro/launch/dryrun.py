import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry run: lower + compile every (arch x shape) on the production
meshes, print memory/cost analysis, and emit roofline JSON artifacts.

MUST be run as its own process (the XLA flag above is read at first jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch nemotron-4-15b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import gc            # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.compat import named_shardings, set_mesh                  # noqa: E402
from repro.configs import ARCH_IDS, get_config                      # noqa: E402
from repro.launch import hlo_analysis, partition, specs, steps      # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.models.config import LM_SHAPES, applicable_shapes        # noqa: E402
from repro.models.sharding import axes_from_mesh                    # noqa: E402
from repro.optim import OptConfig, adamw_init                       # noqa: E402

ARTIFACT_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "../../../benchmarks/artifacts/dryrun"))


def _jsonable(x):
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if hasattr(x, "item"):
        return x.item()
    return x


def _coerce(cfg, key: str, val: str):
    cur = getattr(cfg, key)
    if isinstance(cur, bool):
        return val.lower() in ("1", "true", "yes")
    if isinstance(cur, int):
        return int(val)
    if isinstance(cur, float):
        return float(val)
    return val


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             moe_impl: str = None, quiet: bool = False, tag: str = "",
             overrides=None):
    cfg = get_config(arch)
    if moe_impl and cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    for kv in overrides or []:
        key, val = kv.split("=", 1)
        cfg = dataclasses.replace(cfg, **{key: _coerce(cfg, key, val)})
    shape = LM_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes_from_mesh(mesh)
    set_mesh(mesh)
    n_chips = mesh.size
    t0 = time.time()

    p_shape = specs.params_shape(cfg)
    p_specs = partition.params_specs(mesh, p_shape)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, p_shape)
        o_specs = partition.opt_specs(mesh, opt_shape, p_specs)
        batch = specs.train_inputs(cfg, shape)
        b_specs = partition.batch_specs(mesh, batch)
        step = steps.make_train_step(cfg, OptConfig(), mesh,
                                     grad_specs=o_specs["master"])
        jitted = jax.jit(step,
                         in_shardings=named_shardings(mesh, (p_specs, o_specs, b_specs)),
                         out_shardings=named_shardings(mesh, (p_specs, o_specs, None)),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_shape, opt_shape, batch)
    elif shape.kind == "prefill":
        batch = specs.prefill_inputs(cfg, shape)
        b_specs = partition.batch_specs(mesh, batch)
        step = steps.make_prefill_step(cfg, mesh)
        out_shape = jax.eval_shape(step, p_shape, batch)
        if isinstance(out_shape[1], dict):
            out_caches = partition.cache_specs(mesh, cfg, out_shape[1])
        else:  # encdec: enc_out [B, S, d] — batch-sharded
            out_caches = partition.batch_specs(mesh, out_shape[1])
        jitted = jax.jit(step,
                         in_shardings=named_shardings(mesh, (p_specs, b_specs)),
                         out_shardings=named_shardings(mesh, (None, out_caches)))
        lowered = jitted.lower(p_shape, batch)
    else:  # decode
        caches, tok = specs.decode_inputs(cfg, shape)
        c_specs = partition.cache_specs(mesh, cfg, caches)
        t_specs = partition.batch_specs(mesh, tok)["tokens"]
        step = steps.make_serve_step(cfg, mesh)
        jitted = jax.jit(step,
                         in_shardings=named_shardings(mesh, (p_specs, c_specs, t_specs)),
                         out_shardings=named_shardings(mesh, (None, c_specs)),
                         donate_argnums=(1,))
        lowered = jitted.lower(p_shape, caches, tok["tokens"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    module_cost = hlo_analysis.analyze_module(txt)
    coll = hlo_analysis.CollectiveStats(
        total_bytes=int(module_cost.coll_bytes),
        by_op={k: int(v) for k, v in module_cost.coll_by_op.items()},
        count=module_cost.n_whiles)
    mf = hlo_analysis.model_flops_for(cfg, shape)
    rl = hlo_analysis.roofline(module_cost, coll, n_chips, mf, mem,
                               xla_cost=cost)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "peak_est_bytes_per_dev": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "roofline": rl.as_dict(),
    }
    if not quiet:
        print(f"[{arch} x {shape_name} x {result['mesh']}] "
              f"compile {t_compile:.0f}s | "
              f"args {mem.argument_size_in_bytes/2**30:.2f} GiB/dev, "
              f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB/dev | "
              f"flops/dev {rl.flops_per_dev:.3e} | "
              f"compute {rl.compute_s*1e3:.2f} ms, memory {rl.memory_s*1e3:.2f} ms, "
              f"collective {rl.collective_s*1e3:.2f} ms -> {rl.dominant}-bound | "
              f"useful {rl.useful_ratio:.2f}")
        print("  memory_analysis:", mem)
        print("  cost_analysis: flops=%.4g bytes=%.4g" %
              (rl.flops_per_dev, rl.bytes_per_dev))
        print("  collectives:", coll.by_op)
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"-{tag}" if tag else ""
    fname = f"{arch}--{shape_name}--{result['mesh'].replace('x','_')}{suffix}.json"
    with open(os.path.join(ARTIFACT_DIR, fname), "w") as f:
        json.dump(_jsonable(result), f, indent=1)
    # free compiler memory before the next cell
    del compiled, lowered, jitted
    gc.collect()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(LM_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--moe-impl", choices=["dense", "a2a"], default=None)
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="config override key=value (repeatable)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for sh in applicable_shapes(cfg):
                cells.append((arch, sh))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, sh in cells:
        for mp in meshes:
            try:
                run_cell(arch, sh, mp, moe_impl=args.moe_impl, tag=args.tag,
                         overrides=args.overrides)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, sh, mp, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} dry-run cells compiled OK")


if __name__ == "__main__":
    main()
