"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).  All mesh
construction goes through ``repro.compat`` so the same code runs on JAX
versions with and without ``jax.sharding.AxisType`` / ``axis_types``.
"""

from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (uses however many host devices exist)."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


def dp_axes(mesh) -> tuple:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for n in dp_axes(mesh):
        s *= mesh.shape[n]
    return s


def tp_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
