"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).  All mesh
construction goes through ``repro.compat.host_mesh`` — the same shim the
engine's sharded execution plans build on — so the same code runs on JAX
versions with and without ``jax.sharding.AxisType`` / ``axis_types`` and
device-count errors read identically everywhere.
"""

from __future__ import annotations

from repro.compat import host_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return host_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (uses however many host devices exist)."""
    return host_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for n in dp_axes(mesh):
        s *= mesh.shape[n]
    return s


def tp_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
