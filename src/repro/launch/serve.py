"""Serving driver: batched prefill + decode with continuous batching slots.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
        --batch 4 --prompt-len 32 --gen 16

A minimal production-shaped server loop: a request queue, fixed decode slots
(continuous batching: finished sequences are swapped for queued prompts), and
greedy decoding.  On CPU the reduced configs keep it interactive; the same
code path serves the full configs on a real mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_serve_step
from repro.models import encdec, lm
from repro.models.sharding import axes_from_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-1.3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)) if args.reduced else get_config(args.arch)
    mesh = make_test_mesh(1, 1)
    axes_from_mesh(mesh)
    jax.set_mesh(mesh)
    mod = encdec if cfg.family == "encdec" else lm
    params = mod.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    serve_step = jax.jit(make_serve_step(cfg, mesh))

    rng = np.random.default_rng(0)
    window = args.prompt_len + args.gen
    queue = [rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
             for _ in range(args.requests)]
    done = []
    t0 = time.time()
    tokens_out = 0
    while queue or done and False:
        # fill a batch of slots from the queue (continuous batching)
        slot_prompts = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        if not slot_prompts:
            break
        B = len(slot_prompts)
        prompts = jnp.asarray(np.stack(slot_prompts))
        if cfg.family == "encdec":
            enc_in = jnp.asarray(
                rng.standard_normal((B, args.prompt_len, cfg.d_model)) * 0.05,
                jnp.float32)
            enc_out = encdec.encode(params, cfg, enc_in)
            caches = encdec.make_dec_caches(params, cfg, enc_out,
                                            window=window, dtype=jnp.float32)
            cur = jnp.zeros((B, 1), jnp.int32)
        else:
            logits, caches = lm.prefill(params, cfg, tokens=prompts)
            caches = lm.grow_caches(cfg, caches, window)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [cur]
        for _ in range(args.gen - 1):
            cur, caches = serve_step(params, caches, cur)
            outs.append(cur)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        tokens_out += gen.size
        done.extend(list(gen))
    dt = time.time() - t0
    print(f"arch={cfg.name} served {len(done)} sequences, "
          f"{tokens_out} tokens in {dt:.2f}s "
          f"({tokens_out/max(dt,1e-9):.1f} tok/s greedy)")
    print("sample:", done[0][:16].tolist() if done else "none")


if __name__ == "__main__":
    main()
