"""Serving driver: batched prefill + decode with continuous batching slots.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
        --batch 4 --prompt-len 32 --gen 16

A minimal production-shaped server loop: a request queue, fixed decode slots
(continuous batching: finished sequences are swapped for queued prompts), and
greedy decoding.  On CPU the reduced configs keep it interactive; the same
code path serves the full configs on a real mesh.

``--sparse-ffnn`` serves the paper's workload instead: feature vectors through
a magnitude-pruned block-sparse FFNN, routed through the ``repro.serving``
runtime — one engine compile (or a plan-store hit, which skips annealing
entirely via ``--plan-store DIR``) fanned out across power-of-two batch
buckets, with a deadline-aware wait-or-fire scheduler and SLO metrics:

    PYTHONPATH=src python -m repro.launch.serve --sparse-ffnn --requests 64

``--async`` serves through the background scheduler thread (real clock,
graceful SIGTERM drain); ``--models K`` serves K differently-pruned model
variants from one process via a shared-scheduler ``ModelRouter``:

    PYTHONPATH=src python -m repro.launch.serve --sparse-ffnn --async \
        --models 2 --requests 64

``--workers N`` runs the async scheduler as a staged pipeline (admission ->
batch formation -> per-bucket dispatch lanes -> an N-worker execution pool)
so different-bucket batches overlap; ``--http-port P`` (implies ``--async``)
opens the stdlib JSON front door (``POST /v1/infer``) and drives the request
loop through real HTTP clients, with queue-full admission surfacing as 429:

    PYTHONPATH=src python -m repro.launch.serve --sparse-ffnn \
        --http-port 0 --workers 2 --requests 64

Observability: ``--metrics-port P`` exposes a Prometheus text endpoint
(``/metrics``, port 0 = ephemeral) with the full serving snapshot — SLO
metrics, resilience state, and the per-bucket static-vs-dynamic I/O gauges
from the engine's block-read accounting; ``--trace-out PATH`` records the
request lifecycle (submit -> queue -> batch -> result, plus compile phases
and breaker transitions) and dumps a Chrome-trace JSON (or ``.jsonl``) on
exit, including graceful SIGTERM drain:

    PYTHONPATH=src python -m repro.launch.serve --sparse-ffnn --gate \
        --requests 64 --metrics-port 0 --trace-out /tmp/serve_trace.json
"""

from __future__ import annotations

import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs import ARCH_IDS, get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_serve_step
from repro.models import encdec, lm
from repro.models.sharding import axes_from_mesh


def _make_ffnn_layers(sizes, density, block, seed=0):
    from repro.sparse import prune_dense_stack

    rng = np.random.default_rng(seed)
    ws = [rng.standard_normal((sizes[i], sizes[i + 1])).astype(np.float32) * 0.03
          for i in range(len(sizes) - 1)]
    bs = [np.zeros(s, np.float32) for s in sizes[1:]]
    return prune_dense_stack(ws, bs, density=density,
                             block_m=block, block_n=block)


def _drive_http(front, args, sizes, names, rng, stop) -> dict:
    """Drive the request load through the HTTP front door with a small
    pool of real client connections (stdlib urllib).  Returns a status
    -> count map; a 429 (queue full) backs off per ``Retry-After`` and
    retries the same request, so admission control is load-shaping, not
    request loss."""
    import json
    import threading
    import urllib.error
    import urllib.request
    from collections import Counter

    work = deque((names[k % len(names)] if names else None,
                  rng.standard_normal(sizes[0]).astype(np.float32))
                 for k in range(args.requests))
    counts: Counter = Counter()
    lock = threading.Lock()

    def client() -> None:
        while not stop["flag"]:
            with lock:
                if not work:
                    return
                name, x = work.popleft()
            body = {"x": x.tolist()}
            if name is not None:
                body["model"] = name
            req = urllib.request.Request(
                front.url + "/v1/infer",
                data=json.dumps(body).encode("utf-8"),
                headers={"Content-Type": "application/json"},
                method="POST")
            retry_after = None
            try:
                with urllib.request.urlopen(req, timeout=60) as resp:
                    code = resp.status
                    resp.read()
            except urllib.error.HTTPError as e:
                code = e.code
                retry_after = e.headers.get("Retry-After")
                e.read()
            except OSError:
                code = -1
            with lock:
                counts[code] += 1
            if code == 429 and not stop["flag"]:
                time.sleep(float(retry_after or 0.05))
                with lock:
                    work.appendleft((name, x))

    threads = [threading.Thread(target=client, name=f"http-client-{i}")
               for i in range(args.http_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return dict(counts)


def serve_sparse_ffnn(args) -> None:
    """Serve the paper's sparse-FFNN workload through the serving runtime.

    The offline cost (block DAG, Theorem-1 order, CR, lowering) is paid once
    per model in ``Engine.compile`` — or not at all on a warm start from the
    plan store; the request loop only executes bucketed cached plans.

    ``--async`` runs the background scheduler thread against the real clock
    (the production mode); the default remains the deterministic step-driven
    loop.  ``--models K`` serves K differently-pruned variants through one
    ``ModelRouter``/scheduler.  SIGTERM (and SIGINT) trigger a graceful
    drain: queued requests are served, then the process exits.
    """
    import signal

    from repro.engine import Engine, Mesh
    from repro.obs import MetricsServer, Tracer
    from repro.serving import (
        BucketedPlanSet,
        CircuitBreaker,
        HttpFrontDoor,
        ModelRouter,
        PlanStore,
        RetryPolicy,
        SparseServer,
    )

    if args.http_port is not None:
        # the front door needs a live scheduler behind it
        args.async_mode = True

    rng = np.random.default_rng(0)
    sizes = args.ffnn_sizes
    # one tracer for the whole process: engine compile phases, plan-store
    # hits/misses, and every request's lifecycle land in a single export
    tracer = Tracer() if args.trace_out else None
    engine = Engine(backend=args.backend, activation="gelu", reorder=True,
                    reorder_iters=args.reorder_iters,
                    fuse=not args.no_fuse, gate=args.gate,
                    weight_dtype=args.weight_dtype, tracer=tracer)
    mesh = Mesh.parse(args.mesh) if args.mesh else None
    store = (PlanStore(args.plan_store, tracer=tracer)
             if args.plan_store else None)
    # gating makes the measured dynamic-I/O path available: sample every
    # batch so the metrics endpoint carries live dynamic-vs-static gauges
    measure_every = 1 if args.gate else 0

    # resilience knobs: a breaker needs the safe twin to degrade to;
    # --safe-mode serves the twin directly (so a breaker is moot there)
    want_breaker = args.breaker > 0 and not args.safe_mode
    retry = None
    if args.retries > 0 or args.batch_timeout_ms is not None:
        retry = RetryPolicy(
            max_retries=args.retries,
            timeout_s=(args.batch_timeout_ms / 1e3
                       if args.batch_timeout_ms is not None else None))

    multi = args.models > 1
    t0 = time.time()
    if multi:
        if args.safe_mode:
            raise SystemExit("--safe-mode is single-model only; use "
                             "--breaker to degrade per model instead")
        # K differently-pruned variants of the same architecture, one
        # compile (or store hit) each, served through one shared scheduler
        nets = {f"m{k}": _make_ffnn_layers(sizes, args.density, args.block,
                                           seed=k)
                for k in range(args.models)}
        router = ModelRouter.compile(
            nets, engine=engine, max_batch=args.batch, plan_store=store,
            meshes={name: mesh for name in nets} if mesh else None,
            max_queue=args.max_queue, slo_ms=args.slo_ms, retry=retry,
            tracer=tracer, measure_dynamic_every=measure_every,
            breaker=(lambda: CircuitBreaker(
                threshold=args.breaker,
                cooldown_s=args.breaker_cooldown_ms / 1e3))
            if want_breaker else None,
            executor_workers=args.workers)
        names = list(router.servers)
        for name, srv in router.servers.items():
            print(f"[{name}] {srv.plans.describe()}")
    else:
        layers = _make_ffnn_layers(sizes, args.density, args.block)
        plans = BucketedPlanSet.compile(layers, engine=engine,
                                        max_batch=args.batch,
                                        plan_store=store, mesh=mesh,
                                        safe_twin=want_breaker)
        start = "warm (plan-store hit)" if plans.cache_hit else "cold"
        print(f"engine compile: {time.time() - t0:.1f}s [{start}] — "
              f"{plans.describe()}")
        if args.safe_mode:
            # the degraded path as the primary: jnp backend, gate off —
            # the same bit-exact forward the breaker would swap to
            plans = plans.build_safe_twin(jit=engine.jit)
            print(f"safe mode: {plans.describe()}")
        plans.warmup()
        server = SparseServer(
            plans, max_queue=args.max_queue, slo_ms=args.slo_ms,
            engine=engine, plan_store=store, mesh=mesh, retry=retry,
            tracer=tracer, measure_dynamic_every=measure_every,
            breaker=CircuitBreaker(threshold=args.breaker,
                                   cooldown_s=args.breaker_cooldown_ms / 1e3)
            if want_breaker else None,
            executor_workers=args.workers)

    # graceful drain on SIGTERM/SIGINT: stop submitting, serve everything
    # queued, report, exit — no request accepted before the signal is lost
    stop = {"flag": False}

    def _drain_handler(signum, frame):
        stop["flag"] = True

    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, _drain_handler)

    runtime = router if multi else server
    metrics_srv = None
    if args.metrics_port is not None:
        metrics_srv = MetricsServer(runtime.snapshot,
                                    port=args.metrics_port).start()
        print(f"metrics endpoint: {metrics_srv.url}")
    if args.async_mode:
        runtime.start()
        print("async scheduler thread started"
              + (f" (pipeline: {args.workers} executor workers)"
                 if args.workers else ""))
    front = None
    if args.http_port is not None:
        front = HttpFrontDoor(runtime, port=args.http_port).start()
        print(f"http front door: {front.url}  "
              f"(POST /v1/infer, GET /v1/result/<rid>)")

    rids = []   # (model or None, rid)
    http_codes = {}
    if front is not None:
        http_codes = _drive_http(front, args, sizes,
                                 names if multi else None, rng, stop)
        print(f"http clients done: {dict(sorted(http_codes.items()))} "
              f"over {args.http_clients} connections")
    else:
        pending = args.requests
        # bursty arrivals: submit a random clump, let the wait-or-fire
        # policy form batches, repeat — so the bucket router sees mixed
        # batch sizes
        while pending and not stop["flag"]:
            burst = int(rng.integers(1, args.batch + 1))
            for _ in range(min(burst, pending)):
                x = rng.standard_normal(sizes[0]).astype(np.float32)
                if multi:
                    name = names[len(rids) % len(names)]
                    rid = router.submit(name, x)
                else:
                    name, rid = None, server.submit(x)
                if rid is not None:
                    rids.append((name, rid))
                pending -= 1
                if not pending:
                    break
            if not args.async_mode:
                runtime.poll()
    if stop["flag"]:
        print("signal received: draining queued requests ...")
    # the pool snapshot lives until shutdown() releases the pipeline refs,
    # so sample it here — but only after the in-flight work finishes, or
    # the per-worker batch counts would reflect a near-empty pipeline
    if args.workers and args.async_mode and front is None:
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline and any(
                (router.servers[name] if multi else server).status(rid)
                == "pending" for name, rid in rids):
            time.sleep(0.005)
    pool_snap = (runtime.snapshot().get("pool")
                 if args.workers and args.async_mode else None)
    if front is not None:
        front.stop()
    if args.async_mode:
        runtime.shutdown(drain=True)
    else:
        runtime.drain()
    if pool_snap is not None:
        per = pool_snap.get("per_worker", {})
        util = {w: round(s.get("utilization", 0.0), 3)
                for w, s in sorted(per.items())}
        print(f"executor pool: {pool_snap.get('workers')} workers, "
              f"batches={ {w: s.get('batches') for w, s in sorted(per.items())} } "
              f"utilization={util}")

    # "served" comes from the metrics: collecting at the very end can see
    # fewer results than were served once capacity eviction kicks in (the
    # oldest uncollected results are dropped by design under heavy traffic)
    if multi:
        collected = (http_codes.get(200, 0) if front is not None else
                     sum(router.result(name, rid) is not None
                         for name, rid in rids))
        served = router.metrics_snapshot()["total"]["served"]
        print(f"served {served} requests across {args.models} models "
              f"({collected} collected)")
        print(router.summary())
    else:
        collected = (http_codes.get(200, 0) if front is not None else
                     sum(server.result(rid) is not None for _, rid in rids))
        print(f"served {server.metrics.served} sparse-FFNN requests "
              f"({collected} collected) — {server.metrics.summary()}")
        if want_breaker or retry is not None:
            m = server.metrics.snapshot()
            print(f"resilience: retries={m['retries']} "
                  f"timeouts={m['batch_timeouts']} "
                  f"breaker trips={m['breaker_trips']} "
                  f"resets={m['breaker_resets']} "
                  f"degraded batches={m['degraded_batches']}")
        print(f"bucket calls: "
              f"{ {b: n for b, n in plans.bucket_calls.items() if n} }")
        base = getattr(plans, "base", None)
        if args.gate and base is not None and \
                getattr(base, "_measure", None) is not None:
            # measured dynamic I/O of one representative batch: how many
            # scheduled weight blocks a demand-driven stream actually read
            xs = np.stack([rng.standard_normal(sizes[0]).astype(np.float32)
                           for _ in range(min(args.batch, 8))])
            print(base.measure_dynamic(xs).summary())

    if metrics_srv is not None:
        # scrape our own endpoint once so the run exercises the full HTTP
        # exposition path (the CI smoke greps these lines)
        import urllib.request
        with urllib.request.urlopen(metrics_srv.url, timeout=5) as resp:
            body = resp.read().decode("utf-8")
        lines = body.splitlines()
        print(f"metrics scrape: {len(lines)} lines from {metrics_srv.url}")
        for ln in lines[:8]:
            print(f"  {ln}")
        for ln in lines:
            if "_io_" in ln and not ln.startswith("#"):
                print(f"  {ln}")
        metrics_srv.stop()
    if args.trace_out and tracer is not None:
        path = tracer.export(args.trace_out)
        print(f"trace: {tracer.recorded} spans recorded "
              f"({tracer.dropped} dropped) -> {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-1.3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--sparse-ffnn", action="store_true",
                    help="serve the paper's sparse-FFNN workload via the "
                         "fused inference engine instead of an LM")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="drive the sparse serving loop from a background "
                         "scheduler thread (real clock) instead of the "
                         "step-driven caller loop; SIGTERM drains gracefully")
    ap.add_argument("--models", type=int, default=1,
                    help="serve N differently-pruned model variants from "
                         "one process through a shared-scheduler ModelRouter "
                         "(sparse-ffnn only)")
    ap.add_argument("--ffnn-sizes", type=int, nargs="+",
                    default=[1024, 4096, 1024])
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--reorder-iters", type=int, default=300)
    ap.add_argument("--no-fuse", action="store_true",
                    help="serve with per-layer dispatch instead of the fused "
                         "whole-network megakernel plan")
    ap.add_argument("--gate", action="store_true",
                    help="runtime tile-occupancy gating: skip weight blocks "
                         "whose input tile is all-zero for the batch "
                         "(bit-exact; prints the measured dynamic I/O report "
                         "after serving)")
    ap.add_argument("--mesh", default=None, metavar="MODELxDATA",
                    help="serve through a sharded execution plan, e.g. 4x2 "
                         "= 4 model shards x 2 data replicas (sparse-ffnn "
                         "only; falls back to a host loop when the machine "
                         "has fewer devices than mesh slots)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "interpret", "jnp"))
    ap.add_argument("--weight-dtype", default="f32",
                    choices=("f32", "bf16", "fp8"),
                    help="storage dtype of the streamed weight blocks: "
                         "bf16/fp8 quantize each block with one f32 scale "
                         "at compile time and fuse the dequant into the "
                         "kernel, halving/quartering weight-stream bytes "
                         "(outputs approximate within the documented "
                         "tolerance; f32 stays bit-exact)")
    ap.add_argument("--plan-store", default=None,
                    help="directory of the persistent plan cache; a warm "
                         "start skips the annealing cost entirely")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="target end-to-end latency SLO for the sparse "
                         "serving scheduler")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission bound of the sparse serving queue")
    ap.add_argument("--safe-mode", action="store_true",
                    help="serve the plan's safe-mode twin directly (jnp "
                         "backend, gating off — the same bit-exact forward "
                         "the circuit breaker degrades to, as the primary)")
    ap.add_argument("--breaker", type=int, default=0, metavar="K",
                    help="arm a circuit breaker: K consecutive batch "
                         "failures/timeouts degrade to the precompiled "
                         "safe-mode twin, half-opening back after the "
                         "cool-down (0 = off)")
    ap.add_argument("--breaker-cooldown-ms", type=float, default=1000.0,
                    help="circuit-breaker cool-down before probing the "
                         "fast plan again")
    ap.add_argument("--retries", type=int, default=0,
                    help="bounded per-batch retry attempts (with "
                         "exponential backoff) before a batch fails")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="execution-stage worker pool size: the async "
                         "scheduler becomes a staged pipeline (formation "
                         "-> per-bucket dispatch lanes -> N workers) so "
                         "different-bucket batches overlap; 0 keeps the "
                         "single-threaded scheduler (sparse-ffnn only)")
    ap.add_argument("--http-port", type=int, default=None, metavar="P",
                    help="open the JSON front door on this port (0 = "
                         "ephemeral) and drive the request load through "
                         "real HTTP clients; queue-full admission becomes "
                         "429 + Retry-After (implies --async; sparse-ffnn "
                         "only)")
    ap.add_argument("--http-clients", type=int, default=4,
                    help="concurrent HTTP client connections used by "
                         "--http-port to drive the load")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="P",
                    help="expose a Prometheus text endpoint (/metrics) on "
                         "this port with the live serving snapshot: SLO "
                         "quantiles, resilience state, per-bucket static/"
                         "dynamic block-read gauges (0 = ephemeral port; "
                         "sparse-ffnn only)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request/compile/breaker spans and write a "
                         "Chrome-trace JSON (.jsonl for line-delimited "
                         "spans) on exit — open in chrome://tracing or "
                         "Perfetto (sparse-ffnn only)")
    ap.add_argument("--batch-timeout-ms", type=float, default=None,
                    help="wall-clock bound on one batch execution attempt; "
                         "a hung attempt is abandoned and counted (and "
                         "retried under --retries)")
    args = ap.parse_args()

    if args.sparse_ffnn:
        serve_sparse_ffnn(args)
        return

    cfg = reduced(get_config(args.arch)) if args.reduced else get_config(args.arch)
    mesh = make_test_mesh(1, 1)
    axes_from_mesh(mesh)
    set_mesh(mesh)
    mod = encdec if cfg.family == "encdec" else lm
    params = mod.init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    serve_step = jax.jit(make_serve_step(cfg, mesh))

    rng = np.random.default_rng(0)
    window = args.prompt_len + args.gen
    queue = deque(
        rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests))
    done = []
    t0 = time.time()
    tokens_out = 0
    while queue:
        # fill a batch of slots from the queue (continuous batching)
        slot_prompts = [queue.popleft()
                        for _ in range(min(args.batch, len(queue)))]
        if not slot_prompts:
            break
        B = len(slot_prompts)
        prompts = jnp.asarray(np.stack(slot_prompts))
        if cfg.family == "encdec":
            enc_in = jnp.asarray(
                rng.standard_normal((B, args.prompt_len, cfg.d_model)) * 0.05,
                jnp.float32)
            enc_out = encdec.encode(params, cfg, enc_in)
            caches = encdec.make_dec_caches(params, cfg, enc_out,
                                            window=window, dtype=jnp.float32)
            cur = jnp.zeros((B, 1), jnp.int32)
        else:
            logits, caches = lm.prefill(params, cfg, tokens=prompts)
            caches = lm.grow_caches(cfg, caches, window)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [cur]
        for _ in range(args.gen - 1):
            cur, caches = serve_step(params, caches, cur)
            outs.append(cur)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        tokens_out += gen.size
        done.extend(list(gen))
    dt = time.time() - t0
    print(f"arch={cfg.name} served {len(done)} sequences, "
          f"{tokens_out} tokens in {dt:.2f}s "
          f"({tokens_out/max(dt,1e-9):.1f} tok/s greedy)")
    print("sample:", done[0][:16].tolist() if done else "none")


if __name__ == "__main__":
    main()
