"""ShapeDtypeStruct input stand-ins per (architecture x shape) — no allocation."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import LM_SHAPES, ModelConfig, ShapeConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        St = S // cfg.tgt_frac
        return {
            "src_embeds": sds((B, S, cfg.d_model), jnp.bfloat16),
            "tgt_tokens": sds((B, St), jnp.int32),
            "labels": sds((B, St), jnp.int32),
        }
    if cfg.modality == "vision_stub":
        return {
            "embeds": sds((B, S, cfg.d_model), jnp.bfloat16),
            "labels": sds((B, S), jnp.int32),
        }
    return {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"src_embeds": sds((B, S, cfg.d_model), jnp.bfloat16),
                "tgt_tokens": sds((B, S // cfg.tgt_frac), jnp.int32)}
    if cfg.modality == "vision_stub":
        return {"embeds": sds((B, S, cfg.d_model), jnp.bfloat16)}
    return {"tokens": sds((B, S), jnp.int32)}


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Any, Dict]:
    """Returns (caches_shape_tree, token_inputs) for one serve step with a
    KV window of ``shape.seq_len``."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        enc_out = sds((B, S, cfg.d_model), jnp.bfloat16)
        caches = jax.eval_shape(
            lambda eo: encdec.make_dec_caches(
                {"dec_layers": jax.eval_shape(
                    lambda k: encdec.init(k, cfg), jax.random.PRNGKey(0)
                )["dec_layers"]}, cfg, eo, window=S),
            enc_out)
        return caches, {"tokens": sds((B, 1), jnp.int32)}
    caches = jax.eval_shape(lambda: lm.make_caches(cfg, B, S))
    return caches, {"tokens": sds((B, 1), jnp.int32)}


def params_shape(cfg: ModelConfig):
    mod = encdec if cfg.family == "encdec" else lm
    return jax.eval_shape(lambda k: mod.init(k, cfg), jax.random.PRNGKey(0))


def shape_by_name(name: str) -> ShapeConfig:
    return LM_SHAPES[name]
