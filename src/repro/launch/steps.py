"""Train / prefill / serve step builders shared by train.py, serve.py, dryrun.py."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw_update


def _model(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else lm


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, mesh=None,
                    grad_specs=None):
    """``grad_specs``: optional PartitionSpec tree for the f32 gradient
    accumulator — pass the ZeRO-1 optimizer-state specs to reduce-scatter
    microbatch gradients over the data axis instead of holding a full f32
    copy per chip (ZeRO-2; −(dp-1)/dp of grad memory)."""
    mod = _model(cfg)

    def loss(p, mb):
        return mod.loss_fn(p, cfg, mb, mesh)

    def constrain(g):
        if grad_specs is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g, grad_specs)

    dp_sz = 1
    if mesh is not None:
        try:
            for name in mesh.axis_names:
                if name in ("pod", "data", "replica"):
                    dp_sz *= mesh.shape[name]
        except (TypeError, KeyError):
            dp_sz = 1

    def train_step(params, opt_state, batch):
        # clamp microbatching so every micro-slice still shards over dp:
        # B/n_micro must be divisible by dp (else XLA silently replicates
        # the micro-batch across the surplus data ranks — observed as
        # unchanged per-device FLOPs on the 2-pod mesh).
        B = jax.tree.leaves(batch)[0].shape[0]
        n_micro = max(1, min(cfg.microbatch, B // max(1, dp_sz)))
        while n_micro > 1 and (B % n_micro or (B // n_micro) % dp_sz):
            n_micro -= 1
        if n_micro > 1:
            mb_batch = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch)
            zeros = constrain(jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params))

            def micro(acc, mb):
                (lval, metrics), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                g = constrain(jax.tree.map(lambda b: b.astype(jnp.float32), g))
                acc = constrain(jax.tree.map(lambda a, b: a + b, acc, g))
                return acc, lval

            acc, losses = jax.lax.scan(micro, zeros, mb_batch)
            grads = jax.tree.map(lambda a: a / n_micro, acc)
            lval = jnp.mean(losses)
        else:
            (lval, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
            grads = constrain(jax.tree.map(lambda g: g.astype(jnp.float32),
                                           grads))
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {"loss": lval, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    mod = _model(cfg)

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            enc_out = encdec.encode(params, cfg, batch["src_embeds"])
            h = encdec.decode_train(params, cfg, enc_out, batch["tgt_tokens"])
            logits = jnp.einsum("bd,dv->bv", h[:, -1],
                                encdec.unembed_matrix(params),
                                preferred_element_type=jnp.float32)
            return logits, enc_out
        logits, caches = lm.prefill(
            params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            mesh=mesh)
        return logits, caches

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None):
    """One decode step: greedy next token + updated caches."""

    def serve_step(params, caches, tokens):
        if cfg.family == "encdec":
            logits, new_caches = encdec.decode_step(params, cfg, tokens, caches)
        else:
            logits, new_caches = lm.decode_step(params, cfg, tokens, caches,
                                                mesh=mesh)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_caches

    return serve_step
