"""Training driver: resilient loop with checkpoint/restart on any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/run1

``--reduced`` uses the small same-family config (CPU-runnable); omit it on a
real fleet.  Restarting the same command resumes from the latest checkpoint.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config, reduced
from repro.data import SyntheticLM, TokenBatcher
from repro.launch import partition
from repro.launch.mesh import dp_axes, make_test_mesh
from repro.compat import named_shardings, set_mesh
from repro.launch.steps import make_train_step
from repro.models import encdec, lm
from repro.models.sharding import axes_from_mesh
from repro.optim import OptConfig, adamw_init
from repro.runtime.failure import FaultInjector, ResilientTrainer, StragglerMonitor


def build(cfg, mesh, opt_cfg, seed=0, dtype=jnp.bfloat16):
    mod = encdec if cfg.family == "encdec" else lm
    axes_from_mesh(mesh)
    set_mesh(mesh)
    params = mod.init(jax.random.PRNGKey(seed), cfg, dtype=dtype)
    p_specs = partition.params_specs(mesh, jax.eval_shape(lambda: params))
    params = jax.device_put(params, partition.to_named(mesh, p_specs))
    opt_state = adamw_init(params)
    o_specs = partition.opt_specs(mesh, jax.eval_shape(lambda: opt_state),
                                  p_specs)
    opt_state = jax.device_put(opt_state, partition.to_named(mesh, o_specs))
    step = jax.jit(make_train_step(cfg, opt_cfg, mesh,
                                   grad_specs=o_specs["master"]),
                   in_shardings=named_shardings(mesh, (p_specs, o_specs, None)),
                   out_shardings=named_shardings(mesh, (p_specs, o_specs, None)),
                   donate_argnums=(0, 1))
    return params, opt_state, step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["bert-ffnn"],
                    default="granite-moe-1b-a400m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--inject-fault-at", type=int, default=None,
                    help="simulate a node failure at this step (demo/tests)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, microbatch=1)
    mesh = make_test_mesh(args.data_mesh, args.model_mesh)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    params, opt_state, step_fn = build(cfg, mesh, opt_cfg)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)}")

    src = SyntheticLM(vocab=cfg.vocab, seed=0)
    batcher = TokenBatcher(src, args.batch, args.seq, seed=1)

    def batches(step):
        b = batcher(step)
        if cfg.modality == "vision_stub":
            rng = np.random.default_rng(step)
            d = cfg.d_model
            return {"embeds": jnp.asarray(
                rng.standard_normal((args.batch, args.seq, d)) * 0.05,
                jnp.bfloat16), "labels": jnp.asarray(b["labels"])}
        if cfg.family == "encdec":
            rng = np.random.default_rng(step)
            st = args.seq // cfg.tgt_frac
            return {"src_embeds": jnp.asarray(
                rng.standard_normal((args.batch, args.seq, cfg.d_model)) * 0.05,
                jnp.bfloat16),
                "tgt_tokens": jnp.asarray(b["tokens"][:, :st]),
                "labels": jnp.asarray(b["labels"][:, :st])}
        return {k: jnp.asarray(v) for k, v in b.items()}

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    injector = FaultInjector([args.inject_fault_at]
                             if args.inject_fault_at is not None else [])
    trainer = ResilientTrainer(
        step_fn, params, opt_state, ckpt, ckpt_every=args.ckpt_every,
        fault_injector=injector, straggler=StragglerMonitor())
    t0 = time.time()
    summary = trainer.run(batches, args.steps)
    dt = time.time() - t0
    ls = summary["losses"]
    print(f"steps={args.steps} time={dt:.1f}s "
          f"loss {ls[0]:.4f} -> {ls[-1]:.4f} "
          f"restarts={summary['restarts']} "
          f"stragglers={summary['straggler_events']}")


if __name__ == "__main__":
    main()
