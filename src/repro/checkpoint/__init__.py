from .store import (
    CheckpointManager,
    load_checkpoint,
    manifest_exists,
    read_manifest_dir,
    save_checkpoint,
    write_manifest_dir,
)

__all__ = [
    "CheckpointManager",
    "load_checkpoint",
    "manifest_exists",
    "read_manifest_dir",
    "save_checkpoint",
    "write_manifest_dir",
]
