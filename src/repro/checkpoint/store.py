"""Fault-tolerant checkpointing: atomic, async, manifest-verified, reshardable.

The storage primitive is a *manifest directory* — a directory of ``.npy``
files plus a ``manifest.json`` recording name/shape/dtype/crc per array and
arbitrary JSON ``extra`` metadata.  Writes go to ``<dir>.tmp`` and are
atomically renamed after the manifest is fsynced, so a crash mid-write never
corrupts the latest good artifact.  ``write_manifest_dir`` /
``read_manifest_dir`` are the reusable layer; both the training checkpoints
here and the compiled-plan store (``repro.serving.plancache``) sit on top of
it.

Layout of one checkpoint:

    <dir>/step_<N>/
        manifest.json          # tree structure, shapes, dtypes, leaf files, crc
        leaf_00000.npy ...     # one .npy per leaf (host-local full arrays)

Saves can run on a background thread (``async_save``); ``wait()`` joins the
inflight write before the next one starts (single-writer discipline).

Restore is *elastic*: arrays are loaded as host numpy and re-placed under
whatever mesh/sharding the caller provides (``target_shardings``), so a
checkpoint taken on a 16x16 mesh restores onto 8x8, 2x16x16, or 1 CPU device
unchanged — the re-shard is a device_put per leaf.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy round-trips ml_dtypes arrays (bf16 etc.) as raw void records; map the
# recorded logical dtype back on load.
_EXTENDED_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": getattr(ml_dtypes, "float8_e4m3fn", None),
    "float8_e5m2": getattr(ml_dtypes, "float8_e5m2", None),
}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    return [jax.tree_util.keystr(path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


# --------------------------------------------------------------------------- #
# reusable manifest layer (checkpoints AND the serving plan store use this)
# --------------------------------------------------------------------------- #

def write_manifest_dir(final: str, arrays: Mapping[str, np.ndarray],
                       extra: Optional[Dict] = None) -> str:
    """Atomically write named arrays + JSON metadata as a manifest directory.

    Each array lands as ``<name>.npy`` with its crc32 recorded in
    ``manifest.json``; the whole directory is staged at ``<final>.tmp`` and
    renamed into place after the manifest is fsynced, so readers only ever
    see complete, verified artifacts.  Array names must be filesystem-safe.
    """
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"arrays": [], "extra": extra or {}}
    for name, value in arrays.items():
        arr = np.asarray(jax.device_get(value))
        fname = f"{name}.npy"
        disk = arr
        if _EXTENDED_DTYPES.get(str(arr.dtype)) is not None:
            # store extended dtypes (bf16/f8) as raw void bytes — np.save
            # would otherwise emit descriptors np.load cannot parse; the
            # manifest records the logical dtype and load views it back
            disk = arr.view(f"V{arr.dtype.itemsize}")
        np.save(os.path.join(tmp, fname), disk)
        manifest["arrays"].append({
            "name": name, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def read_manifest_dir(path: str, verify: bool = True
                      ) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Load a manifest directory back as ``(arrays, extra)``.

    Extended dtypes (bf16, f8) that numpy round-trips as void records are
    viewed back to their logical dtype; ``verify`` checks every crc and
    raises ``IOError`` on corruption.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if "arrays" not in manifest and "leaves" in manifest:
        # legacy checkpoint manifest (pre-manifest-layer format): same
        # per-record fields under "leaves", tree metadata at top level
        manifest = {
            "arrays": [{**rec, "name": rec["file"][:-len(".npy")]}
                       for rec in manifest["leaves"]],
            "extra": {"step": manifest["step"],
                      "n_leaves": manifest["n_leaves"],
                      "paths": [rec["path"] for rec in manifest["leaves"]],
                      "extra": manifest.get("extra", {})},
        }
    arrays: Dict[str, np.ndarray] = {}
    for rec in manifest["arrays"]:
        arr = np.load(os.path.join(path, rec["file"]))
        if arr.dtype.kind == "V" and _EXTENDED_DTYPES.get(rec["dtype"]) is not None:
            arr = arr.view(_EXTENDED_DTYPES[rec["dtype"]])
        if verify and (zlib.crc32(arr.tobytes()) & 0xFFFFFFFF) != rec["crc"]:
            raise IOError(f"crc mismatch in {rec['file']} ({rec['name']})")
        arrays[rec["name"]] = arr
    return arrays, manifest.get("extra", {})


def manifest_exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.json"))


# --------------------------------------------------------------------------- #
# tree checkpoints
# --------------------------------------------------------------------------- #

def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Blocking atomic save.  Returns the final checkpoint path."""
    leaves, _ = _flatten(tree)
    paths = _tree_paths(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    arrays = {f"leaf_{i:05d}": np.asarray(jax.device_get(leaf))
              for i, leaf in enumerate(leaves)}
    meta = {"step": step, "n_leaves": len(leaves), "paths": paths,
            "extra": extra or {}}
    return write_manifest_dir(final, arrays, meta)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if manifest_exists(os.path.join(directory, name)):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, tree_like: Any, step: Optional[int] = None,
                    target_shardings: Any = None, verify: bool = True) -> Any:
    """Load into the structure of ``tree_like``; re-shard onto
    ``target_shardings`` (a matching tree of Shardings) if given."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    arrays, meta = read_manifest_dir(path, verify=verify)
    leaves, treedef = _flatten(tree_like)
    if meta["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, expected {len(leaves)}")
    shard_leaves = (None,) * len(leaves)
    if target_shardings is not None:
        shard_leaves = treedef.flatten_up_to(target_shardings)
    out = []
    for i, (like, shard, tree_path) in enumerate(
            zip(leaves, shard_leaves, meta["paths"])):
        arr = arrays[f"leaf_{i:05d}"]
        if list(arr.shape) != list(like.shape):
            raise ValueError(
                f"shape mismatch for {tree_path}: {arr.shape} vs {like.shape}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out)


class CheckpointManager:
    """Async single-writer checkpoint manager with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def async_save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Device-get happens on the caller thread (consistent snapshot);
        file I/O runs in the background."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        self.wait()
        p = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return p

    def restore(self, tree_like: Any, step: Optional[int] = None,
                target_shardings: Any = None) -> Any:
        self.wait()
        return load_checkpoint(self.directory, tree_like, step,
                               target_shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
