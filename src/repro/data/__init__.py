from .pipeline import SyntheticLM, TokenBatcher, sharded_batches

__all__ = ["SyntheticLM", "TokenBatcher", "sharded_batches"]
