"""Deterministic, restart-safe data pipeline.

``SyntheticLM`` generates a reproducible Markov-chain token stream (so a ~100M
model has non-trivial structure to learn and the loss visibly decreases);
``TokenBatcher`` packs it into (tokens, labels) batches keyed by *step
number*, so a restarted job re-reads exactly the batches it would have seen —
the property the fault-tolerance path relies on.  ``sharded_batches`` places
each batch onto the mesh with the dp sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticLM:
    """Order-1 Markov chain over a small vocab with heavy-tailed transitions."""

    vocab: int
    seed: int = 0
    branching: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.next_tokens = rng.integers(
            0, self.vocab, size=(self.vocab, self.branching))
        probs = rng.dirichlet(np.ones(self.branching) * 0.5,
                              size=self.vocab)
        self.cum = np.cumsum(probs, axis=1)

    def sample(self, rng: np.random.Generator, batch: int, length: int) -> np.ndarray:
        out = np.empty((batch, length + 1), dtype=np.int32)
        cur = rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, length + 1):
            u = rng.random(batch)
            choice = (u[:, None] > self.cum[cur]).sum(axis=1)
            cur = self.next_tokens[cur, np.minimum(choice, self.branching - 1)]
            out[:, t] = cur
        return out


class TokenBatcher:
    """step -> {"tokens", "labels"}; deterministic in (seed, step)."""

    def __init__(self, source: SyntheticLM, batch: int, seq_len: int,
                 seed: int = 0):
        self.source = source
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        seqs = self.source.sample(rng, self.batch, self.seq_len)
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


def sharded_batches(batcher: TokenBatcher, mesh, dp_spec,
                    steps: Optional[int] = None) -> Iterator[Dict]:
    shard = NamedSharding(mesh, P(dp_spec, None))
    step = 0
    while steps is None or step < steps:
        b = batcher(step)
        yield {k: jax.device_put(v, shard) for k, v in b.items()}
        step += 1
