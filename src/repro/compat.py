"""JAX version-compatibility shims.

The codebase targets the current JAX sharding / Pallas APIs, but must run on
older installs (0.4.x) too.  Everything version-dependent funnels through this
module so the rest of the tree imports one stable surface:

  * ``AxisType``            — ``jax.sharding.AxisType`` or an equivalent enum;
  * ``make_mesh``           — ``jax.make_mesh`` with ``axis_types`` dropped
                              when unsupported;
  * ``set_mesh``            — ``jax.set_mesh`` or an emulation via the
                              ``Mesh`` context manager (old JAX resolves named
                              axes from the entered mesh context);
  * ``get_abstract_mesh``   — ``jax.sharding.get_abstract_mesh`` or the
                              thread-resources physical mesh (empty when no
                              mesh is active; callers check ``.empty``);
  * ``tpu_compiler_params`` — ``pltpu.CompilerParams`` (new) /
                              ``pltpu.TPUCompilerParams`` (old).
"""

from __future__ import annotations

import enum
from typing import Optional, Sequence

import jax

# True on JAX installs predating the explicit-sharding API family
# (set_mesh / AxisType / get_abstract_mesh).  A few call sites need more than
# an API spelling change on these versions — e.g. known-bad GSPMD interactions
# are gated off.
LEGACY_JAX = not hasattr(jax, "set_mesh")

try:  # jax >= 0.7
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    axis_types: Optional[Sequence] = None,
    **kwargs,
):
    """``jax.make_mesh`` tolerant of installs without ``axis_types``."""
    if axis_types is None:
        axis_types = (AxisType.Auto,) * len(tuple(axis_names))
    try:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=tuple(axis_types), **kwargs)
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)


_entered_mesh = None  # the mesh context we are emulating set_mesh with


def set_mesh(mesh) -> None:
    """``jax.set_mesh`` or an emulation on old JAX.

    Old JAX has no process-global mesh; entering the ``Mesh`` context manager
    (and leaving any previously entered one) gives the same named-axis
    resolution for everything traced afterwards.
    """
    global _entered_mesh
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
        return
    if _entered_mesh is not None:
        _entered_mesh.__exit__(None, None, None)
        _entered_mesh = None
    if mesh is not None:
        mesh.__enter__()
        _entered_mesh = mesh


def get_abstract_mesh():
    """The active mesh, or an *empty* mesh object when none is set.

    Returns ``jax.sharding.get_abstract_mesh()`` on new JAX; on old JAX the
    physical mesh of the active ``with mesh:`` context (which ``set_mesh``
    above enters).  Either way the result supports ``.empty``,
    ``.axis_names`` and ``.shape``.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def axis_size(axis_name):
    """``jax.lax.axis_size`` with a fallback for JAX versions without it."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax._src import core

    return core.trace_ctx.axis_env.axis_size(axis_name)


def named_shardings(mesh, spec_tree):
    """Convert a pytree of ``PartitionSpec``/``None`` into ``NamedSharding``s.

    New JAX accepts raw specs (and ``None``) in ``jax.jit``'s
    ``in_shardings``/``out_shardings`` under a set mesh, so the tree passes
    through untouched there — in particular ``None`` keeps meaning
    "unconstrained, compiler's choice".  Old JAX requires ``Sharding``
    instances; there ``None`` becomes fully replicated (the closest legal
    spelling).
    """
    if not LEGACY_JAX:
        return spec_tree
    from jax.sharding import NamedSharding, PartitionSpec

    def conv(s):
        if s is None:
            return NamedSharding(mesh, PartitionSpec())
        if isinstance(s, PartitionSpec):
            return NamedSharding(mesh, s)
        return s

    return jax.tree.map(
        conv, spec_tree,
        is_leaf=lambda x: x is None or isinstance(x, PartitionSpec),
    )


def tpu_compiler_params(**kwargs):
    """Build Pallas TPU compiler params across the class rename."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
