"""JAX version-compatibility shims.

The codebase targets the current JAX sharding / Pallas APIs, but must run on
older installs (0.4.x) too.  Everything version-dependent funnels through this
module so the rest of the tree imports one stable surface:

  * ``AxisType``            — ``jax.sharding.AxisType`` or an equivalent enum;
  * ``make_mesh``           — ``jax.make_mesh`` with ``axis_types`` dropped
                              when unsupported;
  * ``set_mesh``            — ``jax.set_mesh`` or an emulation via the
                              ``Mesh`` context manager (old JAX resolves named
                              axes from the entered mesh context);
  * ``get_abstract_mesh``   — ``jax.sharding.get_abstract_mesh`` or the
                              thread-resources physical mesh (empty when no
                              mesh is active; callers check ``.empty``);
  * ``tpu_compiler_params`` — ``pltpu.CompilerParams`` (new) /
                              ``pltpu.TPUCompilerParams`` (old);
  * ``shard_map``           — ``jax.shard_map`` (new, ``check_vma``) or
                              ``jax.experimental.shard_map.shard_map`` (old,
                              ``check_rep``) behind one keyword surface;
  * ``host_mesh``           — device-count-validated mesh construction used
                              by both the legacy launch meshes and the
                              engine's sharded execution plans.
"""

from __future__ import annotations

import enum
from typing import Optional, Sequence

import jax

# True on JAX installs predating the explicit-sharding API family
# (set_mesh / AxisType / get_abstract_mesh).  A few call sites need more than
# an API spelling change on these versions — e.g. known-bad GSPMD interactions
# are gated off.
LEGACY_JAX = not hasattr(jax, "set_mesh")

try:  # jax >= 0.7
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    axis_types: Optional[Sequence] = None,
    **kwargs,
):
    """``jax.make_mesh`` tolerant of installs without ``axis_types``."""
    if axis_types is None:
        axis_types = (AxisType.Auto,) * len(tuple(axis_names))
    try:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=tuple(axis_types), **kwargs)
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)


_entered_mesh = None  # the mesh context we are emulating set_mesh with


def set_mesh(mesh) -> None:
    """``jax.set_mesh`` or an emulation on old JAX.

    Old JAX has no process-global mesh; entering the ``Mesh`` context manager
    (and leaving any previously entered one) gives the same named-axis
    resolution for everything traced afterwards.
    """
    global _entered_mesh
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
        return
    if _entered_mesh is not None:
        _entered_mesh.__exit__(None, None, None)
        _entered_mesh = None
    if mesh is not None:
        mesh.__enter__()
        _entered_mesh = mesh


def get_abstract_mesh():
    """The active mesh, or an *empty* mesh object when none is set.

    Returns ``jax.sharding.get_abstract_mesh()`` on new JAX; on old JAX the
    physical mesh of the active ``with mesh:`` context (which ``set_mesh``
    above enters).  Either way the result supports ``.empty``,
    ``.axis_names`` and ``.shape``.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax.interpreters import pxla

    return pxla.thread_resources.env.physical_mesh


def axis_size(axis_name):
    """``jax.lax.axis_size`` with a fallback for JAX versions without it."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax._src import core

    return core.trace_ctx.axis_env.axis_size(axis_name)


def named_shardings(mesh, spec_tree):
    """Convert a pytree of ``PartitionSpec``/``None`` into ``NamedSharding``s.

    New JAX accepts raw specs (and ``None``) in ``jax.jit``'s
    ``in_shardings``/``out_shardings`` under a set mesh, so the tree passes
    through untouched there — in particular ``None`` keeps meaning
    "unconstrained, compiler's choice".  Old JAX requires ``Sharding``
    instances; there ``None`` becomes fully replicated (the closest legal
    spelling).
    """
    if not LEGACY_JAX:
        return spec_tree
    from jax.sharding import NamedSharding, PartitionSpec

    def conv(s):
        if s is None:
            return NamedSharding(mesh, PartitionSpec())
        if isinstance(s, PartitionSpec):
            return NamedSharding(mesh, s)
        return s

    return jax.tree.map(
        conv, spec_tree,
        is_leaf=lambda x: x is None or isinstance(x, PartitionSpec),
    )


def host_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Build a mesh over the host's devices, with a readable size check.

    One construction path for every mesh in the tree — the legacy launch
    meshes (``launch/mesh.py``) and the engine's sharded execution plans
    (``engine/sharding.py``) — so device-count errors surface the same way
    everywhere instead of as backend-specific assembly failures.
    """
    need = 1
    for s in axis_shapes:
        need *= int(s)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axis_names, axis_shapes))} needs {need} devices "
            f"but the host has {have}; on CPU force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    return make_mesh(tuple(int(s) for s in axis_shapes), tuple(axis_names),
                     axis_types=(AxisType.Auto,) * len(tuple(axis_names)))


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across the entry-point move and the kwarg rename.

    New JAX exposes ``jax.shard_map`` with ``check_vma``; old JAX has
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.  ``check``
    maps onto whichever spelling the install understands (callers here
    always use explicit collectives, so the default is off).
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    for kw in ({"check_vma": check}, {"check_rep": check}, {}):
        try:
            return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
        except TypeError:
            continue
    raise RuntimeError("no usable shard_map entry point in this JAX install")


def tpu_compiler_params(**kwargs):
    """Build Pallas TPU compiler params across the class rename."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
