"""Continuous-batching serving runtime over compiled execution plans.

The production-shaped half of the paper's compile-once/run-many split:

    from repro.serving import BucketedPlanSet, PlanStore, SparseServer

    store = PlanStore("plans/")                       # persistent plan cache
    plans = BucketedPlanSet.compile(layers, engine=engine,
                                    max_batch=32, plan_store=store)
    server = SparseServer(plans, slo_ms=50.0, engine=engine,
                          plan_store=store)
    server.start()                                    # async scheduler thread
    rid = server.submit(x)                            # admission + queueing
    y = server.wait(rid)                              # Future-style result
    server.swap(new_layers)                           # plan hot-swap
    server.shutdown()                                 # drain + join
    print(server.metrics.summary())

Step-driven mode (no ``start()``: drive ``poll()``/``drain()`` yourself,
collect with ``result(rid)``) is the deterministic test path; ``ModelRouter``
serves several named plan sets through one shared scheduler.  With
``executor_workers=N`` the async mode runs as a staged pipeline — HTTP
ingress (:class:`HttpFrontDoor`) -> batch formation -> per-bucket dispatch
lanes (:class:`DispatchQueues`) -> a bounded :class:`ExecutorPool` — so
different-bucket batches overlap while each lane stays FIFO.  See
``docs/serving.md`` for the bucketing policy, the SLO scheduler, the
threading model, the pipeline architecture, swap semantics, and the
plan-store layout.
"""

from .bucketing import (
    BucketedPlanSet,
    DispatchQueues,
    FormedBatch,
    bucket_sizes,
)
from .http import HttpFrontDoor
from .metrics import ServingMetrics, percentile
from .plancache import PlanStore, layers_fingerprint, plan_cache_key
from .resilience import (
    BatchTimeoutError,
    CircuitBreaker,
    FaultInjector,
    OutputGuardError,
    RetryPolicy,
    Watchdog,
)
from .server import (
    ExecutorPool,
    ModelRouter,
    Request,
    SparseServer,
    SwapHandle,
)

__all__ = [
    "BatchTimeoutError",
    "BucketedPlanSet",
    "CircuitBreaker",
    "DispatchQueues",
    "ExecutorPool",
    "FaultInjector",
    "FormedBatch",
    "HttpFrontDoor",
    "ModelRouter",
    "OutputGuardError",
    "PlanStore",
    "Request",
    "RetryPolicy",
    "ServingMetrics",
    "SparseServer",
    "SwapHandle",
    "Watchdog",
    "bucket_sizes",
    "layers_fingerprint",
    "percentile",
    "plan_cache_key",
]
