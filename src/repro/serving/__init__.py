"""Continuous-batching serving runtime over compiled execution plans.

The production-shaped half of the paper's compile-once/run-many split:

    from repro.serving import BucketedPlanSet, PlanStore, SparseServer

    store = PlanStore("plans/")                       # persistent plan cache
    plans = BucketedPlanSet.compile(layers, engine=engine,
                                    max_batch=32, plan_store=store)
    server = SparseServer(plans, slo_ms=50.0)
    rid = server.submit(x)                            # admission + queueing
    server.poll()                                     # wait-or-fire batches
    y = server.result(rid)
    print(server.metrics.summary())

See ``docs/serving.md`` for the bucketing policy, the SLO scheduler, and
the plan-store layout.
"""

from .bucketing import BucketedPlanSet, bucket_sizes
from .metrics import ServingMetrics, percentile
from .plancache import PlanStore, layers_fingerprint, plan_cache_key
from .server import Request, SparseServer

__all__ = [
    "BucketedPlanSet",
    "PlanStore",
    "Request",
    "ServingMetrics",
    "SparseServer",
    "bucket_sizes",
    "layers_fingerprint",
    "percentile",
    "plan_cache_key",
]
