"""Fault tolerance for the serving runtime: the pieces that keep a server
serving when something inside it breaks.

The engine's layering already contains a correct fallback at every level —
the megakernel and the jnp segment lowering are bit-exact twins (PR 2), and
so are the gated and ungated forwards (PR 6) — exactly the way EIE and
SparseNN treat their compressed/sparsity-exploiting datapaths as
optimizations over a dense reference semantics.  What was missing is the
runtime machinery that *uses* that layering when the fast path misbehaves.
This module provides it:

  * :class:`RetryPolicy` — per-batch execution timeouts plus bounded retry
    with exponential backoff (``SparseServer(retry=...)``);
  * :class:`CircuitBreaker` — the classic three-state machine
    (``closed -> open -> half_open``) that trips after K consecutive batch
    failures/timeouts; the server reacts by swapping to the plan set's
    precompiled **safe-mode twin** (jnp backend, gating off — the same
    bit-exact forward, only slower) and probes the fast plan again after a
    cool-down;
  * :func:`check_finite` — the NaN/Inf output guard: a batch whose result
    is not finite *fails* (contained, per the PR-5 semantics) instead of
    silently returning garbage to every request in it;
  * :func:`call_with_timeout` — bounded execution of a possibly-hung plan
    call (a hung thread cannot be killed in Python; it is abandoned as a
    daemon and the batch is failed/retried);
  * :class:`Heartbeat` / :class:`Watchdog` — detects a dead or wedged
    scheduler thread and restarts it; the request queue and result slots
    are *server* state, so a restart loses nothing that was still queued;
  * :class:`FaultInjector` — deterministic fault injection at named sites
    (raise / delay / hang / corrupt), the harness ``tests/test_chaos.py``
    drives every one of the mechanisms above with.

Everything here is policy + plumbing: no piece touches the schedule
substrate, and the degraded path serves bit-identical outputs by
construction (``ExecutionPlan.safe_twin`` shares the schedule arrays by
reference).  See docs/serving.md "Failure semantics".
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

import numpy as np


class BatchTimeoutError(RuntimeError):
    """A batch execution attempt exceeded ``RetryPolicy.timeout_s``."""


class OutputGuardError(RuntimeError):
    """A batch produced NaN/Inf output (caught by the output guard)."""


# --------------------------------------------------------------------------- #
# retry / timeout / backoff
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff for batch execution.

    Args:
      max_retries: additional attempts after the first failure (0 = the
        pre-resilience behavior: one attempt, failure is final).
      timeout_s: wall-clock bound on ONE execution attempt; ``None`` runs
        unbounded on the calling thread (no helper-thread overhead).
      backoff_s / backoff_mult / max_backoff_s: the delay before retry
        attempt ``k`` (1-based) is ``min(max_backoff_s,
        backoff_s * backoff_mult ** (k - 1))``.
    """

    max_retries: int = 2
    timeout_s: Optional[float] = None
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    max_backoff_s: float = 2.0

    def backoff(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based)."""
        return min(self.max_backoff_s,
                   self.backoff_s * self.backoff_mult ** (attempt - 1))


def call_with_timeout(fn: Callable[[], object],
                      timeout_s: Optional[float],
                      name: str = "call") -> object:
    """Run ``fn()`` with a wall-clock bound.

    ``timeout_s=None`` calls directly on this thread (zero overhead — the
    default serving path).  Otherwise the call runs on a daemon helper
    thread; on timeout :class:`BatchTimeoutError` is raised and the helper
    is *abandoned* (Python cannot cancel a running thread) — callers must
    treat the attempt's side effects as lost, which is safe for plan
    execution because plans are pure functions of their input.
    """
    if timeout_s is None:
        return fn()
    box: dict = {}
    done = threading.Event()

    def run():
        try:
            box["y"] = fn()
        except BaseException as e:  # noqa: BLE001 — re-raised on the caller
            box["e"] = e
        finally:
            done.set()

    t = threading.Thread(target=run, daemon=True, name=f"timed-{name}")
    t.start()
    if not done.wait(timeout_s):
        raise BatchTimeoutError(
            f"{name} exceeded its {timeout_s}s execution timeout")
    if "e" in box:
        raise box["e"]
    return box["y"]


def check_finite(y) -> None:
    """Raise :class:`OutputGuardError` when ``y`` contains NaN/Inf.

    A non-finite batch result must fail the batch (requests complete as
    None, the failure is counted and feeds the circuit breaker) rather
    than be silently returned as garbage to every request in it.
    """
    arr = np.asarray(y)
    if arr.dtype.kind not in "fc":
        try:  # extended dtypes (bf16 …) need a float view to test
            arr = arr.astype(np.float32)
        except (TypeError, ValueError):
            return  # non-numeric output: nothing to guard
    if not np.isfinite(arr).all():
        bad = int(arr.size - np.isfinite(arr).sum())
        raise OutputGuardError(
            f"output guard: batch result has {bad} non-finite values")


# --------------------------------------------------------------------------- #
# circuit breaker
# --------------------------------------------------------------------------- #

class CircuitBreaker:
    """Three-state breaker over consecutive batch failures.

    * ``closed`` — healthy: serve the fast plan.  ``threshold`` consecutive
      failures trip it to ``open``.
    * ``open`` — degraded: the server swaps to the safe-mode twin.  After
      ``cooldown_s`` (measured on the server's injected clock) the next
      batch *probes* the fast plan (``half_open``).
    * ``half_open`` — one probe in flight: success closes the breaker
      (back on the fast plan), failure reopens it (back to the safe twin,
      cool-down restarts).

    The breaker only decides; the plan swap itself is the server's job
    (``SparseServer`` drives it through the same install path ``swap()``
    uses).  Methods return a transition event string (or None) so the
    server can count trips/resets in its metrics.

    ``on_transition`` (settable after construction) is called as
    ``on_transition(event, new_state)`` on EVERY state change — including
    the ``open -> half_open`` probe admission, which no return value
    surfaces — outside the breaker's lock.  The server wires its tracer
    through this so breaker transitions appear in exported traces.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 5.0,
                 on_transition: Optional[Callable[[str, str], None]] = None):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.on_transition = on_transition
        self._mu = threading.Lock()
        self._state = "closed"
        self._failures = 0
        self._opened_at = 0.0
        self.trips = 0          # transitions into `open` (incl. reopen)
        self.resets = 0         # half_open -> closed recoveries

    def _notify(self, event: Optional[str]) -> Optional[str]:
        """Fire ``on_transition`` for ``event`` (lock NOT held — the
        callback may take other locks, e.g. a tracer's)."""
        if event is not None and self.on_transition is not None:
            self.on_transition(event, self.state)
        return event

    @property
    def state(self) -> str:
        with self._mu:
            return self._state

    @property
    def failures(self) -> int:
        with self._mu:
            return self._failures

    def on_success(self) -> Optional[str]:
        """A batch served fine.  Returns ``"reset"`` when a half-open probe
        just closed the breaker."""
        with self._mu:
            self._failures = 0
            if self._state == "half_open":
                self._state = "closed"
                self.resets += 1
                event = "reset"
            else:
                event = None
        return self._notify(event)

    def on_failure(self, now: float) -> Optional[str]:
        """A batch failed/timed out.  Returns ``"tripped"`` (closed -> open)
        or ``"reopened"`` (a half-open probe failed) on a transition."""
        with self._mu:
            self._failures += 1
            if self._state == "half_open":
                self._state = "open"
                self._opened_at = now
                self.trips += 1
                event = "reopened"
            elif self._state == "closed" and \
                    self._failures >= self.threshold:
                self._state = "open"
                self._opened_at = now
                self.trips += 1
                event = "tripped"
            else:
                event = None
        return self._notify(event)

    def use_fast(self, now: float) -> bool:
        """Should the NEXT batch run on the fast plan?  In ``open`` state
        this flips to ``half_open`` (and answers yes — the probe) once the
        cool-down has elapsed."""
        with self._mu:
            event = None
            if self._state == "open":
                if now - self._opened_at >= self.cooldown_s:
                    self._state = "half_open"
                    event = "half_open"
                else:
                    return False
        self._notify(event)
        return True

    def reset(self) -> None:
        """Force-close (a plan hot-swap installs fresh weights — old
        failure history is meaningless for them)."""
        with self._mu:
            changed = self._state != "closed"
            self._state = "closed"
            self._failures = 0
        self._notify("force_reset" if changed else None)


# --------------------------------------------------------------------------- #
# scheduler watchdog
# --------------------------------------------------------------------------- #

class Heartbeat:
    """Wall-clock heartbeat a scheduler loop beats each iteration and the
    watchdog reads.  Deliberately on ``time.monotonic`` rather than the
    server's injectable clock: liveness is a property of real threads."""

    __slots__ = ("_t",)

    def __init__(self):
        self._t = time.monotonic()

    def beat(self) -> None:
        self._t = time.monotonic()

    def age(self) -> float:
        return time.monotonic() - self._t


class Watchdog:
    """Background thread that restarts a dead or wedged scheduler.

    Every ``poll_s`` it checks the watched thread: restart when the thread
    has died (crashed/killed), or when there is queued work but the
    heartbeat is older than ``timeout_s`` (wedged — e.g. hung inside a
    batch with no execution timeout configured).  The restart callback
    must beat the heartbeat itself, so a freshly spawned scheduler is
    never double-restarted before its first loop iteration.
    """

    def __init__(self, *, timeout_s: float, heartbeat: Heartbeat,
                 get_thread: Callable[[], Optional[threading.Thread]],
                 has_work: Callable[[], bool],
                 restart: Callable[[bool], None],
                 stop_event: threading.Event,
                 poll_s: Optional[float] = None,
                 on_poll: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.heartbeat = heartbeat
        self.get_thread = get_thread
        self.has_work = has_work
        self.restart = restart
        self._stop = stop_event
        self.poll_s = poll_s if poll_s is not None \
            else max(0.01, timeout_s / 4.0)
        # extra liveness hook fired every poll, watched-thread state aside:
        # the pipeline server uses it to respawn dead executor-pool workers
        # (the formation thread is `get_thread`; workers are a separate
        # population the dead/wedged checks don't see)
        self.on_poll = on_poll
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "Watchdog":
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="serve-watchdog")
        self._thread.start()
        return self

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            if self.on_poll is not None:
                try:
                    self.on_poll()
                except Exception:
                    pass  # a liveness hook must never kill the watchdog
            t = self.get_thread()
            dead = t is None or not t.is_alive()
            wedged = (not dead and self.has_work()
                      and self.heartbeat.age() > self.timeout_s)
            if dead or wedged:
                self.restart(dead)

    def join(self, timeout: Optional[float] = None) -> None:
        t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout)


# --------------------------------------------------------------------------- #
# fault injection
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class _Fault:
    error: Optional[BaseException] = None
    delay_s: float = 0.0
    hang_s: Optional[float] = None
    corrupt: Optional[Callable] = None
    remaining: Optional[int] = None       # None = fire forever


class FaultInjector:
    """Deterministic fault injection at named sites.

    The serving runtime (and the plan store) call ``fire(site, value)`` at
    well-known points; an injector configured for that site can raise,
    delay, hang, or corrupt the value flowing through — driving every
    failure path the resilience layer has from a test, deterministically.

    Sites currently wired:

    ==================== ====================================================
    ``server.run_batch`` fired inside one batch-execution attempt (before
                         the plan call) — raise/hang/delay here exercises
                         retry, timeout, breaker, and watchdog-wedge paths
    ``server.result``    the batch output flows through ``corrupt=`` —
                         returning NaN-poisoned rows exercises the guard
    ``server.scheduler`` fired once per scheduler-loop iteration — an
                         injected raise kills the scheduler thread (the
                         watchdog-restart path)
    ``router.scheduler`` the ``ModelRouter`` analogue of the above
    ``store.load``       fired inside ``PlanStore.load``'s read path — a
                         raise sends the entry to quarantine
    ==================== ====================================================

    ``times=N`` arms a fault for exactly N firings (the default fires
    forever until ``clear``).  Hung sites block on an event for up to
    ``hang_s``; ``release_hangs()`` unblocks them all (test teardown).
    """

    def __init__(self):
        self._mu = threading.Lock()
        self._faults: Dict[str, _Fault] = {}
        self._unhang = threading.Event()
        self.fired: Dict[str, int] = {}

    def inject(self, site: str, *, error: Optional[BaseException] = None,
               delay_s: float = 0.0, hang_s: Optional[float] = None,
               corrupt: Optional[Callable] = None,
               times: Optional[int] = None) -> "FaultInjector":
        """Arm ``site``: raise ``error`` (an exception instance or class),
        sleep ``delay_s``, hang up to ``hang_s`` (until ``release_hangs``),
        and/or map the site's value through ``corrupt``.  ``times`` bounds
        how many firings the fault survives."""
        if error is None and not delay_s and hang_s is None \
                and corrupt is None:
            raise ValueError(f"fault at {site!r} does nothing")
        with self._mu:
            self._faults[site] = _Fault(error=error, delay_s=delay_s,
                                        hang_s=hang_s, corrupt=corrupt,
                                        remaining=times)
        return self

    def clear(self, site: Optional[str] = None) -> None:
        with self._mu:
            if site is None:
                self._faults.clear()
            else:
                self._faults.pop(site, None)

    def release_hangs(self) -> None:
        """Unblock every site currently (or subsequently) hanging."""
        self._unhang.set()

    def fired_count(self, site: str) -> int:
        with self._mu:
            return self.fired.get(site, 0)

    def fire(self, site: str, value=None):
        """Called by the runtime at ``site``.  Applies the armed fault (if
        any fires remain) and returns the possibly-corrupted value."""
        with self._mu:
            f = self._faults.get(site)
            if f is None or (f.remaining is not None and f.remaining <= 0):
                return value
            if f.remaining is not None:
                f.remaining -= 1
            self.fired[site] = self.fired.get(site, 0) + 1
            error, delay_s, hang_s, corrupt = \
                f.error, f.delay_s, f.hang_s, f.corrupt
        if delay_s:
            time.sleep(delay_s)
        if hang_s is not None:
            self._unhang.wait(hang_s)
        if error is not None:
            raise error() if isinstance(error, type) else error
        if corrupt is not None:
            return corrupt(value)
        return value
