"""Serving metrics: per-request latency, queue depth, throughput, SLO hits.

Everything is recorded against the server's injected clock, so tests drive
time deterministically and production uses ``time.monotonic``.  ``snapshot``
returns a plain JSON-serializable dict — the same shape
``benchmarks/bench_serving.py`` writes into ``BENCH_serving.json``.

Two properties matter for long-lived servers (PR 8):

  * **bounded memory** — the observation series (``latency_s``,
    ``queue_wait_s``, ``form_wait_s``, ``dispatch_wait_s``, ``exec_s``,
    ``queue_depth``, ``form_depth``, ``swap_compile_s``,
    ``batch_sizes``) are :class:`repro.obs.BoundedSeries`, not lists:
    exact percentiles up to 4096 samples, then fixed log-bucket
    estimates within ~12% relative error, O(1) memory forever after;
  * **atomic snapshots** — all ``record_*`` methods and ``snapshot()``
    share one internal lock, so a snapshot taken under traffic is a
    consistent cut (``served`` always equals the latency series count,
    never a torn read between them).  The lock is a *leaf*: nothing is
    called while holding it, so it composes with the server/router locks
    in any order.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from ..obs.series import BoundedSeries


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile; 0.0 for an empty series.

    Total on every input ``snapshot()`` can produce: a single-sample series
    answers every q with its one value, and out-of-range q clamps to
    [0, 100] (q=100 is the max, never an off-the-end index).
    """
    if not xs:
        return 0.0
    ys = sorted(xs)
    q = min(100.0, max(0.0, q))
    k = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[k]


def _series() -> BoundedSeries:
    return BoundedSeries()


@dataclasses.dataclass
class ServingMetrics:
    """Counters + bounded series for one server lifetime."""

    admitted: int = 0
    rejected: int = 0
    served: int = 0
    batches: int = 0
    padded_rows: int = 0
    batched_rows: int = 0
    deadline_misses: int = 0
    results_evicted: int = 0
    batch_failures: int = 0
    failed_requests: int = 0
    swaps: int = 0
    swap_hits: int = 0
    # resilience counters (see repro.serving.resilience)
    retries: int = 0                # batch attempts retried after a failure
    batch_timeouts: int = 0         # attempts killed by RetryPolicy.timeout_s
    nan_guard_failures: int = 0     # batches failed by the NaN/Inf guard
    breaker_trips: int = 0          # circuit breaker closed/half_open -> open
    breaker_resets: int = 0         # half_open -> closed recoveries
    degraded_batches: int = 0       # batches served on the safe-mode twin
    watchdog_restarts: int = 0      # scheduler threads respawned
    deadline_evictions: int = 0     # queued requests evicted past deadline
    cancelled: int = 0              # requests cancelled before execution
    latency_s: BoundedSeries = dataclasses.field(default_factory=_series)
    queue_wait_s: BoundedSeries = dataclasses.field(default_factory=_series)
    # the pipeline split of queue_wait_s (PR 10): form-wait is submit ->
    # batch formation, dispatch-wait is formation -> execution start (time
    # a formed batch sat in its bucket's dispatch lane waiting for a
    # worker).  queue_wait_s stays their sum, so its series is comparable
    # across pre- and post-pipeline runs.
    form_wait_s: BoundedSeries = dataclasses.field(default_factory=_series)
    dispatch_wait_s: BoundedSeries = dataclasses.field(default_factory=_series)
    exec_s: BoundedSeries = dataclasses.field(default_factory=_series)
    swap_compile_s: BoundedSeries = dataclasses.field(default_factory=_series)
    queue_depth: BoundedSeries = dataclasses.field(default_factory=_series)
    # queue depth observed when a batch FORMS (after its rows are popped):
    # arrival-time depth alone cannot show pool-induced buildup — a slow
    # executor pool leaves rows behind at formation, and this series is
    # where that becomes visible
    form_depth: BoundedSeries = dataclasses.field(default_factory=_series)
    batch_sizes: BoundedSeries = dataclasses.field(default_factory=_series)
    bucket_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    max_queue_depth: int = 0
    t_first: Optional[float] = None
    t_last: Optional[float] = None
    # leaf lock: record_* are called from submit, scheduler, and watchdog
    # threads while snapshot() runs from metrics scrapes — one lock makes
    # every snapshot a consistent cut.  Nothing is called while held.
    _mu: threading.Lock = dataclasses.field(default_factory=threading.Lock,
                                            repr=False, compare=False)

    # ------------------------------------------------------------------ #
    def record_submit(self, now: float, depth: int, admitted: bool) -> None:
        """One submit.  ``depth`` is the queue depth the request OBSERVED on
        arrival (before any enqueue) — one convention for admitted and
        rejected submits, so the ``queue_depth`` series is comparable across
        both.  ``max_queue_depth`` separately tracks the depth *attained*:
        an admitted request deepens the queue to ``depth + 1``."""
        with self._mu:
            if self.t_first is None:
                self.t_first = now
            if admitted:
                self.admitted += 1
                self.max_queue_depth = max(self.max_queue_depth, depth + 1)
            else:
                self.rejected += 1
                self.max_queue_depth = max(self.max_queue_depth, depth)
            self.queue_depth.add(depth)

    def record_formation(self, depth: int) -> None:
        """Queue depth left behind at batch-formation time (rows the formed
        batch did NOT take).  Under a healthy pool this hugs zero; a
        saturated executor pool shows up here before it shows up in
        latency."""
        with self._mu:
            self.form_depth.add(depth)

    def record_batch(self, now: float, n: int, bucket: int, exec_s: float,
                     waits_s: List[float], misses: int,
                     dispatch_wait_s: float = 0.0) -> None:
        """One executed batch.  ``waits_s`` are per-request form-waits
        (submit -> batch formation); ``dispatch_wait_s`` is the batch's time
        on its dispatch lane (formation -> execution start), zero for the
        inline/step-driven path.  Total queue wait and latency include
        both, so pre-pipeline series remain comparable."""
        with self._mu:
            self.batches += 1
            self.served += n
            self.batch_sizes.add(n)
            self.bucket_hist[bucket] = self.bucket_hist.get(bucket, 0) + 1
            self.padded_rows += bucket - n
            self.batched_rows += bucket
            self.exec_s.add(exec_s)
            self.dispatch_wait_s.add(dispatch_wait_s)
            self.deadline_misses += misses
            for w in waits_s:
                self.form_wait_s.add(w)
                self.queue_wait_s.add(w + dispatch_wait_s)
                self.latency_s.add(w + dispatch_wait_s + exec_s)
            self.t_last = now

    def record_batch_failure(self, now: float, n: int) -> None:
        """One batch whose plan execution raised: its ``n`` requests were
        consumed (slots complete as None) but not served."""
        with self._mu:
            self.batch_failures += 1
            self.failed_requests += n
            self.t_last = now

    def record_result_evictions(self, n: int) -> None:
        """``n`` finished results dropped before the caller collected them
        (capacity/TTL eviction — see ``SparseServer`` result retention)."""
        with self._mu:
            self.results_evicted += n

    def record_swap(self, now: float, compile_s: float,
                    cache_hit: bool) -> None:
        """One plan hot-swap: the off-path compile (or plan-store hit) that
        produced the swapped-in plan set."""
        with self._mu:
            self.swaps += 1
            if cache_hit:
                self.swap_hits += 1
            self.swap_compile_s.add(compile_s)
            # deliberately NOT touching t_first/t_last: a pre-traffic swap
            # must not stretch the serving span throughput_rps is computed
            # over

    def record_retry(self, timed_out: bool = False,
                     nan_guard: bool = False) -> None:
        """One failed batch attempt that will be retried."""
        with self._mu:
            self.retries += 1
            if timed_out:
                self.batch_timeouts += 1
            if nan_guard:
                self.nan_guard_failures += 1

    def record_attempt_failure(self, timed_out: bool = False,
                               nan_guard: bool = False) -> None:
        """Classify one terminal (non-retried) attempt failure; the batch
        outcome itself is recorded by ``record_batch_failure``."""
        with self._mu:
            if timed_out:
                self.batch_timeouts += 1
            if nan_guard:
                self.nan_guard_failures += 1

    def record_breaker_trip(self) -> None:
        with self._mu:
            self.breaker_trips += 1

    def record_breaker_reset(self) -> None:
        with self._mu:
            self.breaker_resets += 1

    def record_degraded_batch(self) -> None:
        """One batch served on the safe-mode twin (bit-identical outputs,
        slower path)."""
        with self._mu:
            self.degraded_batches += 1

    def record_watchdog_restart(self) -> None:
        with self._mu:
            self.watchdog_restarts += 1

    def record_deadline_evictions(self, n: int) -> None:
        """``n`` queued requests evicted (completed as None) because their
        deadline passed before a batch picked them up."""
        with self._mu:
            self.deadline_evictions += n

    def record_cancel(self) -> None:
        with self._mu:
            self.cancelled += 1

    # ------------------------------------------------------------------ #
    @staticmethod
    def _quantiles_ms(s: BoundedSeries) -> dict:
        return {
            "p50": 1e3 * s.percentile(50),
            "p99": 1e3 * s.percentile(99),
            "count": len(s),
        }

    def snapshot(self) -> dict:
        """A consistent cut of every counter and series.

        Holds the same lock ``record_*`` take, so concurrent traffic can
        never produce a torn read (e.g. ``served`` updated but the latency
        series not yet — the invariant ``served == latency_ms["count"]``
        holds in every snapshot)."""
        with self._mu:
            span = 0.0
            if self.t_first is not None and self.t_last is not None:
                span = max(0.0, self.t_last - self.t_first)
            return {
                "admitted": self.admitted,
                "rejected": self.rejected,
                "served": self.served,
                "batches": self.batches,
                "deadline_misses": self.deadline_misses,
                "results_evicted": self.results_evicted,
                "batch_failures": self.batch_failures,
                "failed_requests": self.failed_requests,
                "swaps": self.swaps,
                "swap_hits": self.swap_hits,
                "retries": self.retries,
                "batch_timeouts": self.batch_timeouts,
                "nan_guard_failures": self.nan_guard_failures,
                "breaker_trips": self.breaker_trips,
                "breaker_resets": self.breaker_resets,
                "degraded_batches": self.degraded_batches,
                "watchdog_restarts": self.watchdog_restarts,
                "deadline_evictions": self.deadline_evictions,
                "cancelled": self.cancelled,
                "swap_compile_ms": self._quantiles_ms(self.swap_compile_s),
                "throughput_rps": self.served / span if span > 0 else 0.0,
                "latency_ms": self._quantiles_ms(self.latency_s),
                "queue_wait_ms": self._quantiles_ms(self.queue_wait_s),
                "form_wait_ms": self._quantiles_ms(self.form_wait_s),
                "dispatch_wait_ms": self._quantiles_ms(self.dispatch_wait_s),
                "form_depth": {
                    "p50": self.form_depth.percentile(50),
                    "p99": self.form_depth.percentile(99),
                    "count": len(self.form_depth),
                },
                "exec_ms": self._quantiles_ms(self.exec_s),
                "mean_batch_size": (self.batch_sizes.total / self.batches
                                    if self.batches else 0.0),
                "max_queue_depth": self.max_queue_depth,
                "padding_fraction": (self.padded_rows / self.batched_rows
                                     if self.batched_rows else 0.0),
                "bucket_hist": {str(k): v
                                for k, v in sorted(self.bucket_hist.items())},
            }

    def summary(self) -> str:
        s = self.snapshot()
        return (f"served {s['served']} ({s['rejected']} rejected, "
                f"{s['deadline_misses']} deadline misses) in {s['batches']} "
                f"batches (mean {s['mean_batch_size']:.1f} rows, "
                f"{100 * s['padding_fraction']:.0f}% padding); "
                f"latency p50 {s['latency_ms']['p50']:.1f} ms / "
                f"p99 {s['latency_ms']['p99']:.1f} ms, "
                f"{s['throughput_rps']:.1f} req/s")
