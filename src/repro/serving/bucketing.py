"""Bucketed execution plans: variable batch sizes without retraces.

A jitted plan traces one program per input shape, so a naive serving loop
either pays a retrace for every distinct batch size that arrives or pads
every batch up to one fixed shape (the old ``launch/serve.py`` behavior —
a 1-row tail batch paid full-bucket latency).  ``BucketedPlanSet`` is the
middle ground the paper's amortization story wants:

  * the offline cost — block DAG, Theorem-1 order, Connection Reordering,
    schedule packing — is paid ONCE, by a single ``Engine.compile`` (or a
    plan-store hit, which skips even the annealing);
  * each power-of-two batch bucket gets its own jitted forward over the
    *same* schedule arrays, so a batch of n rows routes to the smallest
    bucket >= n, pads only up to that bucket, and never retraces once the
    bucket is warm.

Buckets share ``layers``/``schedules``/``flat``/``io`` with the base plan by
reference — the only thing compiled per bucket is the jitted dispatch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.blocksparse import BlockFFNN, BSRLayer
from repro.engine import Engine, ExecutionPlan, Mesh, ShardedExecutionPlan
from repro.obs.trace import NULL_TRACER

AnyPlan = Union[ExecutionPlan, ShardedExecutionPlan]


def bucket_sizes(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch``, plus ``max_batch`` itself when it
    is not a power of two (so the largest batch the server forms still has a
    bucket that fits it exactly)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


@dataclasses.dataclass
class BucketedPlanSet:
    """One compiled schedule, one jitted forward per batch bucket."""

    base: AnyPlan
    buckets: Tuple[int, ...]
    plans: Dict[int, AnyPlan]
    cache_hit: bool = False           # True when the base plan came warm
    bucket_calls: Dict[int, int] = dataclasses.field(default_factory=dict)
    warmup_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    compile_s: float = 0.0            # wall time of the compile/store lookup
    safe_mode: bool = False           # True on a safe twin (degraded path)
    safe: Optional["BucketedPlanSet"] = None   # precompiled safe-mode twin
    # the engine's tracer (when set): fan-out and per-bucket warmup emit
    # compile-phase spans through it.  Never part of equality/repr.
    tracer: Optional[object] = dataclasses.field(default=None, repr=False,
                                                 compare=False)

    @property
    def _tr(self):
        tr = self.tracer
        return tr if tr is not None else NULL_TRACER

    @classmethod
    def compile(
        cls,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        engine: Optional[Engine] = None,
        max_batch: int = 32,
        plan_store=None,
        backend: Optional[str] = None,
        mesh: Optional[Mesh] = None,
        safe_twin: bool = False,
    ) -> "BucketedPlanSet":
        """Compile the schedule once, then fan it out across batch buckets.

        ``plan_store`` (a :class:`repro.serving.plancache.PlanStore`) makes
        the single expensive compile a content-addressed lookup: a hit
        rebuilds the plan from the stored connection order with zero
        annealer iterations.

        ``mesh`` routes the compile through the sharded engine path: the
        base plan is a :class:`ShardedExecutionPlan` and every bucket's
        forward is a fresh lowering of the same collective program —
        ``plan.with_fresh_forward`` hides the single- vs sharded-plan
        difference, so the fan-out code is one path.

        ``safe_twin=True`` also fans out the base plan's safe-mode twin
        (jnp backend, gate off — the same bit-exact forward, only slower)
        into ``self.safe``, so a circuit breaker can degrade to it without
        compiling anything on the failure path.
        """
        engine = engine or Engine()
        tracer = getattr(engine, "tracer", None)
        tr = tracer if tracer is not None else NULL_TRACER
        t0 = time.perf_counter()
        if plan_store is not None:
            base, hit = plan_store.get_or_compile(engine, net, backend,
                                                  mesh=mesh)
        else:
            base, hit = engine.compile(net, backend, mesh=mesh), False
        sizes = bucket_sizes(max_batch)
        with tr.span("bucket.fanout", buckets=len(sizes), cache_hit=hit):
            plans = {b: base.with_fresh_forward(jit=engine.jit)
                     for b in sizes}
        out = cls(base=base, buckets=sizes, plans=plans, cache_hit=hit,
                  bucket_calls={b: 0 for b in sizes},
                  compile_s=time.perf_counter() - t0, tracer=tracer)
        if safe_twin:
            out.safe = out.build_safe_twin(jit=engine.jit)
        return out

    def build_safe_twin(self, jit: bool = True) -> "BucketedPlanSet":
        """Fan this set's schedule out through the safe-mode twin (jnp
        backend, gating off): same buckets, same schedule arrays by
        reference, the simplest lowering of the identical function.  The
        twin is marked ``safe_mode=True`` so the server can tell which
        plan set a batch ran on (``degraded_batches`` accounting)."""
        safe_base = self.base.safe_twin(jit=jit)
        return dataclasses.replace(
            self,
            base=safe_base,
            plans={b: safe_base.with_fresh_forward(jit=jit)
                   for b in self.buckets},
            bucket_calls={b: 0 for b in self.buckets},
            warmup_s={},
            safe_mode=True,
            safe=None,
        )

    @property
    def max_batch(self) -> int:
        return self.buckets[-1]

    @property
    def n_in(self) -> int:
        return self.base.n_in

    @property
    def n_out(self) -> int:
        return self.base.n_out

    @property
    def dtype(self) -> np.dtype:
        """The dtype every bucket was traced with; inputs are cast to it
        before padding, so a client sending e.g. float64 never forces a
        second jit program per bucket."""
        return self.base.dtype

    @property
    def weight_dtype(self) -> str:
        """Storage dtype of the base plan's streamed weight blocks; every
        bucket shares the same (possibly quantized) schedule arrays."""
        return getattr(self.base, "weight_dtype", "f32")

    def bucket_for(self, n: int) -> int:
        """Smallest bucket that fits ``n`` rows (the largest one if none)."""
        if n < 1:
            raise ValueError(f"batch size must be >= 1, got {n}")
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    def warmup(self, dtype=None) -> "BucketedPlanSet":
        """Trace every bucket ahead of traffic, so no request ever pays jit
        time.  Each bucket then runs one *timed* post-trace batch, recorded
        in ``warmup_s[bucket]`` — the per-bucket execution-latency seed the
        server's deadline estimator starts from (without it the deadline
        clause is dead until the first real batch completes).  Warmup calls
        are not counted."""
        dtype = self.dtype if dtype is None else dtype
        tr = self._tr
        for b in self.buckets:
            with tr.span("bucket.warmup", bucket=b,
                         safe_mode=self.safe_mode) as sp:
                x = np.zeros((b, self.n_in), dtype)
                np.asarray(self.plans[b](x))   # block until trace completes
                t0 = time.perf_counter()
                np.asarray(self.plans[b](x))   # steady-state exec latency
                self.warmup_s[b] = time.perf_counter() - t0
                sp["warmup_s"] = round(self.warmup_s[b], 6)
            self.plans[b].calls = 0
        if self.safe is not None:
            # the degraded path must be warm too: a breaker trip is the
            # worst moment to discover an untraced bucket
            self.safe.warmup(dtype)
        return self

    def __call__(self, x) -> np.ndarray:
        """Run a batch of any size.  ``x`` is ``[n, n_in]``; batches larger
        than the top bucket are served in top-bucket chunks."""
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.n_in:
            raise ValueError(
                f"expected input [n, {self.n_in}], got {tuple(x.shape)}")
        if x.dtype != self.dtype:
            # cast BEFORE bucket padding: a caller dtype that differs from
            # the traced one (float64 clients, say) would otherwise lower a
            # second program per bucket and defeat warmup()
            x = x.astype(self.dtype)
        n = x.shape[0]
        if n > self.max_batch:
            parts = [self(x[i:i + self.max_batch])
                     for i in range(0, n, self.max_batch)]
            return np.concatenate(parts)
        b = self.bucket_for(n)
        if n < b:
            x = np.concatenate(
                [x, np.zeros((b - n, x.shape[1]), x.dtype)])
        self.bucket_calls[b] += 1
        y = self.plans[b](x)
        return np.asarray(y)[:n]

    def describe(self) -> str:
        src = "plan-store hit" if self.cache_hit else "cold compile"
        extra = ""
        if self.safe_mode:
            extra = " [SAFE MODE]"
        elif self.safe is not None:
            extra = " [+safe twin]"
        return (f"BucketedPlanSet buckets={list(self.buckets)}{extra} "
                f"({src}); " + self.base.describe())
