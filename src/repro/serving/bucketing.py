"""Bucketed execution plans: variable batch sizes without retraces.

A jitted plan traces one program per input shape, so a naive serving loop
either pays a retrace for every distinct batch size that arrives or pads
every batch up to one fixed shape (the old ``launch/serve.py`` behavior —
a 1-row tail batch paid full-bucket latency).  ``BucketedPlanSet`` is the
middle ground the paper's amortization story wants:

  * the offline cost — block DAG, Theorem-1 order, Connection Reordering,
    schedule packing — is paid ONCE, by a single ``Engine.compile`` (or a
    plan-store hit, which skips even the annealing);
  * each power-of-two batch bucket gets its own jitted forward over the
    *same* schedule arrays, so a batch of n rows routes to the smallest
    bucket >= n, pads only up to that bucket, and never retraces once the
    bucket is warm.

Buckets share ``layers``/``schedules``/``flat``/``io`` with the base plan by
reference — the only thing compiled per bucket is the jitted dispatch.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.blocksparse import BlockFFNN, BSRLayer
from repro.engine import Engine, ExecutionPlan, Mesh, ShardedExecutionPlan
from repro.obs.trace import NULL_TRACER

AnyPlan = Union[ExecutionPlan, ShardedExecutionPlan]


def bucket_sizes(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch``, plus ``max_batch`` itself when it
    is not a power of two (so the largest batch the server forms still has a
    bucket that fits it exactly)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


@dataclasses.dataclass
class BucketedPlanSet:
    """One compiled schedule, one jitted forward per batch bucket."""

    base: AnyPlan
    buckets: Tuple[int, ...]
    plans: Dict[int, AnyPlan]
    cache_hit: bool = False           # True when the base plan came warm
    bucket_calls: Dict[int, int] = dataclasses.field(default_factory=dict)
    warmup_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    compile_s: float = 0.0            # wall time of the compile/store lookup
    safe_mode: bool = False           # True on a safe twin (degraded path)
    safe: Optional["BucketedPlanSet"] = None   # precompiled safe-mode twin
    # the engine's tracer (when set): fan-out and per-bucket warmup emit
    # compile-phase spans through it.  Never part of equality/repr.
    tracer: Optional[object] = dataclasses.field(default=None, repr=False,
                                                 compare=False)

    @property
    def _tr(self):
        tr = self.tracer
        return tr if tr is not None else NULL_TRACER

    @classmethod
    def compile(
        cls,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        engine: Optional[Engine] = None,
        max_batch: int = 32,
        plan_store=None,
        backend: Optional[str] = None,
        mesh: Optional[Mesh] = None,
        safe_twin: bool = False,
    ) -> "BucketedPlanSet":
        """Compile the schedule once, then fan it out across batch buckets.

        ``plan_store`` (a :class:`repro.serving.plancache.PlanStore`) makes
        the single expensive compile a content-addressed lookup: a hit
        rebuilds the plan from the stored connection order with zero
        annealer iterations.

        ``mesh`` routes the compile through the sharded engine path: the
        base plan is a :class:`ShardedExecutionPlan` and every bucket's
        forward is a fresh lowering of the same collective program —
        ``plan.with_fresh_forward`` hides the single- vs sharded-plan
        difference, so the fan-out code is one path.

        ``safe_twin=True`` also fans out the base plan's safe-mode twin
        (jnp backend, gate off — the same bit-exact forward, only slower)
        into ``self.safe``, so a circuit breaker can degrade to it without
        compiling anything on the failure path.
        """
        engine = engine or Engine()
        tracer = getattr(engine, "tracer", None)
        tr = tracer if tracer is not None else NULL_TRACER
        t0 = time.perf_counter()
        if plan_store is not None:
            base, hit = plan_store.get_or_compile(engine, net, backend,
                                                  mesh=mesh)
        else:
            base, hit = engine.compile(net, backend, mesh=mesh), False
        sizes = bucket_sizes(max_batch)
        with tr.span("bucket.fanout", buckets=len(sizes), cache_hit=hit):
            plans = {b: base.with_fresh_forward(jit=engine.jit)
                     for b in sizes}
        out = cls(base=base, buckets=sizes, plans=plans, cache_hit=hit,
                  bucket_calls={b: 0 for b in sizes},
                  compile_s=time.perf_counter() - t0, tracer=tracer)
        if safe_twin:
            out.safe = out.build_safe_twin(jit=engine.jit)
        return out

    def build_safe_twin(self, jit: bool = True) -> "BucketedPlanSet":
        """Fan this set's schedule out through the safe-mode twin (jnp
        backend, gating off): same buckets, same schedule arrays by
        reference, the simplest lowering of the identical function.  The
        twin is marked ``safe_mode=True`` so the server can tell which
        plan set a batch ran on (``degraded_batches`` accounting)."""
        safe_base = self.base.safe_twin(jit=jit)
        return dataclasses.replace(
            self,
            base=safe_base,
            plans={b: safe_base.with_fresh_forward(jit=jit)
                   for b in self.buckets},
            bucket_calls={b: 0 for b in self.buckets},
            warmup_s={},
            safe_mode=True,
            safe=None,
        )

    @property
    def max_batch(self) -> int:
        return self.buckets[-1]

    @property
    def n_in(self) -> int:
        return self.base.n_in

    @property
    def n_out(self) -> int:
        return self.base.n_out

    @property
    def dtype(self) -> np.dtype:
        """The dtype every bucket was traced with; inputs are cast to it
        before padding, so a client sending e.g. float64 never forces a
        second jit program per bucket."""
        return self.base.dtype

    @property
    def weight_dtype(self) -> str:
        """Storage dtype of the base plan's streamed weight blocks; every
        bucket shares the same (possibly quantized) schedule arrays."""
        return getattr(self.base, "weight_dtype", "f32")

    def bucket_for(self, n: int) -> int:
        """Smallest bucket that fits ``n`` rows (the largest one if none)."""
        if n < 1:
            raise ValueError(f"batch size must be >= 1, got {n}")
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    def warmup(self, dtype=None) -> "BucketedPlanSet":
        """Trace every bucket ahead of traffic, so no request ever pays jit
        time.  Each bucket then runs one *timed* post-trace batch, recorded
        in ``warmup_s[bucket]`` — the per-bucket execution-latency seed the
        server's deadline estimator starts from (without it the deadline
        clause is dead until the first real batch completes).  Warmup calls
        are not counted."""
        dtype = self.dtype if dtype is None else dtype
        tr = self._tr
        for b in self.buckets:
            with tr.span("bucket.warmup", bucket=b,
                         safe_mode=self.safe_mode) as sp:
                x = np.zeros((b, self.n_in), dtype)
                np.asarray(self.plans[b](x))   # block until trace completes
                t0 = time.perf_counter()
                np.asarray(self.plans[b](x))   # steady-state exec latency
                self.warmup_s[b] = time.perf_counter() - t0
                sp["warmup_s"] = round(self.warmup_s[b], 6)
            self.plans[b].calls = 0
        if self.safe is not None:
            # the degraded path must be warm too: a breaker trip is the
            # worst moment to discover an untraced bucket
            self.safe.warmup(dtype)
        return self

    def __call__(self, x) -> np.ndarray:
        """Run a batch of any size.  ``x`` is ``[n, n_in]``; batches larger
        than the top bucket are served in top-bucket chunks."""
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != self.n_in:
            raise ValueError(
                f"expected input [n, {self.n_in}], got {tuple(x.shape)}")
        if x.dtype != self.dtype:
            # cast BEFORE bucket padding: a caller dtype that differs from
            # the traced one (float64 clients, say) would otherwise lower a
            # second program per bucket and defeat warmup()
            x = x.astype(self.dtype)
        n = x.shape[0]
        if n > self.max_batch:
            parts = [self(x[i:i + self.max_batch])
                     for i in range(0, n, self.max_batch)]
            return np.concatenate(parts)
        b = self.bucket_for(n)
        if n < b:
            x = np.concatenate(
                [x, np.zeros((b - n, x.shape[1]), x.dtype)])
        self.bucket_calls[b] += 1
        y = self.plans[b](x)
        return np.asarray(y)[:n]

    def describe(self) -> str:
        src = "plan-store hit" if self.cache_hit else "cold compile"
        extra = ""
        if self.safe_mode:
            extra = " [SAFE MODE]"
        elif self.safe is not None:
            extra = " [+safe twin]"
        return (f"BucketedPlanSet buckets={list(self.buckets)}{extra} "
                f"({src}); " + self.base.describe())


# --------------------------------------------------------------------------- #
# Pipeline plumbing: formed batches and per-bucket dispatch lanes (PR 10).
#
# The serving pipeline separates batch FORMATION (the scheduler thread's
# wait-or-fire policy) from batch EXECUTION (a bounded worker pool).  The
# hand-off unit is a ``FormedBatch``: the popped requests plus a snapshot of
# the ``BucketedPlanSet`` they were formed against — executing against the
# snapshot (not ``server.plans``) is what keeps ``swap()`` atomic when
# batches overlap: a swap installed mid-flight never splits one batch across
# two weight sets.
#
# ``DispatchQueues`` holds one bounded FIFO *lane* per (server, bucket).  The
# invariant that buys determinism is **at most one in-flight batch per
# lane**: a lane with an executing batch hands out nothing, so same-bucket
# batches complete in formation order no matter how many workers drain the
# queues, while different buckets (distinct lanes) overlap freely.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class FormedBatch:
    """A batch the formation stage has committed: requests popped from the
    server queue, bound to the plan-set snapshot they will execute on."""

    reqs: List[object]
    plans: BucketedPlanSet
    bucket: int
    t_formed: float
    server: Optional[object] = None   # owning SparseServer (lane key + stats)
    gen: int = 0                      # server plan generation at formation
                                      # (fences breaker feedback from stale
                                      # in-flight batches — see server.py)

    @property
    def lane(self) -> Tuple[int, int]:
        return (id(self.server), self.bucket)


class DispatchQueues:
    """Per-(server, bucket) dispatch lanes between formation and execution.

    * ``put`` appends a formed batch to its lane (bounded by ``per_lane``;
      the formation stage checks ``can_accept`` first, so a full lane is
      backpressure, not an error).
    * ``take`` blocks for a *ready* lane — non-empty and with no batch in
      flight — and returns the globally oldest ready batch, marking the
      lane busy.  One-in-flight-per-lane is what keeps same-bucket batches
      FIFO under a multi-worker pool.
    * ``complete`` retires the in-flight batch, freeing the lane and waking
      both workers (a queued successor became ready) and any drain waiter.

    One instance may be shared by several servers (``ModelRouter``): lanes
    are keyed by ``(id(server), bucket)``, so models never share a lane but
    do share the worker pool draining them.
    """

    def __init__(self, per_lane: int = 2):
        if per_lane < 1:
            raise ValueError(f"per_lane must be >= 1, got {per_lane}")
        self.per_lane = per_lane
        self._cv = threading.Condition(threading.Lock())
        self._lanes: Dict[Tuple[int, int], Deque[FormedBatch]] = {}
        self._busy: Dict[Tuple[int, int], FormedBatch] = {}
        self._closed = False

    # ---- formation side ------------------------------------------------- #
    def can_accept(self, lane: Tuple[int, int]) -> bool:
        with self._cv:
            q = self._lanes.get(lane)
            return not self._closed and (q is None or len(q) < self.per_lane)

    def lane_free(self, lane: Tuple[int, int]) -> bool:
        """True when the lane has nothing queued and nothing in flight — a
        batch put there now is picked up immediately by an idle worker."""
        with self._cv:
            q = self._lanes.get(lane)
            return not q and lane not in self._busy

    def put(self, batch: FormedBatch) -> bool:
        """Enqueue on the batch's lane; False when closed or the lane is
        full (the caller keeps the requests queued and retries later)."""
        with self._cv:
            if self._closed:
                return False
            q = self._lanes.get(batch.lane)
            if q is None:
                q = self._lanes[batch.lane] = collections.deque()
            if len(q) >= self.per_lane:
                return False
            q.append(batch)
            self._cv.notify_all()
            return True

    # ---- execution side ------------------------------------------------- #
    def _ready_locked(self) -> Optional[FormedBatch]:
        best = None
        for lane, q in self._lanes.items():
            if q and lane not in self._busy:
                if best is None or q[0].t_formed < best[0].t_formed:
                    best = (q[0], lane)
        if best is None:
            return None
        batch, lane = best
        self._lanes[lane].popleft()
        self._busy[lane] = batch
        return batch

    def take(self, timeout: Optional[float] = None) -> Optional[FormedBatch]:
        """Oldest ready batch, or None on timeout / close-and-empty."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                batch = self._ready_locked()
                if batch is not None:
                    return batch
                if self._closed and not any(self._lanes.values()):
                    return None
                if deadline is None:
                    self._cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._cv.wait(remaining)

    def complete(self, batch: FormedBatch) -> None:
        with self._cv:
            if self._busy.get(batch.lane) is batch:
                del self._busy[batch.lane]
            self._cv.notify_all()

    # ---- introspection / drain ------------------------------------------ #
    def ready_count(self) -> int:
        with self._cv:
            return sum(1 for lane, q in self._lanes.items()
                       if q and lane not in self._busy)

    def depth(self) -> int:
        with self._cv:
            return sum(len(q) for q in self._lanes.values())

    def in_flight(self) -> int:
        with self._cv:
            return len(self._busy)

    def pending(self, server: Optional[object] = None) -> int:
        """Queued + in-flight batches, optionally for one server only."""
        with self._cv:
            if server is None:
                return (sum(len(q) for q in self._lanes.values())
                        + len(self._busy))
            sid = id(server)
            n = sum(len(q) for lane, q in self._lanes.items()
                    if lane[0] == sid)
            n += sum(1 for lane in self._busy if lane[0] == sid)
            return n

    def wait_idle(self, server: Optional[object] = None,
                  timeout: Optional[float] = None) -> bool:
        """Block until ``pending(server) == 0``; False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if server is None:
                    if (not any(self._lanes.values())
                            and not self._busy):
                        return True
                else:
                    sid = id(server)
                    if (not any(q for lane, q in self._lanes.items()
                                if lane[0] == sid)
                            and not any(lane[0] == sid
                                        for lane in self._busy)):
                        return True
                if deadline is None:
                    self._cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                    self._cv.wait(remaining)

    def drain_batches(self, server: Optional[object] = None
                      ) -> List[FormedBatch]:
        """Pop every queued (not in-flight) batch — the shutdown path uses
        this to run leftovers inline after the pool stops."""
        out: List[FormedBatch] = []
        with self._cv:
            for lane in list(self._lanes):
                if server is not None and lane[0] != id(server):
                    continue
                q = self._lanes[lane]
                while q:
                    out.append(q.popleft())
            self._cv.notify_all()
        out.sort(key=lambda b: b.t_formed)
        return out

    def close(self) -> None:
        """Stop accepting new batches; blocked ``take`` calls return None
        once the queues empty out."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
