"""Continuous-batching request scheduler over bucketed execution plans.

``SparseServer`` is the serving half of the paper's amortization story: the
compiled plan substrate (``BucketedPlanSet``) already paid the offline
schedule cost, so the server's only job is batch formation under a latency
SLO:

  * **admission** — a bounded ``collections.deque``; submits beyond
    ``max_queue`` are rejected immediately (backpressure instead of
    unbounded latency);
  * **wait-or-fire** — a batch fires when it is full (``max_batch`` rows),
    when the oldest request has waited ``max_wait_s`` (don't trade the
    whole SLO for batching efficiency), or when the oldest request's
    deadline minus the per-bucket EWMA batch latency says firing any later
    would miss it;
  * **bucket routing** — a fired batch of n rows runs through the smallest
    plan bucket >= n, so tail batches stop paying full-bucket latency.

The server runs in one of two modes over the SAME scheduling code:

  * **step-driven** (default) — the caller drives ``step``/``poll``/
    ``drain`` explicitly; with an injected ``clock`` this is fully
    deterministic, and it is the path every scheduling rule is tested on;
  * **async** — ``start()`` spawns a background scheduler thread that
    drives the identical wait-or-fire policy against the real clock while
    any number of caller threads ``submit`` concurrently.  ``wait(rid)``
    blocks on a per-request event; ``shutdown()`` drains the queue and
    joins the thread.

Async mode optionally runs as a staged **pipeline** (``executor_workers >
0``): the scheduler thread is reduced to admission + batch FORMATION only,
emitting :class:`repro.serving.bucketing.FormedBatch` snapshots onto
per-bucket dispatch lanes (:class:`~repro.serving.bucketing.
DispatchQueues`), and a bounded :class:`ExecutorPool` drains the lanes.
At most one batch per lane is ever in flight, so same-bucket batches
complete in formation order (determinism), while different buckets overlap
across workers — an in-flight batch no longer blocks formation, and the
annealed plans stop idling behind the scheduler.  The step-driven path is
untouched: no pool runs unless ``start()`` is called with workers
configured, so every deterministic test drives the exact pre-pipeline
code.

``swap(net)`` hot-swaps the served plan set: the new plans compile (or
plan-store-hit) OFF the serving path, then install atomically between
batches — an in-flight batch keeps the old plan set by reference, so no
batch ever sees mixed weights and no request is dropped.

``ModelRouter`` serves several named plan sets (differently-sparse models,
optionally sharded) from one process: per-model queues and metrics, one
shared scheduler thread.

Fault tolerance (see ``repro.serving.resilience`` and docs/serving.md
"Failure semantics"): batches run under a ``RetryPolicy`` (bounded retry +
backoff + optional per-attempt execution timeout), outputs pass a NaN/Inf
guard, a per-server ``CircuitBreaker`` degrades to the plan set's
precompiled safe-mode twin after K consecutive failures (and half-opens
back after a cool-down), and a ``Watchdog`` restarts a dead or wedged
scheduler thread without losing queued requests.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional

import numpy as np

from ..obs.telemetry import IOTelemetry, plan_io_attrs
from ..obs.trace import NULL_TRACER, Tracer
from .bucketing import BucketedPlanSet, DispatchQueues, FormedBatch
from .metrics import ServingMetrics
from .resilience import (
    BatchTimeoutError,
    CircuitBreaker,
    FaultInjector,
    Heartbeat,
    OutputGuardError,
    RetryPolicy,
    Watchdog,
    call_with_timeout,
    check_finite,
)

# the async scheduler's idle tick: an upper bound on how long the loop
# sleeps when nothing says when the policy could next change state
_IDLE_WAIT_S = 0.05
# lower bound on a computed sleep so a deadline a few ns away cannot
# degenerate into a spin loop
_MIN_WAIT_S = 1e-4


@dataclasses.dataclass
class Request:
    rid: int
    x: np.ndarray                 # [n_in] feature vector
    t_submit: float
    deadline: Optional[float]     # absolute clock time, or None


class _Slot:
    """Per-request result slot: the finished row + a lazily-created
    completion event (allocated only when a caller actually blocks in
    ``wait`` — poll-style callers never pay for it).  ``waiters`` counts
    threads currently blocked in ``wait``: a slot someone is actively
    collecting is exempt from capacity/TTL eviction."""

    __slots__ = ("event", "value", "t_done", "done", "waiters")

    def __init__(self):
        self.event: Optional[threading.Event] = None
        self.value: Optional[np.ndarray] = None
        self.t_done: Optional[float] = None
        self.done = False
        self.waiters = 0


class ExecutorPool:
    """Bounded execution-stage worker pool draining :class:`DispatchQueues`.

    Each worker blocks in ``dispatch.take()`` for the oldest *ready* lane
    (non-empty, nothing in flight) and runs the batch through its owning
    server's ``_run_batch`` — against the plan-set snapshot the batch was
    formed with, so a concurrent ``swap()`` never mixes weights inside a
    batch.  A worker that catches a non-batch error (``_run_batch`` already
    contains plan failures) completes the batch's slots as None, so the
    PR-5 invariant — a failed batch never takes the server down, and its
    waiters always unblock — holds with any number of workers.

    One pool may be shared by several servers (``ModelRouter``): batches
    carry their server, so the worker loop is server-agnostic.  Per-worker
    busy time and batch counts feed the ``pool.per_worker`` utilization
    gauges in snapshots.
    """

    def __init__(self, dispatch: DispatchQueues, workers: int = 2,
                 wake: Optional[Callable[[], None]] = None,
                 name: str = "sparse-exec"):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.dispatch = dispatch
        self.workers = workers
        self.wake = wake              # fired after every completion (the
                                      # formation loop may be lane-blocked)
        self.name = name
        self._mu = threading.Lock()
        self._threads: Dict[int, threading.Thread] = {}
        self._busy: Dict[int, FormedBatch] = {}
        self._stats = {i: {"batches": 0, "busy_s": 0.0}
                       for i in range(workers)}
        self._stop = threading.Event()
        self._started_at: Optional[float] = None

    # ------------------------------------------------------------------ #
    def start(self) -> "ExecutorPool":
        with self._mu:
            self._stop.clear()
            if self._started_at is None:
                self._started_at = time.monotonic()
            for i in range(self.workers):
                t = self._threads.get(i)
                if t is None or not t.is_alive():
                    self._spawn_locked(i)
        return self

    def _spawn_locked(self, i: int) -> None:
        t = threading.Thread(target=self._work, args=(i,),
                             name=f"{self.name}-{i}", daemon=True)
        self._threads[i] = t
        t.start()

    def ensure(self) -> None:
        """Respawn dead worker threads (watchdog ``on_poll`` hook).  A
        worker can only die on a non-``Exception`` raise — the loop
        swallows everything else — but the lanes it was draining must not
        go silent when it does."""
        if self._stop.is_set():
            return
        with self._mu:
            if self._stop.is_set() or self._started_at is None:
                return
            for i in range(self.workers):
                t = self._threads.get(i)
                if t is None or not t.is_alive():
                    self._spawn_locked(i)

    @property
    def running(self) -> bool:
        with self._mu:
            return any(t.is_alive() for t in self._threads.values())

    @property
    def accepting(self) -> bool:
        """True while the pool is live and not stopping — the formation
        stage dispatches only while this holds (otherwise it executes
        inline, the pre-pipeline path)."""
        return (not self._stop.is_set() and self._started_at is not None
                and self.running)

    def idle_workers(self) -> int:
        with self._mu:
            alive = sum(1 for t in self._threads.values() if t.is_alive())
            return max(0, alive - len(self._busy))

    # ------------------------------------------------------------------ #
    def _work(self, i: int) -> None:
        while not self._stop.is_set():
            batch = self.dispatch.take(timeout=_IDLE_WAIT_S)
            if batch is None:
                continue
            server = batch.server
            t0 = time.monotonic()
            with self._mu:
                self._busy[i] = batch
            try:
                server._run_batch(batch, worker=i)
            except Exception:
                # _run_batch contains plan failures itself; anything that
                # still escapes (a bug in the completion path, say) must
                # not leave the batch's waiters blocked forever
                try:
                    now = server.clock()
                    with server._cv:
                        server._finish_slots(batch.reqs, None, now)
                        server.metrics.record_batch_failure(
                            now, len(batch.reqs))
                except Exception:
                    pass
            finally:
                with self._mu:
                    self._busy.pop(i, None)
                    st = self._stats[i]
                    st["batches"] += 1
                    st["busy_s"] += time.monotonic() - t0
                self.dispatch.complete(batch)
                server._notify()
                if self.wake is not None:
                    self.wake()

    # ------------------------------------------------------------------ #
    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> bool:
        """Stop the workers.  With ``drain`` (default) every queued and
        in-flight batch executes first (bounded by ``timeout``); without
        it, queued batches are left on the lanes for the caller to run
        inline (in-flight ones still finish).  Returns True when the pool
        fully stopped in time."""
        drained = True
        if drain and self._started_at is not None:
            drained = self.dispatch.wait_idle(timeout=timeout)
        self._stop.set()
        self.dispatch.close()
        joined = True
        with self._mu:
            threads = list(self._threads.values())
        for t in threads:
            if t is not threading.current_thread():
                t.join(timeout)
                joined = joined and not t.is_alive()
        return drained and joined

    def snapshot(self) -> dict:
        """Per-worker utilization (busy-time fraction since pool start)
        plus dispatch-queue state — rendered with a ``worker=`` label by
        ``repro.obs.prom``."""
        with self._mu:
            up = (time.monotonic() - self._started_at
                  if self._started_at is not None else 0.0)
            per_worker = {
                str(i): {
                    "batches": st["batches"],
                    "busy_s": round(st["busy_s"], 6),
                    "utilization": (st["busy_s"] / up if up > 0 else 0.0),
                    "in_flight": 1 if i in self._busy else 0,
                }
                for i, st in self._stats.items()
            }
            busy = len(self._busy)
        return {
            "workers": self.workers,
            "busy_workers": busy,
            "dispatch_depth": self.dispatch.depth(),
            "dispatch_in_flight": self.dispatch.in_flight(),
            "per_worker": per_worker,
        }


class SwapHandle:
    """Future-style handle for an asynchronous plan swap
    (``swap(..., swap_async=True)``).

    The replacement plan set compiles (or plan-store-hits) and warms on a
    background thread; the reference install happens between batches when
    it is ready.  ``wait()`` blocks for the install and returns the
    replaced plan set (re-raising a build failure); ``done`` polls."""

    def __init__(self):
        self._ev = threading.Event()
        self._old: Optional[BucketedPlanSet] = None
        self._err: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: Optional[float] = None
             ) -> Optional[BucketedPlanSet]:
        if not self._ev.wait(timeout):
            raise TimeoutError("swap still building/installing")
        if self._err is not None:
            raise self._err
        return self._old


class SparseServer:
    """Request queue + scheduler serving a :class:`BucketedPlanSet`.

    Args:
      plans: the compiled bucketed plan set to serve.
      max_batch: rows per fired batch (default: the top plan bucket).
      max_queue: admission bound; ``submit`` returns None beyond it.
      slo_ms: target end-to-end latency.  Requests submitted without an
        explicit deadline get ``t_submit + slo_ms``.
      max_wait_ms: wait-or-fire threshold for the oldest queued request
        (default ``slo_ms / 4`` — batching may spend at most a quarter of
        the SLO budget on waiting).
      clock: monotonic time source; injectable for deterministic tests.
      result_capacity: finished results retained for collection; beyond it
        the OLDEST uncollected result is evicted (and counted in
        ``metrics.results_evicted``), so a caller that never polls cannot
        leak every response ever served.
      result_ttl_s: optional age bound on uncollected results (evaluated
        against the injected clock on every insert/submit).
      engine / plan_store / backend / mesh: the compile settings
        ``swap(net)`` uses to build the replacement plan set; only needed
        when hot-swap by network (rather than by prebuilt plans) is used.
      retry: a :class:`RetryPolicy` for batch execution (per-attempt
        timeout, bounded retry, backoff).  Default: one attempt, no
        timeout — the pre-resilience behavior.
      breaker: a :class:`CircuitBreaker`; requires ``plans.safe`` (compile
        with ``safe_twin=True``).  After K consecutive batch failures the
        server degrades to the safe-mode twin, and probes the fast plan
        again after the breaker's cool-down.
      output_guard: fail batches whose output contains NaN/Inf (on by
        default — garbage must not be served as a result).
      enforce_deadlines: evict queued requests whose deadline has already
        passed (they complete as None) instead of serving them late.
      watchdog_s: arm a scheduler watchdog on ``start()``: a scheduler
        thread that dies, or wedges for longer than this with work queued,
        is restarted — queued requests and result slots live on the
        server, so nothing queued is lost.
      fault_injector: a :class:`repro.serving.resilience.FaultInjector`
        whose ``server.*`` sites this server fires (chaos testing).
      name: model name stamped on every span and metric this server emits
        (``ModelRouter`` sets it to the routing key).
      tracer: a :class:`repro.obs.Tracer` recording the request lifecycle
        (submit → queue → execute → done), swaps, breaker transitions, and
        watchdog restarts.  Default is the shared disabled ``NULL_TRACER``
        — one ``enabled`` check per site, nothing recorded.
      measure_dynamic_every: sample measured dynamic I/O
        (``ExecutionPlan.measure_dynamic``) every N successful batches and
        fold it into ``self.io`` (requires a gated fused plan; silently
        inactive otherwise).  0 disables sampling — the measurement runs a
        second instrumented forward, so it is opt-in.
      executor_workers: size of the execution-stage worker pool.  0 (the
        default) keeps the pre-pipeline behavior: the scheduler thread
        forms AND executes each batch itself.  With N >= 1, ``start()``
        also spawns an :class:`ExecutorPool` — the scheduler only forms
        batches onto per-bucket dispatch lanes and the pool drains them,
        so different-bucket batches overlap while same-bucket batches
        stay FIFO.  Step-driven mode ignores this (no pool runs until
        ``start()``).
      dispatch_per_lane: formed batches a dispatch lane buffers beyond
        the in-flight one (lane-full is backpressure on formation, not an
        error).

    All public methods are thread-safe; plan execution itself runs outside
    the lock, so submits are never blocked behind a running batch.
    ``snapshot()`` unifies metrics, I/O gauges, and resilience state — the
    dict the Prometheus endpoint renders (see ``repro.obs.prom``).
    """

    def __init__(
        self,
        plans: BucketedPlanSet,
        max_batch: Optional[int] = None,
        max_queue: int = 1024,
        slo_ms: float = 50.0,
        max_wait_ms: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        result_capacity: int = 4096,
        result_ttl_s: Optional[float] = None,
        engine=None,
        plan_store=None,
        backend: Optional[str] = None,
        mesh=None,
        retry: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
        output_guard: bool = True,
        enforce_deadlines: bool = False,
        watchdog_s: Optional[float] = None,
        fault_injector: Optional[FaultInjector] = None,
        name: str = "default",
        tracer: Optional[Tracer] = None,
        measure_dynamic_every: int = 0,
        executor_workers: int = 0,
        dispatch_per_lane: int = 2,
    ):
        self.plans = plans
        self.max_batch = max_batch or plans.max_batch
        if self.max_batch > plans.max_batch:
            raise ValueError(
                f"max_batch {self.max_batch} exceeds top plan bucket "
                f"{plans.max_batch}")
        self.max_queue = max_queue
        self.slo_s = slo_ms / 1e3
        self.max_wait_s = (max_wait_ms / 1e3 if max_wait_ms is not None
                           else self.slo_s / 4.0)
        self.clock = clock
        self.result_capacity = result_capacity
        self.result_ttl_s = result_ttl_s
        self.metrics = ServingMetrics()
        self._engine = engine
        self._plan_store = plan_store
        self._backend = backend
        self._mesh = mesh
        self._queue: deque = deque()
        self._results: Dict[int, _Slot] = {}
        # finished-and-uncollected rids in completion order (t_done
        # ascending): capacity eviction pops the front, the TTL sweep stops
        # at the first unexpired entry — both O(evicted), never O(live)
        self._done: "OrderedDict[int, float]" = OrderedDict()
        self._rid = itertools.count()
        # per-bucket execution-latency EWMAs, seeded from warmup() timings
        # when available — so the deadline clause is live from the very
        # first request instead of dead until the first batch completes
        self._lat_ewma: Dict[int, float] = dict(plans.warmup_s)
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._closed = False
        self._drain_on_stop = True
        # resilience (see repro.serving.resilience)
        self.retry = retry if retry is not None \
            else RetryPolicy(max_retries=0, timeout_s=None)
        self.breaker = breaker
        if breaker is not None and getattr(plans, "safe", None) is None:
            raise ValueError(
                "a circuit breaker needs a safe-mode twin to degrade to — "
                "compile the plan set with "
                "BucketedPlanSet.compile(..., safe_twin=True)")
        self.output_guard = output_guard
        self.enforce_deadlines = enforce_deadlines
        self.watchdog_s = watchdog_s
        self.injector = fault_injector
        self._fast_plans: Optional[BucketedPlanSet] = None
        self._degraded = False
        self._heartbeat = Heartbeat()
        self._watchdog: Optional[Watchdog] = None
        # observability (see repro.obs and docs/observability.md)
        self.name = name
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.io = IOTelemetry(model=name)
        self.measure_dynamic_every = measure_dynamic_every
        self._measure_countdown = measure_dynamic_every
        self._io_seen: set = set()   # (plan-set id, bucket) already gauged
        # pipeline (PR 10): formation -> dispatch lanes -> executor pool.
        # Nothing is created until start(); step-driven mode never sees it.
        if executor_workers < 0:
            raise ValueError(
                f"executor_workers must be >= 0, got {executor_workers}")
        self.executor_workers = executor_workers
        self.dispatch_per_lane = dispatch_per_lane
        self._dispatch: Optional[DispatchQueues] = None
        self._pool: Optional[ExecutorPool] = None
        self._pool_owned = False     # router-attached pools are stopped by
                                     # the router, not this server
        # plan generation counter: bumped by EVERY plan install (swap,
        # breaker degrade, fast-plan reinstall).  Batches carry the gen
        # they were formed at; breaker feedback from a batch whose gen is
        # stale (formed before the last install) is dropped — an in-flight
        # fast batch failing after degradation must not re-trip the
        # breaker, and a stale safe success must not resolve a probe.
        self._plan_gen = 0
        if breaker is not None and breaker.on_transition is None:
            # breaker state changes (incl. half-open probe admission, which
            # no metric counter sees) become trace events
            breaker.on_transition = self._breaker_transition

    def _breaker_transition(self, event: str, state: str) -> None:
        tr = self.tracer
        if tr.enabled:
            tr.event(f"breaker.{event}", model=self.name, state=state)

    def _fire(self, site: str, value=None):
        """Fire a fault-injection site (no-op without an injector)."""
        inj = self.injector
        return value if inj is None else inj.fire(site, value)

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def submit(self, x, deadline_ms: Optional[float] = None) -> Optional[int]:
        """Enqueue one request.  Returns its id, or None when the queue is
        full (admission control — the caller sheds load instead of queueing
        unboundedly past the SLO) or the server has shut down.  A wrong-shape
        input raises HERE, in the submitting thread — it must never reach
        batch formation, where it would poison every request in its batch."""
        rid, _, _ = self._submit(x, deadline_ms)
        return rid

    def submit_ex(self, x, deadline_ms: Optional[float] = None
                  ) -> "tuple[Optional[int], Optional[str]]":
        """``submit`` with the rejection reason: ``(rid, None)`` on
        admission, ``(None, "queue_full")`` on backpressure, ``(None,
        "closed")`` after shutdown.  The HTTP front door maps these onto
        429 vs 503 (see ``repro.serving.http``)."""
        rid, _, reason = self._submit(x, deadline_ms)
        return rid, reason

    def _submit(self, x, deadline_ms: Optional[float] = None
                ) -> "tuple[Optional[int], bool, Optional[str]]":
        """``(rid, wake, reason)`` — ``wake`` is True when this submit changed the
        scheduler's decision state: the queue just became non-empty (a
        sleeping scheduler may be on its idle tick) or just reached a full
        batch (fire now).  Any other submit leaves the head request — and so
        the wait-or-fire timeout a scheduler is already sleeping on —
        unchanged.  Computed atomically under the lock so a shared-scheduler
        caller (``ModelRouter``) cannot miss the transition."""
        x = np.asarray(x)
        if x.shape != (self.plans.n_in,):
            raise ValueError(
                f"expected input [{self.plans.n_in}], got {tuple(x.shape)}")
        now = self.clock()
        with self._cv:
            self._evict_expired(now)
            depth = len(self._queue)
            if self._closed or depth >= self.max_queue:
                self.metrics.record_submit(now, depth, admitted=False)
                if self.tracer.enabled:
                    self.tracer.event("request.submit", model=self.name,
                                      depth=depth, admitted=False,
                                      closed=self._closed)
                return None, False, \
                    ("closed" if self._closed else "queue_full")
            rid = next(self._rid)
            deadline = now + (deadline_ms / 1e3 if deadline_ms is not None
                              else self.slo_s)
            self._queue.append(Request(rid=rid, x=x,
                                       t_submit=now, deadline=deadline))
            # the result slot exists from admission, so wait(rid) can block
            # on it before the request is ever picked into a batch
            self._results[rid] = _Slot()
            self.metrics.record_submit(now, depth, admitted=True)
            if self.tracer.enabled:
                self.tracer.event("request.submit", model=self.name,
                                  rid=rid, depth=depth, admitted=True)
            # wake on any transition that can change the scheduler's
            # decision or its sleep bound: queue newly non-empty, reached a
            # full batch, or crossed a bucket boundary (the deadline clause
            # estimates from the bucket the CURRENT depth routes to, so a
            # bucket change moves the fire time the scheduler slept on)
            qlen = depth + 1
            pmax = self.plans.max_batch
            wake = (qlen == 1 or qlen == self.max_batch
                    or (qlen <= pmax
                        and self.plans.bucket_for(qlen)
                        != self.plans.bucket_for(max(1, qlen - 1))))
            if wake:
                self._cv.notify_all()
            return rid, wake, None

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    def result(self, rid: int) -> Optional[np.ndarray]:
        """Pop a finished request's output (None while still queued, or
        after its uncollected result was evicted)."""
        with self._lock:
            slot = self._results.get(rid)
            if slot is None or not slot.done:
                return None
            del self._results[rid]
            self._done.pop(rid, None)
            return slot.value

    def status(self, rid: int) -> str:
        """``"pending"`` (queued or in flight), ``"done"`` (result ready to
        collect), or ``"unknown"`` (never admitted, already collected, or
        evicted).  The HTTP front door's poll path."""
        with self._lock:
            slot = self._results.get(rid)
            if slot is None:
                return "unknown"
            return "done" if slot.done else "pending"

    def cancel(self, rid: int) -> bool:
        """Cancel request ``rid`` if it is still queued: it leaves the
        queue, its slot completes as None (waiters unblock), and it is
        counted in ``metrics.cancelled``.  Returns False when the request
        is already in a batch, finished, or unknown — an in-flight row
        cannot be pulled out of a running plan call."""
        with self._cv:
            for i, r in enumerate(self._queue):
                if r.rid == rid:
                    del self._queue[i]
                    self._finish_slots([r], None, self.clock())
                    self.metrics.record_cancel()
                    return True
        return False

    def wait(self, rid: int, timeout: Optional[float] = None,
             cancel_on_timeout: bool = False) -> Optional[np.ndarray]:
        """Block until request ``rid`` finishes, then pop its output.
        Returns None on timeout (the result stays collectable) or when the
        result was already collected/evicted.  This is the Future-style
        collection path for async-mode callers.

        ``cancel_on_timeout`` turns a timeout into per-request deadline
        enforcement: the request is cancelled if still queued (evicted
        cleanly, never served) — an in-flight or finished request is left
        alone and its result stays collectable."""
        with self._lock:
            slot = self._results.get(rid)
            if slot is None:
                return None
            if slot.event is None:
                slot.event = threading.Event()
                if slot.done:
                    slot.event.set()
            slot.waiters += 1
        finished = False
        try:
            finished = slot.event.wait(timeout)
        finally:
            # collect in the SAME locked section that drops the waiter
            # refcount: releasing the count first would open a window where
            # eviction deletes the served result before we pop it
            with self._lock:
                slot.waiters -= 1
                value = None
                if finished and slot.done and \
                        self._results.get(rid) is slot:
                    del self._results[rid]
                    self._done.pop(rid, None)
                    value = slot.value
        if not finished and cancel_on_timeout:
            self.cancel(rid)
        return value

    # ------------------------------------------------------------------ #
    # result retention
    # ------------------------------------------------------------------ #
    def _evict_expired(self, now: float) -> None:
        """Drop uncollected results past ``result_ttl_s`` (lock held).
        ``_done`` is ordered by completion time, so the sweep stops at the
        first unexpired entry — in-flight requests, and slots a ``wait``
        caller is actively blocked on, are never touched."""
        if self.result_ttl_s is None:
            return
        victims = []
        for rid, t_done in self._done.items():
            if now - t_done <= self.result_ttl_s:
                break
            if self._results[rid].waiters:
                continue
            victims.append(rid)
        for rid in victims:
            del self._done[rid]
            del self._results[rid]
        if victims:
            self.metrics.record_result_evictions(len(victims))

    def _evict_over_capacity(self) -> None:
        """Drop the oldest FINISHED results beyond capacity (lock held).
        In-flight slots don't count against the cap; slots with an active
        ``wait`` caller are skipped — a served result must never turn into
        a None for a thread already blocked on collecting it."""
        need = len(self._done) - self.result_capacity
        if need <= 0:
            return
        victims = []
        for rid in self._done:         # oldest first; stops after `need`
            if need <= 0:
                break
            if self._results[rid].waiters:
                continue
            victims.append(rid)
            need -= 1
        for rid in victims:
            del self._done[rid]
            del self._results[rid]
        if victims:
            self.metrics.record_result_evictions(len(victims))

    # ------------------------------------------------------------------ #
    # scheduling
    # ------------------------------------------------------------------ #
    def _estimated_batch_s(self, n: Optional[int] = None) -> float:
        """EWMA execution-latency estimate for a batch of ``n`` rows (the
        current queue depth by default), keyed by the bucket it would route
        to.  A bucket with no observation yet falls back to the most
        pessimistic known bucket; with no observations at all (no warmup,
        no batch served) the estimate is 0.0 and the deadline clause stays
        conservative."""
        if not self._lat_ewma:
            return 0.0
        if n is None:
            n = max(1, min(len(self._queue), self.max_batch))
        bucket = self.plans.bucket_for(min(n, self.plans.max_batch))
        est = self._lat_ewma.get(bucket)
        return est if est is not None else max(self._lat_ewma.values())

    def should_fire(self, now: Optional[float] = None) -> bool:
        """Wait-or-fire policy for the current queue state."""
        with self._lock:
            return self._should_fire_locked(now)

    def _should_fire_locked(self, now: Optional[float] = None) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        now = self.clock() if now is None else now
        head = self._queue[0]
        if now - head.t_submit >= self.max_wait_s:
            return True
        if head.deadline is not None and \
                head.deadline - now <= self._estimated_batch_s():
            return True   # waiting any longer guarantees an SLO miss
        return False

    def _seconds_to_fire_locked(self, now: float) -> float:
        """How long (at most) until the wait-or-fire policy could flip for
        the CURRENT queue head — the async loop's sleep bound.  New submits
        wake the loop through the condition variable regardless."""
        if not self._queue:
            return _IDLE_WAIT_S
        head = self._queue[0]
        until = head.t_submit + self.max_wait_s - now
        if head.deadline is not None:
            until = min(until,
                        head.deadline - self._estimated_batch_s() - now)
        return min(_IDLE_WAIT_S, max(_MIN_WAIT_S, until))

    def _evict_expired_requests(self, now: float) -> None:
        """Deadline enforcement on the queue (lock held; no-op unless
        ``enforce_deadlines``): requests whose deadline has already passed
        are evicted — their slots complete as None immediately instead of
        wasting a batch row on an answer nobody can use in time."""
        if not self.enforce_deadlines or not self._queue:
            return
        expired = [r for r in self._queue
                   if r.deadline is not None and now > r.deadline]
        if not expired:
            return
        dead = {r.rid for r in expired}
        kept = [r for r in self._queue if r.rid not in dead]
        self._queue.clear()
        self._queue.extend(kept)
        self._finish_slots(expired, None, now)
        self.metrics.record_deadline_evictions(len(expired))

    def _breaker_admit_locked(self, now: float) -> None:
        """Ask the breaker which plan set the NEXT batch runs on (lock
        held).  While degraded, an elapsed cool-down half-opens the breaker
        and reinstalls the fast plans for one probe batch; the probe's
        outcome (``on_success``/``on_failure``) decides whether they
        stay."""
        if self.breaker is None or not self._degraded:
            return
        if self.breaker.use_fast(now):
            fast = self._fast_plans
            if fast is not None:
                self.plans = fast
                self._plan_gen += 1   # fence: stale safe batches still in
                                      # flight must not resolve the probe
                if fast.warmup_s:
                    self._lat_ewma = dict(fast.warmup_s)
            self._degraded = False

    def _breaker_failure_locked(self, now: float) -> None:
        """Feed one terminal batch failure to the breaker (lock held); on a
        trip/reopen, degrade: install the safe-mode twin through the same
        reference-install path ``swap()`` uses — in-flight batches keep
        their snapshot, the next batch runs safe."""
        if self.breaker is None:
            return
        if self.breaker.on_failure(now) is None:
            return
        fast = self._fast_plans if self._degraded else self.plans
        safe = getattr(fast, "safe", None)
        if safe is not None:
            self._fast_plans = fast
            self.plans = safe
            self._plan_gen += 1   # fence: in-flight fast batches that fail
                                  # AFTER this install are stale — their
                                  # breaker feedback is dropped, so one bad
                                  # overlap window can't double-trip
            self._degraded = True
            if safe.warmup_s:
                self._lat_ewma = dict(safe.warmup_s)
        self.metrics.record_breaker_trip()
        self._cv.notify_all()

    def _pipeline_active(self) -> bool:
        """True while formed batches should go to the dispatch lanes (a
        live, accepting executor pool is attached)."""
        pool = self._pool
        return (self._dispatch is not None and pool is not None
                and pool.accepting)

    def _notify(self) -> None:
        """Wake the formation loop (executor-pool completion callback — a
        freed lane may unblock formation or a drain waiter)."""
        with self._cv:
            self._cv.notify_all()

    def _choose_take_locked(self, dispatching: bool) -> int:
        """How many rows the next formed batch takes (lock held; queue
        known non-empty and policy-fired).  Inline execution always takes
        the preferred count (pre-pipeline behavior).  When dispatching,
        lane state decides:

          * preferred lane free -> preferred count (a worker picks it up
            immediately);
          * preferred lane occupied but a worker sits idle -> **spill**: a
            full batch for the largest FREE smaller bucket, so an idle
            worker gets different-bucket work to overlap instead of the
            one hot lane serializing everything (at saturation every
            preferred batch is the top bucket — without spill, workers > 1
            would add nothing);
          * otherwise queue onto the preferred lane while it has room, or
            form nothing (lane-full backpressure; a completion notifies).
        """
        qlen = len(self._queue)
        n_pref = min(qlen, self.max_batch)
        if not dispatching:
            return n_pref
        pref_bucket = self.plans.bucket_for(
            min(n_pref, self.plans.max_batch))
        lane_pref = (id(self), pref_bucket)
        d = self._dispatch
        if d.lane_free(lane_pref):
            return n_pref
        if self._pool is not None and self._pool.idle_workers() > 0:
            for b in reversed(self.plans.buckets):
                if b >= pref_bucket or b > qlen:
                    continue
                if d.lane_free((id(self), b)):
                    return b
        return n_pref if d.can_accept(lane_pref) else 0

    def _form_batch(self, flush: bool = False,
                    dispatching: bool = False) -> Optional[FormedBatch]:
        """The formation stage: apply the wait-or-fire policy and pop one
        batch worth of requests, bound to a snapshot of the current plan
        set (and its generation).  Returns None when the policy says wait
        — or, when dispatching, when every eligible lane is full."""
        with self._lock:
            now = self.clock()
            self._evict_expired_requests(now)
            if not self._queue:
                return None
            if not flush and not self._should_fire_locked(now):
                return None
            self._breaker_admit_locked(now)
            take = self._choose_take_locked(dispatching)
            if take <= 0:
                return None
            reqs: List[Request] = [self._queue.popleft()
                                   for _ in range(take)]
            # formation-time depth: what the batch LEFT behind (satellite
            # fix — arrival-time depth alone can't show pool-induced
            # buildup)
            self.metrics.record_formation(len(self._queue))
            plans = self.plans        # snapshot: a swap() between batches
            return FormedBatch(reqs=reqs, plans=plans,
                               bucket=plans.bucket_for(len(reqs)),
                               t_formed=now, server=self,
                               gen=self._plan_gen)

    def _pump(self, flush: bool = False) -> int:
        """Formation loop body in pipeline mode: form batches onto their
        dispatch lanes until the policy or lane backpressure says stop.
        Returns rows dispatched (NOT served — execution is async)."""
        dispatched = 0
        while True:
            batch = self._form_batch(flush, dispatching=True)
            if batch is None:
                return dispatched
            if not self._dispatch.put(batch):
                # closed (shutdown race) — run inline so nothing is lost
                self._run_batch(batch)
                return dispatched + len(batch.reqs)
            dispatched += len(batch.reqs)

    def step(self, flush: bool = False) -> int:
        """Fire at most one batch if the policy (or ``flush``) says so.
        Returns the number of requests served."""
        batch = self._form_batch(flush)
        if batch is None:
            return 0
        return self._run_batch(batch)

    def poll(self) -> int:
        """Fire as many batches as the policy allows right now."""
        served = 0
        while True:
            n = self.step()
            if n == 0:
                return served
            served += n

    def drain(self) -> int:
        """Serve everything queued, ignoring the wait policy (shutdown /
        end-of-trace flush).  In pipeline mode this pumps the backlog
        through the dispatch lanes and waits for the pool to go idle —
        the bounded-drain invariant holds with any number of workers."""
        if self._pipeline_active():
            dispatched = 0
            while True:
                dispatched += self._pump(flush=True)
                with self._cv:
                    if not self._queue:
                        break
                    if not self._pipeline_active():
                        break   # pool stopped mid-drain: finish inline
                    # lanes full: a completion notifies; bounded wait so a
                    # dying pool cannot wedge the drain
                    self._cv.wait(timeout=_IDLE_WAIT_S)
            if self._dispatch is not None:
                # bounded waits so a pool that stops mid-drain can't wedge
                # us; whatever it leaves on the lanes runs inline below
                while self._pipeline_active() and \
                        not self._dispatch.wait_idle(server=self,
                                                     timeout=_IDLE_WAIT_S):
                    pass
                for b in self._dispatch.drain_batches(server=self):
                    self._run_batch(b)
            # inline sweep for anything left (pool stopped mid-drain)
            while True:
                n = self.step(flush=True)
                if n == 0:
                    return dispatched
                dispatched += n
        served = 0
        while True:
            n = self.step(flush=True)
            if n == 0:
                return served
            served += n

    # ------------------------------------------------------------------ #
    # async mode
    # ------------------------------------------------------------------ #
    def start(self) -> "SparseServer":
        """Spawn the background scheduler thread (idempotent).  The thread
        drives the SAME wait-or-fire policy ``step`` uses, against the real
        clock, while callers ``submit`` concurrently.  With ``watchdog_s``
        a watchdog thread is armed alongside it (see ``_respawn``)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stop.clear()
            self._closed = False
            self._drain_on_stop = True
            if self.executor_workers > 0 and self._dispatch is None:
                # own pipeline (a router-attached one arrives via
                # _attach_pool instead): lanes + pool live for the
                # server's lifetime; start() after shutdown() rebuilds
                # them because close() is sticky on DispatchQueues
                self._dispatch = DispatchQueues(
                    per_lane=self.dispatch_per_lane)
                self._pool = ExecutorPool(self._dispatch,
                                          workers=self.executor_workers,
                                          name=f"{self.name}-exec")
                self._pool_owned = True
            if self._pool is not None and self._pool_owned:
                self._pool.start()
            self._spawn_scheduler_locked()
            if self.watchdog_s is not None and \
                    (self._watchdog is None or not self._watchdog.running):
                pool = self._pool if self._pool_owned else None
                self._watchdog = Watchdog(
                    timeout_s=self.watchdog_s,
                    heartbeat=self._heartbeat,
                    get_thread=lambda: self._thread,
                    has_work=lambda: len(self._queue) > 0,
                    restart=self._respawn,
                    stop_event=self._stop,
                    on_poll=(pool.ensure if pool is not None else None),
                ).start()
        return self

    def _attach_pool(self, dispatch: DispatchQueues,
                     pool: ExecutorPool) -> None:
        """Hook this server up to a SHARED dispatch/pool (``ModelRouter``):
        lanes are keyed by (server, bucket) so models never share a lane,
        but the workers draining them are common.  The router owns the
        pool's lifecycle."""
        with self._lock:
            self._dispatch = dispatch
            self._pool = pool
            self._pool_owned = False

    def _spawn_scheduler_locked(self) -> None:
        # beat first: a fresh scheduler must never look stale to the
        # watchdog before its first loop iteration
        self._heartbeat.beat()
        self._thread = threading.Thread(
            target=self._serve_loop, name="sparse-server", daemon=True)
        self._thread.start()

    def _respawn(self, dead: bool) -> None:
        """Watchdog callback: the scheduler thread died (crashed) or wedged
        past ``watchdog_s`` with work queued — replace it.  Queued requests
        and result slots are server state, not thread state, so the new
        scheduler picks the backlog up exactly where the old one left it; a
        wedged-but-alive old thread retires itself at its next loop check
        (``self._thread is not me``)."""
        with self._cv:
            if self._stop.is_set():
                return
            self.metrics.record_watchdog_restart()
            if self.tracer.enabled:
                self.tracer.event("watchdog.restart", model=self.name,
                                  dead=dead)
            self._spawn_scheduler_locked()
            self._cv.notify_all()

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def _serve_loop(self) -> None:
        me = threading.current_thread()
        while True:
            if self._thread is not me:
                return  # superseded by a watchdog restart — retire quietly
            self._heartbeat.beat()
            # chaos site: an injected raise here kills this thread (the
            # watchdog-restart path); fired OUTSIDE the lock so an injected
            # hang wedges only the scheduler, never submitters
            self._fire("server.scheduler")
            with self._cv:
                while not self._stop.is_set() and not self._queue:
                    if self._thread is not me:
                        return
                    self._heartbeat.beat()
                    self._cv.wait(timeout=_IDLE_WAIT_S)
                if self._stop.is_set() and \
                        (not self._drain_on_stop or not self._queue):
                    return
                timeout = self._seconds_to_fire_locked(self.clock())
            # execution happens OUTSIDE the lock: submits stay unblocked.
            # Pipeline mode only FORMS here — execution is the pool's job
            pipelined = self._pipeline_active()
            if pipelined:
                served = self._pump(flush=self._stop.is_set())
            else:
                served = self.step(flush=self._stop.is_set())
            if served == 0:
                with self._cv:
                    # re-check under the cv before sleeping: a notify that
                    # landed between step() and here (e.g. the queue filling
                    # to a full batch) would otherwise be lost and the ready
                    # batch would sleep out the stale timeout.  In pipeline
                    # mode a zero pump may also mean lane-full backpressure
                    # — then the wait is correct regardless of the policy
                    # (a batch completion notifies this cv), and it stays
                    # bounded by `timeout` <= the idle tick
                    if pipelined or (not self._stop.is_set()
                                     and not self._should_fire_locked()):
                        if not (self._stop.is_set() and not self._queue):
                            self._cv.wait(timeout=timeout)

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None,
                 drain_timeout_s: Optional[float] = None) -> bool:
        """Stop the scheduler thread gracefully.  New submits are rejected
        from this point on.  With ``drain`` (default) every queued request
        is served before the thread exits — the loop switches to flush
        mode, and anything it leaves behind is drained synchronously here.
        With ``drain=False`` the backlog is abandoned: the thread exits
        immediately, queued requests stay unserved, and their waiters only
        return on timeout (bad-traffic bailout, not the graceful path).

        ``drain_timeout_s`` bounds the WHOLE graceful path: a scheduler
        hung inside a batch would otherwise block this join (and the
        drain) forever.  Past the bound the hung thread and any remaining
        backlog are abandoned — the drain keeps running on a daemon helper,
        but shutdown returns.  Returns True when the stop fully completed
        (thread joined and, with ``drain``, the backlog fully served)."""
        with self._cv:
            self._closed = True
            self._drain_on_stop = drain
            self._stop.set()
            self._cv.notify_all()
        t = self._thread
        join_s = timeout if timeout is not None else drain_timeout_s
        joined = True
        if t is not None and t is not threading.current_thread():
            t.join(join_s)
            joined = not t.is_alive()
        if self._watchdog is not None:
            self._watchdog.join(1.0)
        if self._pool is not None and self._pool_owned:
            # execution stage: with drain, every queued + in-flight lane
            # batch runs before the workers stop; leftovers (a worker died
            # mid-stop) run inline so no dispatched request is lost
            joined = self._pool.stop(drain=drain,
                                     timeout=drain_timeout_s) and joined
            if drain and self._dispatch is not None:
                for b in self._dispatch.drain_batches(server=self):
                    self._run_batch(b)
            # sticky close() on the lanes: rebuild on the next start()
            self._dispatch = None
            self._pool = None
            self._pool_owned = False
        if not drain:
            return joined
        if drain_timeout_s is None:
            self.drain()
            return joined
        done = threading.Event()

        def _drain_bg():
            try:
                self.drain()
            finally:
                done.set()

        helper = threading.Thread(target=_drain_bg, daemon=True,
                                  name="sparse-server-drain")
        helper.start()
        return done.wait(drain_timeout_s) and joined

    # ------------------------------------------------------------------ #
    # plan hot-swap
    # ------------------------------------------------------------------ #
    def swap(self, net=None, plans: Optional[BucketedPlanSet] = None,
             warmup: bool = True, swap_async: bool = False):
        """Hot-swap the served plan set; returns the replaced one.

        Pass ``net`` (a pruned layer stack / ``BlockFFNN`` — the weight
        update) to compile the replacement through the server's
        engine/plan-store settings, or a prebuilt ``plans``.  The compile,
        the plan-store lookup, and the bucket warmup all run OFF the
        serving path — no lock held, batches keep firing throughout; only
        the final reference install holds the lock.  A batch snapshots
        ``self.plans`` when it forms, so an in-flight batch finishes on the
        plan set it started with: no request is ever dropped or served by
        mixed weights, and the swapped-in weights take effect on the next
        batch.

        ``swap_async=True`` moves even the *caller's* wait off the serving
        path: the build runs on a background thread and the install lands
        between batches when it is ready — a weight update never stalls
        the pipeline or the thread requesting it.  Returns a
        :class:`SwapHandle` immediately (``handle.wait()`` -> the replaced
        plan set).
        """
        if (net is None) == (plans is None):
            raise ValueError("swap needs exactly one of net= or plans=")
        tr = self.tracer
        t_sw0 = tr.clock() if tr.enabled else 0.0
        if not swap_async:
            built, compile_s, cache_hit = self._swap_build(net, plans,
                                                           warmup)
            return self._swap_install(built, compile_s, cache_hit, t_sw0)
        handle = SwapHandle()

        def _bg():
            try:
                built, compile_s, cache_hit = self._swap_build(net, plans,
                                                               warmup)
                handle._old = self._swap_install(built, compile_s,
                                                 cache_hit, t_sw0)
            except BaseException as e:  # surfaced via handle.wait()
                handle._err = e
            finally:
                handle._ev.set()

        threading.Thread(target=_bg, daemon=True,
                         name=f"{self.name}-swap").start()
        return handle

    def _swap_build(self, net, plans: Optional[BucketedPlanSet],
                    warmup: bool):
        """The off-path half of a swap: compile/plan-store-hit (for a
        ``net=`` swap), safe-twin completion, warmup, shape validation.
        No server lock is ever held here."""
        # prebuilt plans= paid their compile long ago (possibly never, in a
        # ping-pong swap) — only a net= swap charges compile time/hit state
        # to the swap metrics
        compile_s, cache_hit = 0.0, True
        if plans is None:
            if self._engine is None:
                raise ValueError(
                    "swap(net) needs the server constructed with engine= "
                    "(and optionally plan_store=) to compile the "
                    "replacement plan set")
            plans = BucketedPlanSet.compile(
                net, engine=self._engine, max_batch=self.plans.max_batch,
                plan_store=self._plan_store, backend=self._backend,
                mesh=self._mesh, safe_twin=self.breaker is not None)
            if warmup:
                plans.warmup()
            compile_s, cache_hit = plans.compile_s, plans.cache_hit
        elif self.breaker is not None and \
                getattr(plans, "safe", None) is None:
            # a breaker-guarded server must always have a degradation
            # target; build the twin here, still OFF the serving path
            plans.safe = plans.build_safe_twin()
            if warmup:
                plans.safe.warmup()
        if (plans.n_in, plans.n_out) != (self.plans.n_in, self.plans.n_out):
            raise ValueError(
                f"swapped plans change the model shape: "
                f"{plans.n_in}->{plans.n_out} vs "
                f"{self.plans.n_in}->{self.plans.n_out}; hot-swap is for "
                "weight updates — serve a different architecture as its "
                "own ModelRouter model instead")
        if plans.max_batch < self.max_batch:
            raise ValueError(
                f"swapped plans' top bucket {plans.max_batch} is below the "
                f"server's max_batch {self.max_batch}")
        return plans, compile_s, cache_hit

    def _swap_install(self, plans: BucketedPlanSet, compile_s: float,
                      cache_hit: bool, t_sw0: float) -> BucketedPlanSet:
        """The locked half of a swap: the reference install, between
        batches by construction (every formed batch carries its own plan
        snapshot and generation)."""
        tr = self.tracer
        with self._cv:
            # the logically-installed set is the fast one even while the
            # breaker has the safe twin serving — return that, and start
            # the new weights with a clean failure history
            old = self._fast_plans if self._degraded and \
                self._fast_plans is not None else self.plans
            self.plans = plans
            self._plan_gen += 1   # fence: batches formed before this
                                  # install must not feed the (reset)
                                  # breaker or the reseeded EWMA
            self._fast_plans = None
            self._degraded = False
            if self.breaker is not None:
                self.breaker.reset()
            if plans.warmup_s:
                self._lat_ewma = dict(plans.warmup_s)
            self.metrics.record_swap(self.clock(), compile_s, cache_hit)
            self._cv.notify_all()
        # the swapped-in plans' static I/O gauges replace the old ones on
        # first batch per bucket (fresh plan-set id in _io_seen)
        if tr.enabled:
            tr.span_at("plan.swap", t_sw0, tr.clock(), model=self.name,
                       compile_s=round(compile_s, 6), cache_hit=cache_hit)
        return old

    # ------------------------------------------------------------------ #
    def _attempt(self, plans: BucketedPlanSet, x: np.ndarray):
        """One bounded batch-execution attempt: injector sites, optional
        wall-clock timeout, NaN/Inf guard.  Raises on any failure."""

        def run():
            self._fire("server.run_batch")
            y = plans(x)
            return self._fire("server.result", y)

        y = call_with_timeout(run, self.retry.timeout_s, name="batch")
        if self.output_guard:
            check_finite(y)
        return y

    def _trace_batch(self, reqs: List[Request], plans, bucket: int,
                     t0: float, t1: float, attempt: int,
                     error: Optional[BaseException] = None,
                     worker: Optional[int] = None) -> None:
        """Record the batch's execute span, each request's retroactive queue
        span, and per-request done events (tracer enabled — caller checked)."""
        tr = self.tracer
        attrs = {"model": self.name, "bucket": bucket, "n": len(reqs),
                 "attempt": attempt + 1,
                 "degraded": bool(getattr(plans, "safe_mode", False))}
        if worker is not None:
            attrs["worker"] = worker
        attrs.update(plan_io_attrs(plans.plans.get(bucket, plans.base)))
        if error is not None:
            attrs["error"] = type(error).__name__
        tr.span_at("batch.execute", t0, t1, **attrs)
        for r in reqs:
            tr.span_at("request.queue", r.t_submit, t0, model=self.name,
                       rid=r.rid, bucket=bucket)
            tr.event("request.done", model=self.name, rid=r.rid,
                     ok=error is None,
                     miss=bool(r.deadline is not None and t1 > r.deadline))

    def _run_batch(self, batch: FormedBatch,
                   worker: Optional[int] = None) -> int:
        """Execute one formed batch — inline (scheduler thread, ``worker``
        None) or on an executor-pool worker.  Runs against the batch's own
        plan snapshot; breaker feedback is fenced by the batch's plan
        generation, so a batch that overlapped a swap/degrade/reinstall
        can neither trip nor reset state that belongs to newer plans."""
        reqs, plans = batch.reqs, batch.plans
        n = len(reqs)
        bucket = plans.bucket_for(n)
        x = np.stack([r.x for r in reqs])
        policy = self.retry
        tr = self.tracer
        attempt = 0
        while True:
            t0 = self.clock()
            try:
                y = self._attempt(plans, x)
                break
            except Exception as e:
                # a failed batch must not kill the scheduler thread (in
                # router mode that would stop EVERY model)
                timed_out = isinstance(e, BatchTimeoutError)
                nan_guard = isinstance(e, OutputGuardError)
                t1 = self.clock()
                if attempt < policy.max_retries:
                    attempt += 1
                    with self._lock:
                        self.metrics.record_retry(timed_out=timed_out,
                                                  nan_guard=nan_guard)
                    if tr.enabled:
                        tr.event("batch.retry", model=self.name,
                                 bucket=bucket, attempt=attempt,
                                 error=type(e).__name__)
                    if policy.backoff_s > 0:
                        time.sleep(policy.backoff(attempt))
                    continue
                # retries exhausted: complete the batch's slots with None
                # so waiters unblock, count the failure, feed the breaker,
                # move on
                if tr.enabled:
                    self._trace_batch(reqs, plans, bucket, t0, t1,
                                      attempt, error=e, worker=worker)
                with self._cv:
                    self.metrics.record_attempt_failure(timed_out=timed_out,
                                                        nan_guard=nan_guard)
                    self._finish_slots(reqs, None, t1)
                    self.metrics.record_batch_failure(t1, n)
                    if batch.gen == self._plan_gen:
                        self._breaker_failure_locked(t1)
                return n
        t1 = self.clock()
        exec_s = t1 - t0
        # the pipeline wait split: form-wait (submit -> formation) per
        # request, dispatch-wait (formation -> execution start) per batch.
        # Inline execution starts at formation time, so its dispatch wait
        # is ~0 and the totals match the pre-pipeline series
        dispatch_wait = max(0.0, t0 - batch.t_formed)
        waits = [batch.t_formed - r.t_submit for r in reqs]
        misses = sum(1 for r in reqs
                     if r.deadline is not None and t1 > r.deadline)
        if tr.enabled:
            self._trace_batch(reqs, plans, bucket, t0, t1, attempt,
                              worker=worker)
        do_measure = False
        with self._cv:
            if self.plans is plans:
                # don't let a batch that was in flight across a swap() write
                # the OLD plans' latency into the estimator the swap seeded
                prev = self._lat_ewma.get(bucket)
                self._lat_ewma[bucket] = (exec_s if prev is None
                                          else 0.5 * prev + 0.5 * exec_s)
            self._finish_slots(reqs, y, t1)
            self._evict_expired(t1)
            self.metrics.record_batch(t1, n, bucket, exec_s, waits, misses,
                                      dispatch_wait_s=dispatch_wait)
            if getattr(plans, "safe_mode", False):
                self.metrics.record_degraded_batch()
            if self.breaker is not None and batch.gen == self._plan_gen \
                    and self.breaker.on_success() == "reset":
                # half-open probe served: back on the fast plan for good
                self.metrics.record_breaker_reset()
                self._fast_plans = None
            if self.measure_dynamic_every > 0:
                self._measure_countdown -= 1
                if self._measure_countdown <= 0:
                    self._measure_countdown = self.measure_dynamic_every
                    do_measure = True
            # the seen-check must be atomic under the pool (two workers
            # finishing the same fresh (plan set, bucket) concurrently);
            # the observe itself stays outside the lock
            io_key = (id(plans), bucket)
            io_first = io_key not in self._io_seen
            if io_first:
                self._io_seen.add(io_key)
        # I/O telemetry runs OUTSIDE the lock: static gauges once per
        # (plan set, bucket), measured dynamic I/O on the sampling cadence
        if io_first:
            self.io.observe_plan(bucket, plans.plans.get(bucket, plans.base))
        if do_measure:
            self._measure_dynamic(plans, bucket, x)
        return n

    def _measure_dynamic(self, plans: BucketedPlanSet, bucket: int,
                         x: np.ndarray) -> None:
        """Sample measured dynamic I/O for one served batch (gated fused
        plans only — quietly inactive otherwise).  Telemetry must never
        fail serving, so measurement errors are swallowed into a trace
        event rather than raised."""
        base = getattr(plans, "base", None)
        if base is None or not getattr(base, "gate", False) \
                or getattr(base, "_measure", None) is None:
            return
        try:
            report = base.measure_dynamic(x)
        except Exception as e:
            if self.tracer.enabled:
                self.tracer.event("io.measure_failed", model=self.name,
                                  bucket=bucket, error=type(e).__name__)
            return
        self.io.observe_dynamic(bucket, report)
        if self.tracer.enabled:
            self.tracer.event(
                "io.measure", model=self.name, bucket=bucket,
                dynamic_blocks=int(report.dynamic_total),
                static_blocks=int(report.static_total),
                read_fraction=round(float(report.read_fraction), 4))

    def _finish_slots(self, reqs: List[Request], y, t1: float) -> None:
        """Complete (and wake) each request's slot — with its output row, or
        None for a failed batch (lock held)."""
        for i, r in enumerate(reqs):
            slot = self._results.get(r.rid)
            if slot is None:          # collected early / server torn down
                continue
            slot.value = None if y is None else y[i]
            slot.t_done = t1
            slot.done = True
            if slot.event is not None:
                slot.event.set()
            self._done[r.rid] = t1
        self._evict_over_capacity()

    # ------------------------------------------------------------------ #
    # observability
    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """One JSON-safe cut of everything observable about this server:
        serving metrics (atomic — see ``ServingMetrics.snapshot``),
        per-bucket I/O gauges, resilience state, tracer accounting.  This
        is the dict ``repro.obs.prom.render_prometheus`` renders."""
        snap = self.metrics.snapshot()
        snap["model"] = self.name
        snap["queue_depth_now"] = self.queue_depth
        snap["degraded"] = self._degraded
        if self._pool is not None and self._pool_owned:
            # per-worker utilization + dispatch state (router-shared pools
            # are reported once, at the router level)
            snap["pool"] = self._pool.snapshot()
        if self.breaker is not None:
            snap["breaker_state"] = self.breaker.state
            snap["breaker_open"] = self.breaker.state == "open"
        snap["io"] = self.io.snapshot()
        if self.tracer.enabled:
            snap["tracer"] = self.tracer.snapshot()
        return snap


# ---------------------------------------------------------------------- #
# multi-model serving
# ---------------------------------------------------------------------- #
class ModelRouter:
    """Serve several named :class:`BucketedPlanSet`s from one process.

    Each model gets its own :class:`SparseServer` (queue, admission bound,
    per-model metrics, hot-swap), but ONE shared scheduler thread drives
    them all round-robin — the per-model wait-or-fire policies stay exactly
    the single-model ones, batches never mix models, and a stalled model
    cannot starve another's admission (only delay its batches by one
    execution).

    ``submit`` routes by model id; ``swap(model, net)`` hot-swaps one model
    while the others keep serving.
    """

    def __init__(self, models: Dict[str, BucketedPlanSet],
                 clock: Callable[[], float] = time.monotonic,
                 server_settings: Optional[Dict[str, dict]] = None,
                 watchdog_s: Optional[float] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 tracer: Optional[Tracer] = None,
                 executor_workers: int = 0,
                 dispatch_per_lane: int = 2,
                 **server_kwargs):
        """``server_kwargs`` apply to every model's server;
        ``server_settings[name]`` overlays per-model keyword arguments
        (e.g. the ``engine=``/``plan_store=``/``mesh=`` swap settings, or a
        per-model ``breaker=``).  ``watchdog_s`` arms a watchdog over the
        SHARED scheduler thread; ``fault_injector`` fires the
        ``router.scheduler`` chaos site; ``tracer`` is shared by every
        model's server (spans carry the model name), so one export holds
        the whole process's request lifecycle.  ``executor_workers`` spawns
        ONE execution-stage pool shared by every model on ``start()``:
        lanes are per (model, bucket), so batches of different models — or
        different buckets of one model — overlap across the shared
        workers, while each lane stays FIFO."""
        if not models:
            raise ValueError("ModelRouter needs at least one model")
        settings = server_settings or {}
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.servers: Dict[str, SparseServer] = {
            name: SparseServer(plans, clock=clock,
                               **{"name": name, "tracer": self.tracer,
                                  **server_kwargs,
                                  **settings.get(name, {})})
            for name, plans in models.items()
        }
        self.clock = clock
        self.watchdog_s = watchdog_s
        self.injector = fault_injector
        self.watchdog_restarts = 0
        self._heartbeat = Heartbeat()
        self._watchdog: Optional[Watchdog] = None
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._drain_on_stop = True
        if executor_workers < 0:
            raise ValueError(
                f"executor_workers must be >= 0, got {executor_workers}")
        self.executor_workers = executor_workers
        self.dispatch_per_lane = dispatch_per_lane
        self._dispatch: Optional[DispatchQueues] = None
        self._pool: Optional[ExecutorPool] = None

    @classmethod
    def compile(cls, nets: Dict[str, object], engine=None, max_batch: int = 32,
                plan_store=None, backend: Optional[str] = None,
                meshes: Optional[Dict[str, object]] = None,
                warmup: bool = True, safe_twin: bool = False,
                breaker: Optional[Callable[[], CircuitBreaker]] = None,
                **router_kwargs) -> "ModelRouter":
        """Compile every named network into a bucketed plan set (one
        engine compile or plan-store hit each) and route them together.
        ``meshes`` optionally shards individual models (``{name: Mesh}``).
        The per-model compile settings are threaded through to each server
        so ``swap(model, net)`` works out of the box.

        ``safe_twin`` also precompiles each model's safe-mode twin;
        ``breaker`` is a zero-arg factory (breaker state is per model —
        e.g. ``lambda: CircuitBreaker(threshold=3, cooldown_s=5)``) giving
        every server its own circuit breaker, and implies ``safe_twin``."""
        if breaker is not None:
            safe_twin = True
        models = {}
        for name, net in nets.items():
            mesh = (meshes or {}).get(name)
            plans = BucketedPlanSet.compile(net, engine=engine,
                                            max_batch=max_batch,
                                            plan_store=plan_store,
                                            backend=backend, mesh=mesh,
                                            safe_twin=safe_twin)
            if warmup:
                plans.warmup()
            models[name] = plans
        return cls(models,
                   server_settings={
                       name: dict(engine=engine, plan_store=plan_store,
                                  backend=backend,
                                  mesh=(meshes or {}).get(name),
                                  **({"breaker": breaker()}
                                     if breaker is not None else {}))
                       for name in models
                   }, **router_kwargs)

    # ------------------------------------------------------------------ #
    def _server(self, model: str) -> SparseServer:
        try:
            return self.servers[model]
        except KeyError:
            raise KeyError(
                f"unknown model {model!r}; serving "
                f"{sorted(self.servers)}") from None

    def submit(self, model: str, x,
               deadline_ms: Optional[float] = None) -> Optional[int]:
        """Enqueue one request for ``model``; the returned id is scoped to
        that model (pass the same model to ``result``/``wait``)."""
        # the wake decision is computed atomically inside the server's lock
        # (re-deriving it from queue_depth here could miss the empty->
        # non-empty transition when two submits race) and the router cv is
        # taken only AFTER the server lock is released — the shared loop
        # acquires router-then-server, so the reverse order would deadlock
        rid, wake, _ = self._server(model)._submit(x, deadline_ms)
        if wake:
            with self._cv:
                self._cv.notify_all()
        return rid

    def submit_ex(self, model: str, x,
                  deadline_ms: Optional[float] = None
                  ) -> "tuple[Optional[int], Optional[str]]":
        """``submit`` with the rejection reason (``None`` / ``"queue_full"``
        / ``"closed"``) — the HTTP front door's admission path."""
        rid, wake, reason = self._server(model)._submit(x, deadline_ms)
        if wake:
            with self._cv:
                self._cv.notify_all()
        return rid, reason

    def result(self, model: str, rid: int) -> Optional[np.ndarray]:
        return self._server(model).result(rid)

    def wait(self, model: str, rid: int,
             timeout: Optional[float] = None) -> Optional[np.ndarray]:
        return self._server(model).wait(rid, timeout)

    def swap(self, model: str, net=None,
             plans: Optional[BucketedPlanSet] = None,
             warmup: bool = True, swap_async: bool = False):
        return self._server(model).swap(net, plans=plans, warmup=warmup,
                                        swap_async=swap_async)

    @property
    def queue_depth(self) -> int:
        return sum(s.queue_depth for s in self.servers.values())

    # ------------------------------------------------------------------ #
    def poll(self) -> int:
        return sum(s.poll() for s in self.servers.values())

    def drain(self) -> int:
        return sum(s.drain() for s in self.servers.values())

    def step(self, flush: bool = False) -> int:
        return sum(s.step(flush=flush) for s in self.servers.values())

    # ------------------------------------------------------------------ #
    def start(self) -> "ModelRouter":
        """Spawn the ONE scheduler thread shared by every model (plus its
        watchdog when ``watchdog_s`` is set)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return self
            self._stop.clear()
            self._drain_on_stop = True
            for s in self.servers.values():
                s._closed = False
            if self.executor_workers > 0 and self._dispatch is None:
                # ONE pool shared across every model: per-(model, bucket)
                # lanes, common workers.  Completions wake the shared
                # formation loop through the router cv
                self._dispatch = DispatchQueues(
                    per_lane=self.dispatch_per_lane)
                self._pool = ExecutorPool(self._dispatch,
                                          workers=self.executor_workers,
                                          wake=self._notify,
                                          name="router-exec")
                for s in self.servers.values():
                    s._attach_pool(self._dispatch, self._pool)
            if self._pool is not None:
                self._pool.start()
            self._spawn_scheduler_locked()
            if self.watchdog_s is not None and \
                    (self._watchdog is None or not self._watchdog.running):
                pool = self._pool
                self._watchdog = Watchdog(
                    timeout_s=self.watchdog_s,
                    heartbeat=self._heartbeat,
                    get_thread=lambda: self._thread,
                    has_work=lambda: self.queue_depth > 0,
                    restart=self._respawn,
                    stop_event=self._stop,
                    on_poll=(pool.ensure if pool is not None else None),
                ).start()
        return self

    def _notify(self) -> None:
        with self._cv:
            self._cv.notify_all()

    def _spawn_scheduler_locked(self) -> None:
        self._heartbeat.beat()
        self._thread = threading.Thread(
            target=self._serve_loop, name="model-router", daemon=True)
        self._thread.start()

    def _respawn(self, dead: bool) -> None:
        """Watchdog callback: replace a dead/wedged shared scheduler.  All
        queues and slots live on the per-model servers, so no model loses
        anything queued."""
        with self._cv:
            if self._stop.is_set():
                return
            self.watchdog_restarts += 1
            if self.tracer.enabled:
                self.tracer.event("watchdog.restart", scope="router",
                                  dead=dead)
            self._spawn_scheduler_locked()
            self._cv.notify_all()

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def _serve_loop(self) -> None:
        servers = list(self.servers.values())
        me = threading.current_thread()
        while True:
            if self._thread is not me:
                return  # superseded by a watchdog restart
            self._heartbeat.beat()
            inj = self.injector
            if inj is not None:
                inj.fire("router.scheduler")
            stopping = self._stop.is_set()
            if stopping and not self._drain_on_stop:
                return                 # abandon the backlog (bad-traffic exit)
            served = sum((s._pump(flush=stopping) if s._pipeline_active()
                          else s.step(flush=stopping)) for s in servers)
            if stopping and all(s.queue_depth == 0 for s in servers):
                return
            if served == 0:
                now = self.clock()
                with self._cv:
                    # each server's fire time is read under ITS lock — a
                    # concurrent drain()/step() may pop the head between an
                    # unlocked emptiness check and the head access otherwise.
                    # If any server became fireable since the step sweep (a
                    # notify raced the loop), skip the sleep entirely.  A
                    # pipeline server that is fireable but lane-blocked is
                    # NOT fireable for this purpose — waiting is right (a
                    # batch completion notifies the router cv), and spinning
                    # until a lane frees would starve the other models
                    timeout = _IDLE_WAIT_S
                    fireable = False
                    for s in servers:
                        with s._lock:
                            if not s._queue:
                                continue
                            if s._should_fire_locked(now):
                                if not s._pipeline_active() or \
                                        s._choose_take_locked(True) > 0:
                                    fireable = True
                                    break
                                continue
                            timeout = min(
                                timeout, s._seconds_to_fire_locked(now))
                    if not fireable and not self._stop.is_set():
                        self._cv.wait(timeout=timeout)

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None,
                 drain_timeout_s: Optional[float] = None) -> bool:
        """Graceful stop: reject new submits, serve everything queued (with
        ``drain``; ``drain=False`` abandons every model's backlog), join the
        shared scheduler thread.  ``drain_timeout_s`` bounds the whole
        graceful path exactly like :meth:`SparseServer.shutdown` — a batch
        hung in one model must not hold the process shutdown hostage.
        Returns True when the stop fully completed."""
        for s in self.servers.values():
            with s._cv:
                s._closed = True
        with self._cv:
            self._drain_on_stop = drain
            self._stop.set()
            self._cv.notify_all()
        t = self._thread
        join_s = timeout if timeout is not None else drain_timeout_s
        joined = True
        if t is not None and t is not threading.current_thread():
            t.join(join_s)
            joined = not t.is_alive()
        if self._watchdog is not None:
            self._watchdog.join(1.0)
        if self._pool is not None:
            # the router owns the shared pool: drain every model's lanes,
            # stop the workers, run leftovers inline on their own servers
            joined = self._pool.stop(drain=drain,
                                     timeout=drain_timeout_s) and joined
            if drain and self._dispatch is not None:
                for b in self._dispatch.drain_batches():
                    b.server._run_batch(b)
            for s in self.servers.values():
                s._dispatch = None
                s._pool = None
            self._dispatch = None
            self._pool = None
        if not drain:
            return joined
        if drain_timeout_s is None:
            self.drain()
            return joined
        done = threading.Event()

        def _drain_bg():
            try:
                self.drain()
            finally:
                done.set()

        helper = threading.Thread(target=_drain_bg, daemon=True,
                                  name="model-router-drain")
        helper.start()
        return done.wait(drain_timeout_s) and joined

    # ------------------------------------------------------------------ #
    def metrics_snapshot(self) -> dict:
        """Per-model metrics plus process-level totals."""
        per_model = {name: s.metrics.snapshot()
                     for name, s in self.servers.items()}
        total_keys = ("admitted", "rejected", "served", "batches",
                      "deadline_misses", "results_evicted",
                      "batch_failures", "failed_requests", "swaps",
                      "swap_hits", "retries", "batch_timeouts",
                      "nan_guard_failures", "breaker_trips",
                      "breaker_resets", "degraded_batches",
                      "watchdog_restarts", "deadline_evictions",
                      "cancelled")
        totals = {k: sum(m[k] for m in per_model.values())
                  for k in total_keys}
        # the shared scheduler's own watchdog restarts are router-level
        # (one thread serves every model), reported beside the per-model
        # sums rather than smeared into them
        totals["watchdog_restarts"] += self.watchdog_restarts
        return {"models": per_model, "total": totals,
                "router": {"watchdog_restarts": self.watchdog_restarts}}

    def snapshot(self) -> dict:
        """Full observability snapshot: every model's ``SparseServer
        .snapshot()`` (metrics + I/O gauges + resilience state) under
        ``models``, plus the process totals.  This is what a router-level
        Prometheus endpoint renders — the ``models`` map becomes a
        ``model=`` label."""
        base = self.metrics_snapshot()
        out = {
            "models": {name: s.snapshot()
                       for name, s in self.servers.items()},
            "total": base["total"],
            "router": base["router"],
        }
        if self._pool is not None:
            out["pool"] = self._pool.snapshot()
        return out

    def summary(self) -> str:
        lines = [f"{name}: {s.metrics.summary()}"
                 for name, s in self.servers.items()]
        return "\n".join(lines)
