"""Continuous-batching request scheduler over bucketed execution plans.

``SparseServer`` is the serving half of the paper's amortization story: the
compiled plan substrate (``BucketedPlanSet``) already paid the offline
schedule cost, so the server's only job is batch formation under a latency
SLO:

  * **admission** — a bounded ``collections.deque``; submits beyond
    ``max_queue`` are rejected immediately (backpressure instead of
    unbounded latency);
  * **wait-or-fire** — a batch fires when it is full (``max_batch`` rows),
    when the oldest request has waited ``max_wait_s`` (don't trade the
    whole SLO for batching efficiency), or when the oldest request's
    deadline minus the EWMA batch latency says firing any later would miss
    it;
  * **bucket routing** — a fired batch of n rows runs through the smallest
    plan bucket >= n, so tail batches stop paying full-bucket latency.

The clock is injected (default ``time.monotonic``): tests drive virtual
time deterministically through the same code path production runs.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from .bucketing import BucketedPlanSet
from .metrics import ServingMetrics


@dataclasses.dataclass
class Request:
    rid: int
    x: np.ndarray                 # [n_in] feature vector
    t_submit: float
    deadline: Optional[float]     # absolute clock time, or None


class SparseServer:
    """Request queue + scheduler serving a :class:`BucketedPlanSet`.

    Args:
      plans: the compiled bucketed plan set to serve.
      max_batch: rows per fired batch (default: the top plan bucket).
      max_queue: admission bound; ``submit`` returns None beyond it.
      slo_ms: target end-to-end latency.  Requests submitted without an
        explicit deadline get ``t_submit + slo_ms``.
      max_wait_ms: wait-or-fire threshold for the oldest queued request
        (default ``slo_ms / 4`` — batching may spend at most a quarter of
        the SLO budget on waiting).
      clock: monotonic time source; injectable for deterministic tests.
    """

    def __init__(
        self,
        plans: BucketedPlanSet,
        max_batch: Optional[int] = None,
        max_queue: int = 1024,
        slo_ms: float = 50.0,
        max_wait_ms: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.plans = plans
        self.max_batch = max_batch or plans.max_batch
        if self.max_batch > plans.max_batch:
            raise ValueError(
                f"max_batch {self.max_batch} exceeds top plan bucket "
                f"{plans.max_batch}")
        self.max_queue = max_queue
        self.slo_s = slo_ms / 1e3
        self.max_wait_s = (max_wait_ms / 1e3 if max_wait_ms is not None
                           else self.slo_s / 4.0)
        self.clock = clock
        self.metrics = ServingMetrics()
        self._queue: deque = deque()
        self._results: Dict[int, np.ndarray] = {}
        self._rid = itertools.count()
        self._lat_ewma: Optional[float] = None

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def submit(self, x, deadline_ms: Optional[float] = None) -> Optional[int]:
        """Enqueue one request.  Returns its id, or None when the queue is
        full (admission control — the caller sheds load instead of queueing
        unboundedly past the SLO)."""
        now = self.clock()
        if len(self._queue) >= self.max_queue:
            self.metrics.record_submit(now, len(self._queue), admitted=False)
            return None
        rid = next(self._rid)
        deadline = now + (deadline_ms / 1e3 if deadline_ms is not None
                          else self.slo_s)
        self._queue.append(Request(rid=rid, x=np.asarray(x),
                                   t_submit=now, deadline=deadline))
        self.metrics.record_submit(now, len(self._queue), admitted=True)
        return rid

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def result(self, rid: int) -> Optional[np.ndarray]:
        """Pop a finished request's output (None while still queued)."""
        return self._results.pop(rid, None)

    # ------------------------------------------------------------------ #
    # scheduling
    # ------------------------------------------------------------------ #
    def _estimated_batch_s(self) -> float:
        return self._lat_ewma if self._lat_ewma is not None else 0.0

    def should_fire(self, now: Optional[float] = None) -> bool:
        """Wait-or-fire policy for the current queue state."""
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        now = self.clock() if now is None else now
        head = self._queue[0]
        if now - head.t_submit >= self.max_wait_s:
            return True
        if head.deadline is not None and \
                head.deadline - now <= self._estimated_batch_s():
            return True   # waiting any longer guarantees an SLO miss
        return False

    def step(self, flush: bool = False) -> int:
        """Fire at most one batch if the policy (or ``flush``) says so.
        Returns the number of requests served."""
        if not self._queue:
            return 0
        if not flush and not self.should_fire():
            return 0
        reqs: List[Request] = [
            self._queue.popleft()
            for _ in range(min(self.max_batch, len(self._queue)))
        ]
        return self._run_batch(reqs)

    def poll(self) -> int:
        """Fire as many batches as the policy allows right now."""
        served = 0
        while True:
            n = self.step()
            if n == 0:
                return served
            served += n

    def drain(self) -> int:
        """Serve everything queued, ignoring the wait policy (shutdown /
        end-of-trace flush)."""
        served = 0
        while self._queue:
            served += self.step(flush=True)
        return served

    # ------------------------------------------------------------------ #
    def _run_batch(self, reqs: List[Request]) -> int:
        n = len(reqs)
        bucket = self.plans.bucket_for(n)
        x = np.stack([r.x for r in reqs])
        t0 = self.clock()
        y = self.plans(x)
        t1 = self.clock()
        exec_s = t1 - t0
        self._lat_ewma = (exec_s if self._lat_ewma is None
                          else 0.5 * self._lat_ewma + 0.5 * exec_s)
        waits = [t0 - r.t_submit for r in reqs]
        misses = sum(1 for r in reqs
                     if r.deadline is not None and t1 > r.deadline)
        for r, row in zip(reqs, y):
            self._results[r.rid] = row
        self.metrics.record_batch(t1, n, bucket, exec_s, waits, misses)
        return n
