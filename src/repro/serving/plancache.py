"""Persistent, content-addressed store for compiled execution plans.

The paper's amortization argument says the offline cost — Theorem-1
scheduling plus Connection Reordering — is paid once and served from
forever.  Without persistence "once" really means "once per process":
every server restart re-annealed the same network.  ``PlanStore`` closes
that gap:

  * the cache key is a sha256 over the *content* of the network (each
    layer's block pattern, weights, bias, tile shape) plus every engine
    setting that affects the schedule arrays (``reorder``, ``M_tiles``,
    ``reorder_iters``, ``seed``, ``policy``, ``fuse``) and the artifact
    format version — object identity never matters, so any process that
    builds the same pruned network hits the same entry;
  * the stored artifact is the whole-DAG connection ``order`` (everything
    else re-derives from it deterministically), the flat-schedule prefetch
    arrays (used to verify the rebuild bit-for-bit), and the plan's
    ``IOReport`` — written through ``repro.checkpoint``'s atomic manifest
    machinery, so a crash mid-write never corrupts an entry;
  * a hit calls ``Engine.compile_with_order``: zero annealer iterations,
    no I/O re-simulation, outputs bit-identical to the cold compile the
    order came from.  A stored entry whose arrays no longer match the
    rebuild (schedule-packing code drift) is discarded as a miss, so stale
    caches self-heal.

Backend and activation are deliberately NOT part of the key: the connection
order is backend-independent (all backends walk the same arrays) and the
activation only changes the epilogue, not the schedule — one annealed entry
serves every backend.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.checkpoint.store import (
    manifest_exists,
    read_manifest_dir,
    write_manifest_dir,
)
from repro.core.blocksparse import BlockFFNN, BSRLayer
from repro.kernels.ops import resolve_weight_dtype
from repro.engine import (
    Engine,
    ExecutionPlan,
    IOReport,
    Mesh,
    ShardedExecutionPlan,
    ShardedIOReport,
)

FORMAT_VERSION = 1


def _layers_of(net: Union[BlockFFNN, Sequence[BSRLayer]]):
    return net.layers if isinstance(net, BlockFFNN) else list(net)


def layers_fingerprint(net: Union[BlockFFNN, Sequence[BSRLayer]]) -> str:
    """sha256 over every layer's structure AND weights.

    The schedule only depends on the block *pattern*, but keying on weights
    too means a repruned or retrained network can never silently serve a
    stale schedule-with-matching-shape.
    """
    h = hashlib.sha256()
    for lay in _layers_of(net):
        h.update(json.dumps([lay.n_in, lay.n_out, lay.block_m, lay.block_n,
                             lay.nnz_blocks]).encode())
        h.update(np.ascontiguousarray(lay.rows, dtype=np.int32).tobytes())
        h.update(np.ascontiguousarray(lay.cols, dtype=np.int32).tobytes())
        h.update(np.ascontiguousarray(lay.blocks).tobytes())
        h.update(np.ascontiguousarray(lay.bias).tobytes())
    return h.hexdigest()


def plan_cache_key(engine: Engine,
                   net: Union[BlockFFNN, Sequence[BSRLayer]],
                   mesh: Optional[Mesh] = None) -> str:
    """Content-addressed key: layer hash + schedule-affecting settings.

    The mesh topology is part of the key — a sharded plan's per-shard
    orders are meaningless under any other partition, so changing the mesh
    shape (including sharded vs unsharded) must be a miss.  ``mesh`` /
    ``max_move_span`` / ``gate`` / ``weight_dtype`` enter the dict only
    when set (non-default), so entries written by earlier store versions
    stay warm.  A quantized plan's entry stores narrow blocks + scales, so
    f32 and quantized plans of the same net must never alias.
    """
    settings = {
        "format": FORMAT_VERSION,
        "layers": layers_fingerprint(net),
        "reorder": bool(engine.reorder),
        "M_tiles": int(engine.M_tiles),
        "reorder_iters": int(engine.reorder_iters),
        "seed": int(engine.seed),
        "policy": engine.policy,
        "fuse": bool(engine.fuse),
    }
    if getattr(engine, "max_move_span", None):
        settings["max_move_span"] = int(engine.max_move_span)
    if getattr(engine, "gate", False):
        # gated and ungated plans must never alias (their lowered forwards
        # differ even though the schedule arrays are identical)
        settings["gate"] = True
    wdt = resolve_weight_dtype(getattr(engine, "weight_dtype", "f32"))
    if wdt != "f32":
        settings["weight_dtype"] = wdt
    if mesh is not None:
        settings["mesh"] = [int(mesh.model), int(mesh.data)]
    return hashlib.sha256(
        json.dumps(settings, sort_keys=True).encode()).hexdigest()


class PlanStore:
    """Directory of plan artifacts keyed by :func:`plan_cache_key`.

    ``fault_injector`` (a :class:`repro.serving.resilience.FaultInjector`)
    fires the ``store.load`` chaos site inside the read path.  An entry
    that raises on load or fails its self-heal verify is moved to
    ``<root>/quarantine/`` (counted in ``self.quarantined``) and treated
    as a miss — the bad bytes are preserved for inspection but can never
    be retried in a loop, because the recompile overwrites the live slot.
    """

    def __init__(self, root: str, fault_injector=None, tracer=None):
        from repro.obs.trace import NULL_TRACER
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.injector = fault_injector
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.quarantined = 0
        # per-key in-process compile locks: two threads warm-starting the
        # same network (e.g. concurrent SparseServer.swap calls) serialize
        # on the key, so the loser hits the entry the winner just wrote
        # instead of paying the annealing a second time
        self._locks_mu = threading.Lock()
        self._key_locks: dict = {}

    def _key_lock(self, key: str) -> threading.Lock:
        with self._locks_mu:
            lock = self._key_locks.get(key)
            if lock is None:
                lock = self._key_locks[key] = threading.Lock()
            return lock

    def path_for(self, key: str) -> str:
        return os.path.join(self.root, f"plan_{key}")

    def contains(self, engine: Engine,
                 net: Union[BlockFFNN, Sequence[BSRLayer]],
                 mesh: Optional[Mesh] = None) -> bool:
        return manifest_exists(
            self.path_for(plan_cache_key(engine, net, mesh)))

    def evict(self, engine: Engine,
              net: Union[BlockFFNN, Sequence[BSRLayer]],
              mesh: Optional[Mesh] = None) -> bool:
        """Remove the entry for this (engine, net, mesh), if any.  Returns
        True when something was removed (used e.g. by the benchmark to force
        a genuinely cold start against a reused store directory)."""
        path = self.path_for(plan_cache_key(engine, net, mesh))
        if os.path.isdir(path):
            import shutil
            shutil.rmtree(path, ignore_errors=True)
            return True
        return False

    def keys(self):
        if not os.path.isdir(self.root):
            return []
        return sorted(n[len("plan_"):] for n in os.listdir(self.root)
                      if n.startswith("plan_")
                      and manifest_exists(os.path.join(self.root, n)))

    # ------------------------------------------------------------------ #
    def _quarantine(self, path: str, reason: str) -> None:
        """Move a bad entry out of the live store into ``quarantine/``.

        Deleting it outright would lose the evidence; leaving it in place
        would re-fail every load until someone recompiles.  Quarantine does
        neither: the live slot is freed (the next ``get_or_compile``
        recompiles and writes a fresh entry) and the bad bytes are kept —
        suffixed ``.1``, ``.2``, … if the same key lands here repeatedly.
        """
        import shutil
        qdir = os.path.join(self.root, "quarantine")
        os.makedirs(qdir, exist_ok=True)
        dest = os.path.join(qdir, os.path.basename(path))
        n = 0
        while os.path.exists(dest):
            n += 1
            dest = os.path.join(qdir, f"{os.path.basename(path)}.{n}")
        try:
            os.replace(path, dest)
            with open(os.path.join(dest, "QUARANTINE_REASON.txt"),
                      "w") as fh:
                fh.write(reason + "\n")
        except OSError:
            # cross-device move or a racing writer: freeing the live slot
            # is the part that matters
            shutil.rmtree(path, ignore_errors=True)
        self.quarantined += 1
        if self.tracer.enabled:
            self.tracer.event("store.quarantine",
                              entry=os.path.basename(path), reason=reason)

    def _clean_partial(self, path: str) -> None:
        """Remove wreckage a crashed writer left behind: a ``.tmp`` staging
        dir, or a final dir with no manifest.  Either way the entry never
        became valid — a miss, not an error."""
        import shutil
        tmp = path + ".tmp"
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
        if os.path.isdir(path) and not manifest_exists(path):
            shutil.rmtree(path, ignore_errors=True)

    # ------------------------------------------------------------------ #
    def put(self, engine: Engine,
            plan: Union[ExecutionPlan, ShardedExecutionPlan]) -> str:
        """Persist a compiled plan's schedule artifact (atomic).

        A :class:`ShardedExecutionPlan` stores one connection order (plus
        flat-schedule verification arrays) per shard and the per-layer
        partition assignment, keyed on its mesh topology.
        """
        sharded = isinstance(plan, ShardedExecutionPlan)
        mesh = plan.mesh if sharded else None
        key = plan_cache_key(engine, plan.block_ffnn, mesh)
        extra = {
            "format": FORMAT_VERSION,
            "key": key,
            "n_layers": len(plan.shards[0].layers) if sharded
            else len(plan.layers),
            "io": (plan.io_report() if sharded else plan.io).to_dict(),
            "compile_s": plan.compile_s,
            "annealer_iters": plan.annealer_iters,
        }
        if sharded:
            extra["mesh"] = [int(mesh.model), int(mesh.data)]
            extra["n_shards"] = len(plan.shards)
        else:
            extra["fused"] = plan.fused
        return write_manifest_dir(self.path_for(key), plan.artifact_arrays(),
                                  extra)

    def load(
        self,
        engine: Engine,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        backend: Optional[str] = None,
        verify: bool = True,
        mesh: Optional[Mesh] = None,
    ) -> Optional[Union[ExecutionPlan, ShardedExecutionPlan]]:
        """Rebuild a plan from a stored artifact, or None on miss.

        ``verify`` additionally checks that the flat-schedule arrays
        rebuilt from the stored order are bit-identical to the stored
        ones; a mismatch (artifact written by incompatible packing code)
        is treated as a miss.  With ``mesh``, the per-shard orders are
        rebuilt through ``Engine.compile_sharded_with_orders`` (zero
        annealer iterations per shard) and every shard — plus the stored
        partition assignment — is verified.
        """
        key = plan_cache_key(engine, net, mesh)
        path = self.path_for(key)
        if not manifest_exists(path):
            # a crashed writer may have left a .tmp staging dir or a
            # manifest-less final dir — clean the wreckage so the slot is a
            # plain (recompilable) miss, never an error
            self._clean_partial(path)
            return None
        try:
            if self.injector is not None:
                self.injector.fire("store.load")
            arrays, extra = read_manifest_dir(path)
            if extra.get("format") != FORMAT_VERSION:
                # not corrupt — written by a different store version; leave
                # it alone (an older process may still be serving from it)
                return None
            if mesh is None:
                io = IOReport.from_dict(extra["io"])
            else:
                if extra.get("mesh") != [int(mesh.model), int(mesh.data)]:
                    return None
                n_shards = int(extra["n_shards"])
                sio = ShardedIOReport.from_dict(extra["io"])
                orders = [arrays[f"s{i}_order"] for i in range(n_shards)]
        except (OSError, KeyError, ValueError, TypeError) as e:
            # corrupt/unreadable entry (crc mismatch, mangled manifest,
            # wrong-typed metadata field): quarantine it — a miss that
            # recompiles into a fresh entry, never a load loop over the
            # same bad bytes — self-healing, not fatal
            self._quarantine(path, f"load raised {type(e).__name__}: {e}")
            return None
        if mesh is None:
            plan = engine.compile_with_order(net, arrays["order"], backend,
                                             io=io)
            if verify and not self._matches(plan, arrays):
                self._quarantine(path, "self-heal verify failed: rebuilt "
                                       "flat schedule != stored arrays")
                return None
            return plan
        if len(sio.per_shard) != n_shards:
            self._quarantine(path, "self-heal verify failed: stored shard "
                                   "count != per-shard reports")
            return None
        plan = engine.compile_sharded_with_orders(
            net, mesh, orders, backend, ios=list(sio.per_shard))
        if verify and not self._matches_sharded(plan, arrays):
            self._quarantine(path, "self-heal verify failed: rebuilt shard "
                                   "arrays != stored arrays")
            return None
        return plan

    @staticmethod
    def _matches(plan: ExecutionPlan, arrays: dict) -> bool:
        stored_fused = any(k.startswith("flat_") for k in arrays)
        if plan.fused != stored_fused:
            return False
        if plan.flat is None:
            return True
        for name in ("rows", "cols", "first", "last", "layer_id",
                     "hbm_row", "out_tile", "bias_idx"):
            if not np.array_equal(np.asarray(getattr(plan.flat, name)),
                                  arrays[f"flat_{name}"]):
                return False
        if plan.flat.scales is not None:
            # quantized entries also verify the stored narrow blocks +
            # scales byte-for-byte against the deterministic requantization
            # (bytes, not values: narrow floats have NaN patterns
            # np.array_equal would mis-judge)
            for name, rebuilt in (("flat_qblocks", plan.flat.blocks),
                                  ("flat_scales", plan.flat.scales)):
                stored = arrays.get(name)
                rebuilt = np.asarray(rebuilt)
                if (stored is None or stored.dtype != rebuilt.dtype
                        or stored.shape != rebuilt.shape
                        or stored.tobytes() != rebuilt.tobytes()):
                    return False
        return True

    @classmethod
    def _matches_sharded(cls, plan: ShardedExecutionPlan,
                         arrays: dict) -> bool:
        """Every shard's rebuilt arrays — and the partition itself — must
        match the stored artifact bit-for-bit; any drift is a miss."""
        stored = plan.artifact_arrays()
        for k in range(plan.n_layers):
            name = f"assign_l{k}"
            if name not in arrays or \
                    not np.array_equal(arrays[name], stored[name]):
                return False
        for s, shard in enumerate(plan.shards):
            sub = {name[len(f"s{s}_"):]: arr for name, arr in arrays.items()
                   if name.startswith(f"s{s}_")}
            if not sub or not cls._matches(shard, sub):
                return False
        return True

    def get_or_compile(
        self,
        engine: Engine,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        backend: Optional[str] = None,
        mesh: Optional[Mesh] = None,
    ) -> Tuple[Union[ExecutionPlan, ShardedExecutionPlan], bool]:
        """Warm-start compile: ``(plan, hit)``.

        Hit: rebuilt from the stored order(s), zero annealer iterations.
        Miss: full ``Engine.compile`` (schedule + CR — per shard when a
        ``mesh`` is given), then persisted so the next process is warm.

        Thread-safe: concurrent callers with the same key serialize on a
        per-key lock, so at most one of them pays the compile.
        """
        key = plan_cache_key(engine, net, mesh)
        with self._key_lock(key):
            with self.tracer.span("store.load", key=key[:12]) as sp:
                plan = self.load(engine, net, backend, mesh=mesh)
                sp["hit"] = plan is not None
            if plan is not None:
                return plan, True
            with self.tracer.span("store.compile", key=key[:12]):
                plan = engine.compile(net, backend, mesh=mesh)
                self.put(engine, plan)
            return plan, False
