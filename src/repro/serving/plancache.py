"""Persistent, content-addressed store for compiled execution plans.

The paper's amortization argument says the offline cost — Theorem-1
scheduling plus Connection Reordering — is paid once and served from
forever.  Without persistence "once" really means "once per process":
every server restart re-annealed the same network.  ``PlanStore`` closes
that gap:

  * the cache key is a sha256 over the *content* of the network (each
    layer's block pattern, weights, bias, tile shape) plus every engine
    setting that affects the schedule arrays (``reorder``, ``M_tiles``,
    ``reorder_iters``, ``seed``, ``policy``, ``fuse``) and the artifact
    format version — object identity never matters, so any process that
    builds the same pruned network hits the same entry;
  * the stored artifact is the whole-DAG connection ``order`` (everything
    else re-derives from it deterministically), the flat-schedule prefetch
    arrays (used to verify the rebuild bit-for-bit), and the plan's
    ``IOReport`` — written through ``repro.checkpoint``'s atomic manifest
    machinery, so a crash mid-write never corrupts an entry;
  * a hit calls ``Engine.compile_with_order``: zero annealer iterations,
    no I/O re-simulation, outputs bit-identical to the cold compile the
    order came from.  A stored entry whose arrays no longer match the
    rebuild (schedule-packing code drift) is discarded as a miss, so stale
    caches self-heal.

Backend and activation are deliberately NOT part of the key: the connection
order is backend-independent (all backends walk the same arrays) and the
activation only changes the epilogue, not the schedule — one annealed entry
serves every backend.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.checkpoint.store import (
    manifest_exists,
    read_manifest_dir,
    write_manifest_dir,
)
from repro.core.blocksparse import BlockFFNN, BSRLayer
from repro.engine import Engine, ExecutionPlan, IOReport

FORMAT_VERSION = 1


def _layers_of(net: Union[BlockFFNN, Sequence[BSRLayer]]):
    return net.layers if isinstance(net, BlockFFNN) else list(net)


def layers_fingerprint(net: Union[BlockFFNN, Sequence[BSRLayer]]) -> str:
    """sha256 over every layer's structure AND weights.

    The schedule only depends on the block *pattern*, but keying on weights
    too means a repruned or retrained network can never silently serve a
    stale schedule-with-matching-shape.
    """
    h = hashlib.sha256()
    for lay in _layers_of(net):
        h.update(json.dumps([lay.n_in, lay.n_out, lay.block_m, lay.block_n,
                             lay.nnz_blocks]).encode())
        h.update(np.ascontiguousarray(lay.rows, dtype=np.int32).tobytes())
        h.update(np.ascontiguousarray(lay.cols, dtype=np.int32).tobytes())
        h.update(np.ascontiguousarray(lay.blocks).tobytes())
        h.update(np.ascontiguousarray(lay.bias).tobytes())
    return h.hexdigest()


def plan_cache_key(engine: Engine,
                   net: Union[BlockFFNN, Sequence[BSRLayer]]) -> str:
    """Content-addressed key: layer hash + schedule-affecting settings."""
    settings = {
        "format": FORMAT_VERSION,
        "layers": layers_fingerprint(net),
        "reorder": bool(engine.reorder),
        "M_tiles": int(engine.M_tiles),
        "reorder_iters": int(engine.reorder_iters),
        "seed": int(engine.seed),
        "policy": engine.policy,
        "fuse": bool(engine.fuse),
    }
    return hashlib.sha256(
        json.dumps(settings, sort_keys=True).encode()).hexdigest()


class PlanStore:
    """Directory of plan artifacts keyed by :func:`plan_cache_key`."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def path_for(self, key: str) -> str:
        return os.path.join(self.root, f"plan_{key}")

    def contains(self, engine: Engine,
                 net: Union[BlockFFNN, Sequence[BSRLayer]]) -> bool:
        return manifest_exists(self.path_for(plan_cache_key(engine, net)))

    def evict(self, engine: Engine,
              net: Union[BlockFFNN, Sequence[BSRLayer]]) -> bool:
        """Remove the entry for this (engine, net), if any.  Returns True
        when something was removed (used e.g. by the benchmark to force a
        genuinely cold start against a reused store directory)."""
        path = self.path_for(plan_cache_key(engine, net))
        if os.path.isdir(path):
            import shutil
            shutil.rmtree(path, ignore_errors=True)
            return True
        return False

    def keys(self):
        if not os.path.isdir(self.root):
            return []
        return sorted(n[len("plan_"):] for n in os.listdir(self.root)
                      if n.startswith("plan_")
                      and manifest_exists(os.path.join(self.root, n)))

    # ------------------------------------------------------------------ #
    def put(self, engine: Engine, plan: ExecutionPlan) -> str:
        """Persist a compiled plan's schedule artifact (atomic)."""
        key = plan_cache_key(engine, plan.block_ffnn)
        extra = {
            "format": FORMAT_VERSION,
            "key": key,
            "n_layers": len(plan.layers),
            "fused": plan.fused,
            "io": plan.io.to_dict(),
            "compile_s": plan.compile_s,
            "annealer_iters": plan.annealer_iters,
        }
        return write_manifest_dir(self.path_for(key), plan.artifact_arrays(),
                                  extra)

    def load(
        self,
        engine: Engine,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        backend: Optional[str] = None,
        verify: bool = True,
    ) -> Optional[ExecutionPlan]:
        """Rebuild a plan from a stored artifact, or None on miss.

        ``verify`` additionally checks that the flat-schedule arrays
        rebuilt from the stored order are bit-identical to the stored
        ones; a mismatch (artifact written by incompatible packing code)
        is treated as a miss.
        """
        key = plan_cache_key(engine, net)
        path = self.path_for(key)
        if not manifest_exists(path):
            return None
        try:
            arrays, extra = read_manifest_dir(path)
            if extra.get("format") != FORMAT_VERSION:
                return None
            io = IOReport.from_dict(extra["io"])
        except (OSError, KeyError, ValueError):
            # corrupt/unreadable entry (crc mismatch, mangled manifest):
            # a miss recompiles and overwrites it — self-healing, not fatal
            return None
        plan = engine.compile_with_order(net, arrays["order"], backend, io=io)
        if verify and not self._matches(plan, arrays):
            return None
        return plan

    @staticmethod
    def _matches(plan: ExecutionPlan, arrays: dict) -> bool:
        stored_fused = any(k.startswith("flat_") for k in arrays)
        if plan.fused != stored_fused:
            return False
        if plan.flat is None:
            return True
        for name in ("rows", "cols", "first", "last", "layer_id",
                     "hbm_row", "out_tile", "bias_idx"):
            if not np.array_equal(np.asarray(getattr(plan.flat, name)),
                                  arrays[f"flat_{name}"]):
                return False
        return True

    def get_or_compile(
        self,
        engine: Engine,
        net: Union[BlockFFNN, Sequence[BSRLayer]],
        backend: Optional[str] = None,
    ) -> Tuple[ExecutionPlan, bool]:
        """Warm-start compile: ``(plan, hit)``.

        Hit: rebuilt from the stored order, zero annealer iterations.
        Miss: full ``Engine.compile`` (schedule + CR), then persisted so
        the next process is warm.
        """
        plan = self.load(engine, net, backend)
        if plan is not None:
            return plan, True
        plan = engine.compile(net, backend)
        self.put(engine, plan)
        return plan, False
