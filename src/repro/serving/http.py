"""HTTP ingress for the serving pipeline: a stdlib JSON front door.

:class:`HttpFrontDoor` is the pipeline's INGRESS stage (see
docs/serving.md "Pipeline architecture"): a ``ThreadingHTTPServer`` — the
same no-new-dependencies pattern as ``repro.obs.prom.MetricsServer`` —
that turns HTTP requests into ``SparseServer.submit`` calls and maps the
server's admission decisions onto HTTP backpressure:

================================  =====================================
server outcome                    HTTP response
================================  =====================================
admitted + served                 ``200`` with the output row
admitted, ``wait=false``          ``202`` with the request id (poll via
                                  ``GET /v1/result/<rid>``)
queue full (admission control)    ``429`` + ``Retry-After`` — back off,
                                  the queue is the SLO guard
server shut down                  ``503`` (permanent for this process)
deadline-evicted / failed batch   ``503`` (the request was consumed but
                                  could not be served in time)
wait timed out (still in flight)  ``504`` (result may still be
                                  collectable by rid later)
bad JSON / wrong input shape      ``400`` — rejected in the ingress
                                  thread, never reaches formation
unknown model                     ``404``
================================  =====================================

Endpoints:

* ``POST /v1/infer`` — body ``{"x": [...], "model": "name",
  "deadline_ms": 50, "wait": true, "wait_ms": 1000}`` (only ``x`` is
  required; ``model`` defaults to a single-server target's model).
* ``GET  /v1/result/<rid>?model=name`` — poll/collect an async result.
* ``GET  /v1/models`` — served model names.
* ``GET  /healthz`` — liveness (503 once shut down).

The front door holds no queue of its own: every connection thread calls
straight into ``submit_ex`` (bounded by the server's ``max_queue``) and,
for synchronous requests, blocks in ``wait(rid)`` — concurrency is
bounded by ``ThreadingHTTPServer``'s per-connection threads, admission by
the server's own backpressure.  It works identically over a
:class:`~repro.serving.server.SparseServer` or a
:class:`~repro.serving.server.ModelRouter`.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["HttpFrontDoor"]

#: Retry-After seconds suggested on a 429 (one idle tick: by then the
#: scheduler has had a chance to fire at least one batch)
_RETRY_AFTER_S = 0.1


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-serve/1.0"

    # ------------------------------------------------------------------ #
    def do_POST(self) -> None:  # noqa: N802  (http.server API)
        front: "HttpFrontDoor" = self.server.front  # type: ignore[attr-defined]
        if self.path.split("?", 1)[0] != "/v1/infer":
            self._reply(404, {"error": "not_found"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(length) or b"{}")
        except (ValueError, TypeError):
            self._reply(400, {"error": "bad_json"})
            return
        code, payload, headers = front.infer(body)
        self._reply(code, payload, headers)

    def do_GET(self) -> None:  # noqa: N802
        front: "HttpFrontDoor" = self.server.front  # type: ignore[attr-defined]
        path, _, query = self.path.partition("?")
        if path == "/healthz":
            if front.closed:
                self._reply(503, {"status": "shutting_down"})
            else:
                self._reply(200, {"status": "ok"})
            return
        if path == "/v1/models":
            self._reply(200, {"models": front.model_names()})
            return
        if path.startswith("/v1/result/"):
            params = dict(p.split("=", 1) for p in query.split("&") if "=" in p)
            try:
                rid = int(path[len("/v1/result/"):])
            except ValueError:
                self._reply(400, {"error": "bad_rid"})
                return
            code, payload = front.collect(rid, params.get("model"))
            self._reply(code, payload)
            return
        self._reply(404, {"error": "not_found"})

    # ------------------------------------------------------------------ #
    def _reply(self, code: int, obj: dict,
               headers: Optional[Dict[str, str]] = None) -> None:
        data = json.dumps(obj).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def log_message(self, fmt, *args) -> None:   # quiet by default
        pass


class HttpFrontDoor:
    """Background HTTP ingress over a ``SparseServer`` or ``ModelRouter``.

    Args:
      target: the server or router requests are submitted to (it should
        already be ``start()``-ed; the front door only does admission and
        collection).
      port: TCP port; ``0`` binds an ephemeral port (read ``.port``).
      host: bind address, loopback by default.
      default_wait_ms: how long a synchronous ``POST /v1/infer`` blocks
        for its result before answering 504.  Default: 40x the target's
        SLO — generous enough that a healthy server never trips it.
    """

    def __init__(self, target, port: int = 0, host: str = "127.0.0.1",
                 default_wait_ms: Optional[float] = None):
        self.target = target
        self._is_router = hasattr(target, "servers")
        if default_wait_ms is None:
            slo_s = (max(s.slo_s for s in target.servers.values())
                     if self._is_router else target.slo_s)
            default_wait_ms = 40.0 * slo_s * 1e3
        self.default_wait_ms = default_wait_ms
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.front = self                    # type: ignore[attr-defined]
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None
        self.closed = False

    # ------------------------------------------------------------------ #
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def model_names(self) -> list:
        if self._is_router:
            return sorted(self.target.servers)
        return [self.target.name]

    def _server(self, model: Optional[str]):
        """The SparseServer a request routes to, or None for a 404."""
        if self._is_router:
            if model is None and len(self.target.servers) == 1:
                return next(iter(self.target.servers.values()))
            return self.target.servers.get(model)
        if model is not None and model != self.target.name:
            return None
        return self.target

    # ------------------------------------------------------------------ #
    def infer(self, body: dict
              ) -> Tuple[int, dict, Optional[Dict[str, str]]]:
        """Admission + (optionally) synchronous collection for one POST.
        Returns ``(status, payload, extra_headers)``."""
        if self.closed:
            return 503, {"error": "closed"}, None
        model = body.get("model")
        server = self._server(model)
        if server is None:
            return 404, {"error": "unknown_model", "model": model}, None
        try:
            x = np.asarray(body["x"], dtype=server.plans.dtype)
        except (KeyError, TypeError, ValueError):
            return 400, {"error": "bad_input"}, None
        deadline_ms = body.get("deadline_ms")
        try:
            rid, reason = server.submit_ex(x, deadline_ms=deadline_ms)
        except ValueError as e:              # wrong shape — ingress-thread
            return 400, {"error": "bad_input", "detail": str(e)}, None
        if rid is None:
            if reason == "queue_full":
                return (429, {"error": "queue_full"},
                        {"Retry-After": str(_RETRY_AFTER_S)})
            return 503, {"error": reason or "rejected"}, None
        if not body.get("wait", True):
            return 202, {"rid": rid, "model": server.name}, None
        wait_ms = body.get("wait_ms", self.default_wait_ms)
        y = server.wait(rid, timeout=wait_ms / 1e3)
        if y is not None:
            return (200, {"rid": rid, "model": server.name,
                          "y": np.asarray(y).tolist()}, None)
        # None from wait(): either the slot completed as None (failed
        # batch / deadline eviction — the request is consumed and will
        # never be served) or the wait timed out (still in flight)
        if server.status(rid) == "pending":
            return 504, {"rid": rid, "error": "timeout"}, None
        return 503, {"rid": rid, "error": "failed_or_evicted"}, None

    def collect(self, rid: int, model: Optional[str]) -> Tuple[int, dict]:
        """Poll path for ``wait=false`` submissions."""
        server = self._server(model)
        if server is None:
            return 404, {"error": "unknown_model", "model": model}
        status = server.status(rid)
        if status == "pending":
            return 202, {"rid": rid, "status": "pending"}
        y = server.result(rid)
        if y is None:
            # completed-as-None (failed/evicted) or unknown rid
            if status == "done":
                return 503, {"rid": rid, "error": "failed_or_evicted"}
            return 404, {"rid": rid, "error": "unknown_rid"}
        return 200, {"rid": rid, "y": np.asarray(y).tolist()}

    # ------------------------------------------------------------------ #
    def start(self) -> "HttpFrontDoor":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="serve-http", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Stop accepting connections (the serving target is NOT shut
        down — that stays the caller's decision)."""
        self.closed = True
        if self._thread is None:
            return
        self._httpd.shutdown()
        self._thread.join(timeout=timeout)
        self._httpd.server_close()
        self._thread = None

    def __enter__(self) -> "HttpFrontDoor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
