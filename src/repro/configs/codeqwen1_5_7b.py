"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B; hf]

32L d_model=4096 32H (GQA kv=32 — MHA) d_ff=13440 vocab=92416, qwen1.5 arch.
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    activation="swiglu",
    microbatch=4,
))
