"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32 experts top-8.
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    activation="swiglu",
    n_experts=32,
    top_k=8,
    moe_impl="a2a",
    tie_embeddings=True,
    microbatch=4,
))
