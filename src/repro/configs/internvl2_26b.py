"""internvl2-26b [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
Per the assignment the ViT frontend is a STUB: input_specs provides
precomputed patch embeddings [B, S, d] for train/prefill; decode uses the
text embedding table.
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="dense",
    modality="vision_stub",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    activation="swiglu",
    microbatch=16,
))
