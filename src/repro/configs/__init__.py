"""Architecture registry: one module per assigned architecture.

Importing this package registers all configs; ``reduced(cfg)`` derives the
small same-family variant used by the CPU smoke tests (the full configs are
exercised only via the dry-run, shape-only)."""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, get_config, list_configs, register

from . import (  # noqa: F401  (registration side effects)
    granite_moe_1b_a400m,
    deepseek_moe_16b,
    nemotron_4_15b,
    stablelm_12b,
    minitron_4b,
    codeqwen1_5_7b,
    internvl2_26b,
    seamless_m4t_medium,
    mamba2_1_3b,
    zamba2_1_2b,
    bert_ffnn,
)

ARCH_IDS = [
    "granite-moe-1b-a400m",
    "deepseek-moe-16b",
    "nemotron-4-15b",
    "stablelm-12b",
    "minitron-4b",
    "codeqwen1.5-7b",
    "internvl2-26b",
    "seamless-m4t-medium",
    "mamba2-1.3b",
    "zamba2-1.2b",
]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for one-forward/one-train-step CPU smoke tests."""
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, 2))
    if heads % kv:
        kv = 1
    changes = dict(
        name=cfg.name + "-reduced",
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=max(64, min(cfg.d_ff, 128)),
        vocab=256,
        microbatch=1,
        attn_chunk=16,
        remat=False,
    )
    if cfg.family == "moe":
        changes.update(n_experts=4, top_k=2,
                       n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        changes.update(n_layers=3, attn_period=2)
    if cfg.family == "encdec":
        changes.update(n_enc_layers=2, n_dec_layers=2)
    return dataclasses.replace(cfg, **changes)


__all__ = ["ARCH_IDS", "ModelConfig", "get_config", "list_configs", "reduced",
           "register"]
