"""mamba2-1.3b [arXiv:2405.21060; unverified] — SSD (state-space duality).

48L d_model=2048 (attention-free), ssm_state=128, head_dim=64, expand=2.
Runs the long_500k shape (sub-quadratic by construction).
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    d_ff=0,
    n_heads=0,
    n_kv_heads=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
    microbatch=4,
))
