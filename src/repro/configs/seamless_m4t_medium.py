"""seamless-m4t-medium [arXiv:2308.11596; hf] — encoder-decoder, multimodal.

12L (encoder) + 12L (decoder) d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206.  Speech frontend is a STUB: input_specs provides precomputed
frame embeddings for the encoder; target length = seq_len // tgt_frac.
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    modality="audio_stub",
    n_layers=24,
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    activation="gelu",
    tgt_frac=4,
))
