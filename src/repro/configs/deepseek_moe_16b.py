"""deepseek-moe-16b [arXiv:2401.06066; hf]

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE: 2 shared + 64 routed experts, top-6 (fine-grained experts).
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    activation="swiglu",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_impl="a2a",
    microbatch=2,
))
