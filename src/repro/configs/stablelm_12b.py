"""stablelm-12b [hf:stabilityai/stablelm-2-12b family; hf]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    activation="swiglu",
    microbatch=8,
))
