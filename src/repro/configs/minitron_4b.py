"""minitron-4b — pruned nemotron [arXiv:2407.14679; hf]

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
The *pruned* provenance makes this the closest assigned arch to the paper's
own regime (sparsified dense layers).
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    activation="squared_relu",
    microbatch=4,
))
