"""The paper's own experimental target: a BERT-large encoder FFNN.

Depth-2 MLP with weight matrices 1024x4096 and 4096x1024 (paper VI.A.5/VI.B.2),
magnitude-pruned at varying densities.  Used by benchmarks (fig6/fig8) and the
serving example; not part of the assigned 10-arch dry-run grid.
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="bert-ffnn",
    family="dense",
    n_layers=2,
    d_model=1024,
    d_ff=4096,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    vocab=30522,
    activation="gelu",
))
