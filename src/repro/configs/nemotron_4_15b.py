"""nemotron-4-15b [arXiv:2402.16819; unverified]

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU MLP.
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    activation="squared_relu",
    microbatch=16,
))
