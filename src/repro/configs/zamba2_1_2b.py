"""zamba2-1.2b [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
One shared attention+MLP block applied every `attn_period` Mamba2 layers
(38 = 6 groups of 6 + 2 tail layers).  Runs long_500k (hybrid family).
"""

from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    activation="swiglu",
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_period=6,
    microbatch=4,
))
