"""Sparse FFNN substrate: scheduled execution of block-sparse MLP stacks."""

from .layers import ScheduledSparseFFNN, prune_dense_stack

__all__ = ["ScheduledSparseFFNN", "prune_dense_stack"]
