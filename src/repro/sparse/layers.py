"""Scheduled sparse FFNN execution: the paper's pipeline end to end.

prune -> BSR -> block DAG -> Theorem-1 schedule -> (optional) Connection
Reordering -> fused execution plan.

``ScheduledSparseFFNN`` is the legacy-shaped wrapper kept for existing call
sites and tests; since the engine refactor it is a thin veneer over
``repro.engine.Engine`` — the schedule is compiled once for the whole network
and every call runs the fused plan instead of dispatching layer by layer.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocksparse import (
    BlockFFNN,
    BSRLayer,
    simulated_tile_traffic,
    to_bsr,
)
from repro.engine import Engine, ExecutionPlan
from repro.kernels.ops import CompiledSchedule


def prune_dense_stack(
    weights: Sequence[np.ndarray],
    biases: Sequence[np.ndarray],
    density: float,
    block_m: int = 128,
    block_n: int = 128,
) -> List[BSRLayer]:
    """Block-magnitude-prune a stack of dense layers to ``density``."""
    return [
        to_bsr(w, block_m, block_n, density=density, bias=b)
        for w, b in zip(weights, biases)
    ]


@dataclasses.dataclass
class ScheduledSparseFFNN:
    """Multi-layer block-sparse FFNN with a paper-optimized execution schedule."""

    layers: List[BSRLayer]
    schedules: List[CompiledSchedule]
    block_ffnn: BlockFFNN
    order: np.ndarray          # block-DAG connection order in effect
    activation: Callable = jax.nn.relu
    plan: ExecutionPlan = None
    engine: Engine = None

    @classmethod
    def build(
        cls,
        layers: Sequence[BSRLayer],
        activation: Callable = jax.nn.relu,
        reorder: bool = False,
        M_tiles: int = 3,
        reorder_iters: int = 2000,
        seed: int = 0,
        backend: str = "auto",
        fuse: bool = True,
    ) -> "ScheduledSparseFFNN":
        """Compile with the Theorem-1 schedule; optionally improve it with CR.

        ``M_tiles`` is the VMEM budget in tiles used as the CR objective
        (M=3 matches the kernel's single-resident-tile residency model).
        CR proposals that break the contiguous-by-output contract are unusable
        by the kernel, so the engine re-groups the CR result by output tile,
        keeping CR's improved *input-tile locality* within each group.

        With ``fuse=True`` (default) the whole net lowers to ONE flat
        cross-layer dispatch — the Pallas megakernel on TPU backends, with
        hidden states VMEM-resident across layer boundaries.
        """
        engine = Engine(
            backend=backend, activation=activation, final_activation=None,
            reorder=reorder, M_tiles=M_tiles, reorder_iters=reorder_iters,
            seed=seed, fuse=fuse,
        )
        plan = engine.compile(list(layers))
        return cls(
            layers=plan.layers, schedules=plan.schedules,
            block_ffnn=plan.block_ffnn, order=plan.order,
            activation=activation, plan=plan, engine=engine,
        )

    @property
    def fused(self) -> bool:
        """True when the compiled plan runs as one flat cross-layer dispatch."""
        return self.plan is not None and self.plan.fused

    def __call__(self, x: jnp.ndarray, interpret: Optional[bool] = None) -> jnp.ndarray:
        """Run the fused plan.  ``interpret`` forces the Pallas interpret-mode
        backend (True) or the compiled Pallas kernel (False); None keeps the
        engine's resolved backend.

        Instances constructed directly from the dataclass fields (the
        pre-engine API) have no plan; they fall back to per-layer dispatch
        with the stored schedules, exactly the old behavior."""
        if self.plan is None:
            from repro.kernels.ops import scheduled_bsr_layer

            h = x
            for k, (lay, sch) in enumerate(zip(self.layers, self.schedules)):
                act = self.activation if k < len(self.layers) - 1 else None
                h = scheduled_bsr_layer(h, lay, sch, activation=act,
                                        interpret=interpret)
            return h
        if interpret is None:
            return self.plan(x)
        backend = "interpret" if interpret else "pallas"
        return self.engine.compile(self.block_ffnn, backend=backend)(x)

    def simulated_ios(self, M_tiles: int = 3, policy: str = "min"):
        """Exact simulated tile I/Os of the current order (paper's cost model)."""
        return simulated_tile_traffic(self.block_ffnn, self.order, M_tiles, policy)
