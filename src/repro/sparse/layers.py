"""Scheduled sparse FFNN execution: the paper's pipeline end to end.

prune -> BSR -> block DAG -> Theorem-1 schedule -> (optional) Connection
Reordering -> Pallas kernels per layer.

``ScheduledSparseFFNN`` is the inference module used by the serving example
and the fig7/8 runtime benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocksparse import (
    BlockFFNN,
    BSRLayer,
    schedule_arrays,
    simulated_tile_traffic,
    to_block_ffnn,
    to_bsr,
)
from repro.core.reorder import connection_reordering
from repro.kernels.ops import CompiledSchedule, compile_schedule, scheduled_bsr_layer


def prune_dense_stack(
    weights: Sequence[np.ndarray],
    biases: Sequence[np.ndarray],
    density: float,
    block_m: int = 128,
    block_n: int = 128,
) -> List[BSRLayer]:
    """Block-magnitude-prune a stack of dense layers to ``density``."""
    return [
        to_bsr(w, block_m, block_n, density=density, bias=b)
        for w, b in zip(weights, biases)
    ]


@dataclasses.dataclass
class ScheduledSparseFFNN:
    """Multi-layer block-sparse FFNN with a paper-optimized execution schedule."""

    layers: List[BSRLayer]
    schedules: List[CompiledSchedule]
    block_ffnn: BlockFFNN
    order: np.ndarray          # block-DAG connection order in effect
    activation: Callable = jax.nn.relu

    @classmethod
    def build(
        cls,
        layers: Sequence[BSRLayer],
        activation: Callable = jax.nn.relu,
        reorder: bool = False,
        M_tiles: int = 3,
        reorder_iters: int = 2000,
        seed: int = 0,
    ) -> "ScheduledSparseFFNN":
        """Build with the Theorem-1 schedule; optionally improve it with CR.

        ``M_tiles`` is the VMEM budget in tiles used as the CR objective
        (M=3 matches the kernel's single-resident-tile residency model).
        CR proposals that break the contiguous-by-output contract are unusable
        by the kernel, so we re-group the CR result by output tile, keeping
        CR's improved *input-tile locality* within each group.
        """
        bffnn = to_block_ffnn(list(layers))
        order = bffnn.net.theorem1_order()
        if reorder:
            res = connection_reordering(
                bffnn.net, order, M=M_tiles, T=reorder_iters, seed=seed,
            )
            order = _regroup_by_output(bffnn.net, res.order)
        schedules = []
        for k in range(len(layers)):
            perm, _, _, _, _ = schedule_arrays(bffnn, order, k)
            schedules.append(compile_schedule(layers[k], perm))
        return cls(
            layers=list(layers), schedules=schedules, block_ffnn=bffnn,
            order=order, activation=activation,
        )

    def __call__(self, x: jnp.ndarray, interpret: Optional[bool] = None) -> jnp.ndarray:
        h = x
        for k, (lay, sch) in enumerate(zip(self.layers, self.schedules)):
            act = self.activation if k < len(self.layers) - 1 else None
            h = scheduled_bsr_layer(h, lay, sch, activation=act, interpret=interpret)
        return h

    def simulated_ios(self, M_tiles: int = 3, policy: str = "min"):
        """Exact simulated tile I/Os of the current order (paper's cost model)."""
        return simulated_tile_traffic(self.block_ffnn, self.order, M_tiles, policy)


def _regroup_by_output(net, order: np.ndarray) -> np.ndarray:
    """Stable-regroup a connection order by output neuron, ranking groups by
    their *last* appearance; the internal order within groups is preserved
    (keeps CR's input-locality gains kernel-compatible).

    Ranking by last appearance keeps the result topological: for any edge
    B -> A, every B-incoming connection precedes the consuming connection in
    the input order, so last(B) < last(A) and group B lands wholly before
    group A — i.e. the group sequence is a topological order of the neurons,
    which is exactly the Theorem-1 family."""
    order = np.asarray(order)
    dst = net.dst[order]
    last_seen: dict = {}
    for idx, d in enumerate(dst):
        last_seen[int(d)] = idx
    group_rank = np.array([last_seen[int(d)] for d in dst])
    return order[np.argsort(group_rank, kind="stable")]
