from .adamw import OptConfig, adamw_init, adamw_update

__all__ = ["OptConfig", "adamw_init", "adamw_update"]
