"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Optimizer state leaves (master/mu/nu) carry an extra data-axis sharding when
divisible (ZeRO-1 style — see launch/partition.opt_specs), so the update step
reduce-scatters gradients and all-gathers fresh params under SPMD instead of
keeping 3 full fp32 copies per chip.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params) -> Dict[str, Any]:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {
        "master": f32(params),
        "mu": zeros(params),
        "nu": zeros(params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(step, cfg: OptConfig):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"]
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        m = m - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * m)
        return mu, nu, m

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    flat_m = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, mu, nu, m) for g, mu, nu, m in
           zip(flat_g, flat_mu, flat_nu, flat_m)]
    new_mu = treedef.unflatten([o[0] for o in out])
    new_nu = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    flat_p = treedef.flatten_up_to(params)
    new_params = treedef.unflatten(
        [m.astype(p.dtype) for m, p in
         zip([o[2] for o in out], flat_p)])
    new_state = {"master": new_master, "mu": new_mu, "nu": new_nu,
                 "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
