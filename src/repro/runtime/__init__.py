from .compression import (
    CompressionState,
    init_compression,
    topk_compress_with_feedback,
)
from .elastic import reshard_checkpoint
from .failure import ResilientTrainer, StragglerMonitor

__all__ = [
    "CompressionState", "init_compression", "topk_compress_with_feedback",
    "reshard_checkpoint", "ResilientTrainer", "StragglerMonitor",
]
