"""Compute/communication overlap: ring collective matmul (shard_map+ppermute).

``ring_ag_matmul`` computes y = all_gather(x) @ W with W column-sharded, as a
ring: each of the tp steps multiplies the currently-held x shard against the
local W panel while the next shard is in flight (XLA overlaps the
collective-permute with the dot on real hardware).  This replaces the
blocking all-gather + big matmul with tp pipelined chunks — the §Perf
optimization for collective-bound dense cells.

Semantics are exactly all_gather+matmul; tests assert equality.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size


def _ring_body(x_loc, w_loc, axis_name: str):
    """x_loc: [B, S/tp, D]; w_loc: [D, F/tp]  ->  y_loc: [B, S, F/tp]."""
    tp = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    B, s_loc, D = x_loc.shape
    F_loc = w_loc.shape[1]
    y = jnp.zeros((B, s_loc * tp, F_loc), x_loc.dtype)
    perm = [(i, (i + 1) % tp) for i in range(tp)]

    def step(c, i):
        buf, y = c
        # buf currently holds the shard that originated at rank (idx - i) mod tp
        src = (idx - i) % tp
        part = jnp.einsum("bsd,df->bsf", buf, w_loc)
        y = jax.lax.dynamic_update_slice(y, part, (0, src * s_loc, 0))
        buf = jax.lax.ppermute(buf, axis_name, perm)
        return (buf, y), None

    (buf, y), _ = jax.lax.scan(step, (x_loc, y), jnp.arange(tp))
    return y


def ring_ag_matmul(x, w, mesh, dp_spec, tp_axis: str = "model"):
    """y[B, S, F] = x[B, S, D] @ w[D, F] with x sequence-sharded over tp and
    w column-sharded; output column-sharded [B, S, F/tp]."""
    from repro.compat import shard_map

    fn = shard_map(
        functools.partial(_ring_body, axis_name=tp_axis),
        mesh=mesh,
        in_specs=(P(dp_spec, tp_axis, None), P(None, tp_axis)),
        out_specs=P(dp_spec, None, tp_axis),
    )
    return fn(x, w)
