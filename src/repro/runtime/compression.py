"""Distributed-optimization tricks: gradient compression + quantized reduce.

* ``topk_compress_with_feedback`` — per-leaf magnitude top-k sparsification
  with error feedback (Strom'15 / Aji-Heafield'17): the un-sent residual is
  accumulated locally and re-added next step, preserving convergence.
  At k=1% this cuts DP all-reduce bytes ~50x (values + indices).
* ``quantized_psum`` — int8 block-quantized all-reduce emulation: quantize to
  int8 with a per-block scale, sum, dequantize.  On the wire this is a 4x
  reduction vs f32; here we model the numerics exactly (the sum is computed
  on the quantized representatives) so tests can bound the quantization error.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CompressionState:
    error: Any   # pytree like grads — residual feedback


def init_compression(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params))


def _topk_mask(x: jnp.ndarray, k_frac: float) -> jnp.ndarray:
    n = x.size
    k = max(1, int(round(k_frac * n)))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def topk_compress_with_feedback(
    grads, state: CompressionState, k_frac: float = 0.01,
) -> Tuple[Any, CompressionState, Any]:
    """Returns (sparse_grads, new_state, metrics).

    sparse_grads carries only the top-k fraction by magnitude (rest zero);
    the residual goes into the error-feedback accumulator.
    """
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        mask = _topk_mask(acc, k_frac)
        sent = acc * mask
        return sent, acc - sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sent = treedef.unflatten([o[0] for o in outs])
    err = treedef.unflatten([o[1] for o in outs])
    density = sum(float(jnp.mean((o[0] != 0).astype(jnp.float32)))
                  for o in outs) / max(1, len(outs))
    return sent, CompressionState(error=err), {"sent_density": density}


def quantize_int8(x: jnp.ndarray, block: int = 256):
    """Block-wise symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, x.shape, pad


def dequantize_int8(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def quantized_psum(x: jnp.ndarray, axis_name, block: int = 256) -> jnp.ndarray:
    """int8-on-the-wire psum: quantize locally, sum representatives, dequant.

    Inside shard_map/pmap only.  Wire bytes: 1B/elem + 4B/block vs 4B/elem.
    """
    q, scale, shape, pad = quantize_int8(x, block)
    deq = (q.astype(jnp.float32) * scale)
    summed = jax.lax.psum(deq, axis_name)  # numerics of int8 representatives
    flat = summed.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)
