"""Elastic scaling: restore any checkpoint onto a different mesh.

Checkpoints are stored as host-complete arrays (checkpoint.store), so scaling
from N to M devices is a re-shard at load: build the param/opt specs for the
NEW mesh and device_put each leaf.  This is the recovery path when a pod is
lost (shrink) or capacity returns (grow) — training resumes from the last
good step with the same numerics modulo data order.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.checkpoint import CheckpointManager
from repro.launch import partition
from repro.models.sharding import axes_from_mesh


def shardings_for(mesh, cfg, params_shape, opt_shape=None):
    p_specs = partition.params_specs(mesh, params_shape)
    p_shard = partition.to_named(mesh, p_specs)
    if opt_shape is None:
        return p_shard, None
    o_specs = partition.opt_specs(mesh, opt_shape, p_specs)
    o_shard = partition.to_named(mesh, o_specs)
    return p_shard, o_shard


def reshard_checkpoint(
    ckpt: CheckpointManager,
    cfg,
    new_mesh,
    params_shape,
    opt_shape,
    step: Optional[int] = None,
) -> Tuple[Any, Any]:
    """Load (params, opt_state) from ``ckpt`` resharded onto ``new_mesh``."""
    axes_from_mesh(new_mesh)
    p_shard, o_shard = shardings_for(new_mesh, cfg, params_shape, opt_shape)
    tree = ckpt.restore(
        {"params": params_shape, "opt": opt_shape},
        step=step,
        target_shardings={"params": p_shard, "opt": o_shard},
    )
    return tree["params"], tree["opt"]
