"""Fault tolerance: failure detection + checkpoint/restart, straggler watch.

``ResilientTrainer`` wraps a compiled train step with:
  * periodic async checkpoints (atomic — see checkpoint.store);
  * failure detection: non-finite loss, raised exceptions, or injected faults
    (the test hook standing in for a dead host);
  * automatic restore-from-last-good + batch skip on failure;
  * a ``StragglerMonitor`` that tracks per-step wall time against an EMA and
    flags slow steps (on a real fleet the flagged host is cordoned and its
    shard re-issued; on this single-host runtime the event is surfaced to the
    caller, and the policy is unit-tested at simulation level).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    ema: float
    factor: float


class StragglerMonitor:
    """EMA-based step-time watchdog (deterministic, testable)."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.1,
                 warmup: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.events = []

    def observe(self, step: int, duration: float) -> Optional[StragglerEvent]:
        self.n += 1
        if self.ema is None:
            self.ema = duration
            return None
        event = None
        if self.n > self.warmup and duration > self.factor * self.ema:
            event = StragglerEvent(step, duration, self.ema,
                                   duration / self.ema)
            self.events.append(event)
            # do not pollute the EMA with the outlier
            return event
        self.ema = (1 - self.alpha) * self.ema + self.alpha * duration
        return event


class FaultInjector:
    """Deterministic fault schedule for tests: fail at given steps."""

    def __init__(self, fail_at: Iterable[int] = ()):  # steps (0-based)
        self.fail_at = set(fail_at)
        self.injected = []

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.injected.append(step)
            raise RuntimeError(f"injected fault at step {step}")


class ResilientTrainer:
    def __init__(
        self,
        train_step: Callable,        # (params, opt_state, batch) -> (p, o, metrics)
        params,
        opt_state,
        ckpt: CheckpointManager,
        ckpt_every: int = 50,
        max_restarts: int = 10,
        fault_injector: Optional[FaultInjector] = None,
        straggler: Optional[StragglerMonitor] = None,
        target_shardings=None,
    ):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.faults = fault_injector
        self.straggler = straggler or StragglerMonitor()
        self.target_shardings = target_shardings
        self.restarts = 0
        self.step = 0
        self.history: list = []
        # step 0 checkpoint so a first-step failure is recoverable
        self.ckpt.save(0, {"params": self.params, "opt": self.opt_state})

    def _restore(self):
        last = self.ckpt.latest_step()
        tree = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state},
            step=last, target_shardings=self.target_shardings)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = last
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError("restart budget exhausted")

    def run(self, batches: Callable[[int], Any], n_steps: int) -> Dict:
        """batches(step) -> batch.  Returns summary metrics."""
        losses = []
        while self.step < n_steps:
            batch = batches(self.step)
            t0 = time.time()
            try:
                if self.faults is not None:
                    self.faults.maybe_fail(self.step)
                p, o, metrics = self.train_step(self.params, self.opt_state,
                                                batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {self.step}")
            except Exception as e:  # noqa: BLE001 — any failure -> restart
                self.history.append(("failure", self.step, repr(e)))
                self._restore()
                continue
            dt = time.time() - t0
            ev = self.straggler.observe(self.step, dt)
            if ev is not None:
                self.history.append(("straggler", ev.step, ev.factor))
            self.params, self.opt_state = p, o
            self.step += 1
            losses.append(loss)
            if self.step % self.ckpt_every == 0:
                self.ckpt.async_save(self.step, {"params": self.params,
                                                 "opt": self.opt_state})
        self.ckpt.wait()
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state})
        return {"final_loss": losses[-1] if losses else None,
                "losses": losses, "restarts": self.restarts,
                "straggler_events": len(self.straggler.events),
                "history": self.history}
