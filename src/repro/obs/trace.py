"""Request tracing: a thread-safe, bounded ring-buffer span recorder.

The serving runtime (and the engine's compile pipeline) emit *spans* — named
intervals with attributes — and instant *events* into a :class:`Tracer`.
Design constraints, in order:

  * **near-zero overhead when disabled** — every instrumentation site checks
    ``tracer.enabled`` (one attribute read) before building any attribute
    dict; a disabled tracer records nothing and allocates nothing.
    ``NULL_TRACER`` is the shared disabled instance every un-instrumented
    server uses, so the hot path never branches on ``None``;
  * **bounded memory** — spans live in a ``deque(maxlen=capacity)`` ring:
    a week-long server keeps the *latest* ``capacity`` spans and counts the
    rest in ``dropped`` instead of growing without bound;
  * **injected clock** — spans are timestamped on the same clock the server
    schedules on (``SparseServer(clock=...)``), so deterministic fake-clock
    tests produce deterministic traces;
  * **standard export** — :meth:`Tracer.export` writes either Chrome-trace
    JSON (loadable in ``chrome://tracing`` / `Perfetto <https://ui.perfetto.dev>`_)
    or JSONL (one span object per line, grep/jq-friendly).

Span taxonomy (names, attributes, units) is documented in
``docs/observability.md``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

__all__ = ["Span", "Tracer", "NULL_TRACER"]


@dataclasses.dataclass
class Span:
    """One recorded interval (``phase="X"``) or instant event (``"i"``).

    Times are seconds on the tracer's clock; ``tid``/``thread`` identify the
    recording thread (Chrome trace rows group by tid)."""

    name: str
    t0: float
    t1: float
    tid: int
    thread: str
    phase: str = "X"                    # "X" complete span | "i" instant
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def to_chrome(self, pid: int) -> dict:
        """One Chrome-trace event: complete (``X``, microsecond ``ts`` +
        ``dur``) or instant (``i``, thread-scoped)."""
        ev = {
            "name": self.name,
            "cat": self.name.split(".", 1)[0],
            "ph": self.phase,
            "ts": self.t0 * 1e6,
            "pid": pid,
            "tid": self.tid,
            "args": self.attrs,
        }
        if self.phase == "X":
            ev["dur"] = self.dur * 1e6
        else:
            ev["s"] = "t"               # instant events are thread-scoped
        return ev

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "dur": self.dur, "phase": self.phase, "tid": self.tid,
                "thread": self.thread, "attrs": self.attrs}


class _NullSpan:
    """The no-op context manager a disabled tracer hands out (shared
    singleton: entering/exiting it does nothing and allocates nothing)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def __setitem__(self, key, value) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context manager recording one span on exit.  Attributes can be added
    mid-span with ``sp["key"] = value`` (e.g. an outcome only known at the
    end of the interval)."""

    __slots__ = ("_tracer", "_name", "_attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, object]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> "_SpanCtx":
        self._t0 = self._tracer.clock()
        return self

    def __setitem__(self, key: str, value) -> None:
        self._attrs[key] = value

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._attrs.setdefault("error", exc_type.__name__)
        self._tracer.span_at(self._name, self._t0, self._tracer.clock(),
                             **self._attrs)
        return False


class Tracer:
    """Thread-safe bounded span recorder.

    Args:
      capacity: ring-buffer bound — the newest ``capacity`` spans are kept,
        older ones are evicted and counted in ``dropped``.
      clock: monotonic time source (inject the server's fake clock in
        tests; defaults to ``time.monotonic``).
      enabled: a disabled tracer is inert — ``span``/``event`` return
        immediately.  Instrumentation sites should additionally guard
        attribute-dict construction behind ``tracer.enabled`` so a disabled
        tracer costs one attribute read per site.
    """

    def __init__(self, capacity: int = 16384,
                 clock: Callable[[], float] = time.monotonic,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        self.enabled = enabled
        self._mu = threading.Lock()
        self._buf: deque = deque(maxlen=capacity)
        self.recorded = 0               # spans ever recorded
        self.dropped = 0                # spans evicted by the ring bound

    # ------------------------------------------------------------------ #
    # recording
    # ------------------------------------------------------------------ #
    def span(self, name: str, **attrs) -> "_SpanCtx | _NullSpan":
        """Context manager timing one interval: ``with tracer.span("x"): ...``."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanCtx(self, name, attrs)

    def span_at(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Record a span whose endpoints were observed elsewhere (e.g. a
        request's queue interval, closed retroactively at batch formation)."""
        if not self.enabled:
            return
        t = threading.current_thread()
        self._record(Span(name=name, t0=t0, t1=t1, tid=t.ident or 0,
                          thread=t.name, attrs=attrs))

    def event(self, name: str, **attrs) -> None:
        """Record an instant event (a state transition, not an interval)."""
        if not self.enabled:
            return
        now = self.clock()
        t = threading.current_thread()
        self._record(Span(name=name, t0=now, t1=now, tid=t.ident or 0,
                          thread=t.name, phase="i", attrs=attrs))

    def _record(self, span: Span) -> None:
        with self._mu:
            if len(self._buf) == self.capacity:
                self.dropped += 1       # deque(maxlen) evicts the oldest
            self._buf.append(span)
            self.recorded += 1

    # ------------------------------------------------------------------ #
    # inspection / export
    # ------------------------------------------------------------------ #
    def spans(self) -> List[Span]:
        """Snapshot of the buffered spans, oldest first."""
        with self._mu:
            return list(self._buf)

    def clear(self) -> None:
        with self._mu:
            self._buf.clear()

    def snapshot(self) -> dict:
        with self._mu:
            return {"buffered": len(self._buf), "recorded": self.recorded,
                    "dropped": self.dropped, "capacity": self.capacity,
                    "enabled": self.enabled}

    def to_chrome(self) -> dict:
        """Chrome-trace/Perfetto-loadable JSON object.  Events are sorted by
        ``ts`` (retroactive spans can be recorded out of order; the sorted
        stream is what viewers — and the format validator in the tests —
        expect)."""
        pid = os.getpid()
        events = [s.to_chrome(pid) for s in self.spans()]
        events.sort(key=lambda e: (e["ts"], e.get("dur", 0.0)))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh)
            fh.write("\n")
        return path

    def export_jsonl(self, path: str) -> str:
        with open(path, "w") as fh:
            for s in self.spans():
                fh.write(json.dumps(s.to_dict()) + "\n")
        return path

    def export(self, path: str) -> str:
        """Chrome-trace JSON by default; JSONL when ``path`` ends ``.jsonl``."""
        if path.endswith(".jsonl"):
            return self.export_jsonl(path)
        return self.export_chrome(path)


#: Shared disabled tracer: the default for every un-instrumented server, so
#: hot paths branch on ``tracer.enabled`` instead of ``tracer is None``.
NULL_TRACER = Tracer(capacity=1, enabled=False)
