"""I/O-aware run-time telemetry: the paper's counters as live gauges.

At compile time a plan already knows its simulated tile I/O vs the
Theorem-1 bounds (`IOReport`) and — when gated — can measure the dynamic
block reads of a concrete batch (`DynamicIOReport`).  This module turns
those into *serving* telemetry:

  * :func:`plan_io_attrs` — a flat attribute dict for trace spans (works on
    both ``ExecutionPlan`` and ``ShardedExecutionPlan``);
  * :class:`IOTelemetry` — per-bucket aggregation of static plan gauges and
    per-batch measured dynamic I/O, owned by a ``SparseServer`` and exported
    through its snapshot and the Prometheus endpoint.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional

__all__ = ["plan_io_attrs", "IOTelemetry"]

#: occupancy-histogram bin labels, matching ``DynamicIOReport.per_layer_hist``
OCC_BINS = ("dead", "lt25", "lt50", "lt75", "le100")


def _weight_bytes(plan) -> int:
    # prefer the plan's own byte accounting: for a quantized weight stream
    # the schedule blocks are narrower than the f32 layer blocks, and the
    # IOReport counts exactly what the forward streams (blocks + scales)
    io = getattr(plan, "io", None)
    streamed = getattr(io, "weight_stream_bytes", 0)
    if streamed:
        return int(streamed)
    layers = getattr(plan, "layers", None)
    if not layers:
        return 0
    return int(sum(getattr(l.blocks, "nbytes", 0) for l in layers))


def _weight_bytes_by_dtype(plan) -> Dict[str, int]:
    """Streamed weight bytes split by storage dtype.

    Quantized plans stream narrow blocks plus one f32 scale per block, so
    the map has two entries (``{"bf16": ..., "f32": ...}``); an unquantized
    plan puts everything under ``"f32"``.  Empty when the plan predates
    byte accounting."""
    io = getattr(plan, "io", None)
    wdt = getattr(io, "weight_dtype", "f32")
    wbytes = int(getattr(io, "weight_bytes_streamed", 0) or 0)
    sbytes = int(getattr(io, "scale_bytes_streamed", 0) or 0)
    if not wbytes:
        return {}
    out = {wdt: wbytes}
    if sbytes:
        out["f32"] = out.get("f32", 0) + sbytes
    return out


def _nnz_blocks(plan) -> int:
    layers = getattr(plan, "layers", None)
    if not layers:
        return 0
    return int(sum(l.nnz_blocks for l in layers))


def plan_io_attrs(plan) -> Dict[str, object]:
    """Compact span attributes describing a plan's I/O profile.

    Handles both plan kinds: an ``ExecutionPlan`` (direct ``io`` field)
    and a ``ShardedExecutionPlan`` (``io`` property aggregating shards).
    Never raises — a plan missing a field simply omits the attribute.
    """
    attrs: Dict[str, object] = {}
    backend = getattr(plan, "backend", None)
    if backend is not None:
        attrs["backend"] = backend
    for name in ("fused", "gate"):
        v = getattr(plan, name, None)
        if v is not None:
            attrs[name] = bool(v)
    shards = getattr(plan, "shards", None)
    if shards is not None:
        attrs["shards"] = len(shards)
    io = getattr(plan, "io", None)
    if io is None:
        return attrs
    sim = getattr(io, "simulated", None)
    if sim is not None:
        attrs["io_tile_reads"] = int(sim.reads)
        attrs["io_tile_writes"] = int(sim.writes)
        attrs["io_tile_total"] = int(sim.total)
        attrs["io_optimality_ratio"] = round(float(io.optimality_ratio), 4)
        attrs["io_within_bounds"] = bool(io.within_bounds)
    streamed = getattr(io, "weight_stream_bytes", 0)
    if streamed:
        attrs["io_weight_bytes"] = int(streamed)
        attrs["weight_dtype"] = getattr(io, "weight_dtype", "f32")
    dyn = getattr(io, "dynamic", None)
    if dyn is not None:
        attrs["io_dynamic_blocks"] = int(dyn.dynamic_total)
        attrs["io_static_blocks"] = int(dyn.static_total)
        attrs["io_read_fraction"] = round(float(dyn.read_fraction), 4)
    nnz = _nnz_blocks(plan)
    if nnz:
        attrs["nnz_blocks"] = nnz
    return attrs


class _BucketIO:
    """Per-bucket aggregate: static plan gauges (set once) + running
    dynamic measurements."""

    __slots__ = ("bucket", "static_blocks", "weight_bytes", "tile_reads",
                 "tile_writes", "optimality_ratio", "within_bounds",
                 "bytes_per_block", "batches_measured", "dynamic_blocks",
                 "static_scheduled", "dynamic_bytes", "last_read_fraction",
                 "occupancy_hist", "weight_dtype", "weight_bytes_by_dtype")

    def __init__(self, bucket: int):
        self.bucket = bucket
        # static (schedule) gauges — properties of the compiled plan
        self.static_blocks = 0          # nonzero weight blocks in the net
        self.weight_bytes = 0           # bytes of weight blocks on disk/HBM
        self.weight_dtype = "f32"       # storage dtype of streamed blocks
        self.weight_bytes_by_dtype: Dict[str, int] = {}
        self.tile_reads = 0             # simulated tile reads (paper model)
        self.tile_writes = 0
        self.optimality_ratio = 0.0     # simulated / Theorem-1 lower bound
        self.within_bounds = True
        self.bytes_per_block = 0.0
        # dynamic (measured) aggregates — properties of actual batches
        self.batches_measured = 0
        self.dynamic_blocks = 0         # sum of measured dynamic reads
        self.static_scheduled = 0       # sum of static schedule lengths
        self.dynamic_bytes = 0          # estimated weight bytes streamed
        self.last_read_fraction = 1.0
        self.occupancy_hist = [0] * len(OCC_BINS)

    def to_dict(self) -> dict:
        d = {
            "bucket": self.bucket,
            "static_blocks": self.static_blocks,
            "weight_bytes": self.weight_bytes,
            "tile_reads": self.tile_reads,
            "tile_writes": self.tile_writes,
            "optimality_ratio": round(self.optimality_ratio, 4),
            "within_bounds": self.within_bounds,
        }
        if self.weight_bytes_by_dtype:
            d["weight_dtype"] = self.weight_dtype
            d["weight_bytes_by_dtype"] = dict(self.weight_bytes_by_dtype)
        if self.batches_measured:
            d.update({
                "batches_measured": self.batches_measured,
                "dynamic_blocks": self.dynamic_blocks,
                "static_scheduled": self.static_scheduled,
                "dynamic_bytes": self.dynamic_bytes,
                "read_fraction": round(
                    self.dynamic_blocks / max(1, self.static_scheduled), 4),
                "last_read_fraction": round(self.last_read_fraction, 4),
                "occupancy_hist": dict(zip(OCC_BINS, self.occupancy_hist)),
            })
        return d


class IOTelemetry:
    """Thread-safe per-bucket I/O gauge aggregation for one served model.

    ``observe_plan`` records a bucket's static gauges from its compiled
    plan (idempotent — re-observing after a hot-swap refreshes them);
    ``observe_dynamic`` folds in one batch's measured ``DynamicIOReport``.
    The lock is a leaf: nothing is called while holding it.
    """

    def __init__(self, model: str = "default"):
        self.model = model
        self._mu = threading.Lock()
        self._buckets: Dict[int, _BucketIO] = {}

    def _get(self, bucket: int) -> _BucketIO:
        b = self._buckets.get(bucket)
        if b is None:
            b = self._buckets[bucket] = _BucketIO(bucket)
        return b

    def observe_plan(self, bucket: int, plan) -> None:
        """Record the static I/O gauges of the plan serving ``bucket``."""
        nnz = _nnz_blocks(plan)
        wbytes = _weight_bytes(plan)
        by_dtype = _weight_bytes_by_dtype(plan)
        io = getattr(plan, "io", None)
        wdt = getattr(io, "weight_dtype", "f32")
        sim = getattr(io, "simulated", None)
        with self._mu:
            b = self._get(bucket)
            b.static_blocks = nnz
            b.weight_bytes = wbytes
            b.weight_dtype = wdt
            b.weight_bytes_by_dtype = by_dtype
            b.bytes_per_block = wbytes / nnz if nnz else 0.0
            if sim is not None:
                b.tile_reads = int(sim.reads)
                b.tile_writes = int(sim.writes)
                b.optimality_ratio = float(io.optimality_ratio)
                b.within_bounds = bool(io.within_bounds)

    def observe_dynamic(self, bucket: int, report) -> None:
        """Fold one batch's measured ``DynamicIOReport`` into ``bucket``."""
        dyn = int(report.dynamic_total)
        stat = int(report.static_total)
        with self._mu:
            b = self._get(bucket)
            b.batches_measured += 1
            b.dynamic_blocks += dyn
            b.static_scheduled += stat
            b.dynamic_bytes += int(dyn * b.bytes_per_block)
            b.last_read_fraction = float(report.read_fraction)
            for hist in report.per_layer_hist:
                for i, n in enumerate(hist[:len(OCC_BINS)]):
                    b.occupancy_hist[i] += int(n)

    def snapshot(self) -> dict:
        """Per-bucket gauges plus model-level totals (JSON-safe)."""
        with self._mu:
            buckets = {b.bucket: b.to_dict()
                       for b in self._buckets.values()}
        measured = [b for b in buckets.values() if "dynamic_blocks" in b]
        total_dyn = sum(b["dynamic_blocks"] for b in measured)
        total_stat = sum(b["static_scheduled"] for b in measured)
        out = {
            "model": self.model,
            "buckets": buckets,
            "batches_measured": sum(b.get("batches_measured", 0)
                                    for b in buckets.values()),
        }
        if measured:
            out["dynamic_blocks"] = total_dyn
            out["static_scheduled"] = total_stat
            out["read_fraction"] = round(total_dyn / max(1, total_stat), 4)
            out["dynamic_bytes"] = sum(b["dynamic_bytes"] for b in measured)
        return out
