"""repro.obs — observability substrate for the serving runtime.

Three pieces, documented in ``docs/observability.md``:

  * :mod:`repro.obs.trace` — :class:`Tracer`, a thread-safe bounded
    ring-buffer span recorder with Chrome-trace / JSONL export;
  * :mod:`repro.obs.series` — :class:`BoundedSeries`, capped-memory metric
    series with exact-then-bucketed percentiles;
  * :mod:`repro.obs.telemetry` / :mod:`repro.obs.prom` — per-bucket I/O
    gauges from the compiled plans and Prometheus text exposition.
"""

from .series import BoundedSeries
from .telemetry import IOTelemetry, plan_io_attrs
from .trace import NULL_TRACER, Span, Tracer
from .prom import MetricsServer, render_prometheus

__all__ = [
    "BoundedSeries",
    "IOTelemetry",
    "plan_io_attrs",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "MetricsServer",
    "render_prometheus",
]
