"""Prometheus text exposition for serving snapshots.

:func:`render_prometheus` flattens the nested snapshot dicts produced by
``SparseServer.snapshot()`` / ``ModelRouter.metrics_snapshot()`` into the
Prometheus text format (version 0.0.4): scalars become gauges, percentile
dicts become quantile-labelled summaries, per-model and per-bucket maps
become labels.  :class:`MetricsServer` serves the rendered text over HTTP
(stdlib ``ThreadingHTTPServer`` — no new dependencies) at ``/metrics``,
plus a ``/healthz`` liveness probe.

Metric names and units are documented in ``docs/observability.md``.
"""

from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["render_prometheus", "MetricsServer"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")

#: keys answered with ``{quantile=...}`` summary lines
_QUANTILE_KEYS = ("p50", "p99")


def _sanitize(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{str(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _Samples:
    """Samples grouped by metric name (the text format requires each
    name's samples contiguous, after its ``# TYPE`` line)."""

    def __init__(self) -> None:
        self._by_name: Dict[str, List[Tuple[Dict[str, str], object]]] = {}
        self._order: List[str] = []

    def add(self, name: str, labels: Dict[str, str], value) -> None:
        if name not in self._by_name:
            self._by_name[name] = []
            self._order.append(name)
        self._by_name[name].append((dict(labels), value))

    def render(self) -> str:
        lines: List[str] = []
        for name in self._order:
            lines.append(f"# TYPE {name} gauge")
            for labels, value in self._by_name[name]:
                lines.append(f"{name}{_fmt_labels(labels)} {_fmt_value(value)}")
        return "\n".join(lines) + "\n"


def _is_quantile_dict(v) -> bool:
    return (isinstance(v, dict)
            and any(k in v for k in _QUANTILE_KEYS)
            and all(isinstance(x, (int, float)) for x in v.values()))


def _walk(out: _Samples, prefix: str, node: dict,
          labels: Dict[str, str]) -> None:
    for key, v in node.items():
        name = f"{prefix}_{_sanitize(str(key))}"
        if key == "models" and isinstance(v, dict):
            # router snapshot: one sample set per model, model= labelled
            for model, snap in v.items():
                if isinstance(snap, dict):
                    _walk(out, prefix, snap,
                          {**labels, "model": str(model)})
            continue
        if key in ("buckets", "bucket_hist") and isinstance(v, dict):
            # per-bucket maps: bucket= labelled rather than name-mangled
            base = (f"{prefix}_bucket_requests" if key == "bucket_hist"
                    else prefix)
            for bucket, bv in v.items():
                blabels = {**labels, "bucket": str(bucket)}
                if isinstance(bv, dict):
                    _walk(out, base, bv, blabels)
                elif isinstance(bv, (int, float)):
                    out.add(base, blabels, bv)
            continue
        if key == "occupancy_hist" and isinstance(v, dict):
            for bin_name, n in v.items():
                out.add(name, {**labels, "bin": str(bin_name)}, n)
            continue
        if key == "per_worker" and isinstance(v, dict):
            # executor-pool gauges: {"0": {"utilization": ...}, ...} →
            # worker= labelled samples under the parent prefix
            for worker, wv in v.items():
                wlabels = {**labels, "worker": str(worker)}
                if isinstance(wv, dict):
                    _walk(out, f"{prefix}_worker", wv, wlabels)
                elif isinstance(wv, (int, float)):
                    out.add(f"{prefix}_worker", wlabels, wv)
            continue
        if str(key).endswith("_by_dtype") and isinstance(v, dict):
            # {"bf16": bytes, "f32": bytes} → base metric with dtype= label
            base = f"{prefix}_{_sanitize(str(key)[:-len('_by_dtype')])}"
            for dt, n in v.items():
                if isinstance(n, (bool, int, float)):
                    out.add(base, {**labels, "dtype": str(dt)}, n)
            continue
        if _is_quantile_dict(v):
            for qk, qv in v.items():
                if qk == "count":
                    out.add(f"{name}_count", labels, qv)
                elif qk.startswith("p"):
                    q = float(qk[1:]) / 100.0
                    out.add(name, {**labels, "quantile": f"{q:g}"}, qv)
                else:
                    out.add(f"{name}_{_sanitize(qk)}", labels, qv)
            continue
        if isinstance(v, dict):
            _walk(out, name, v, labels)
        elif isinstance(v, (bool, int, float)):
            out.add(name, labels, v)
        # strings / None / lists are descriptive, not metrics — skipped


def render_prometheus(snapshot: dict, prefix: str = "repro") -> str:
    """Render a serving snapshot as Prometheus text exposition format.

    Accepts either a single-server snapshot (``SparseServer.snapshot()``)
    or a router snapshot (``ModelRouter.metrics_snapshot()``, whose
    ``models`` map becomes a ``model=`` label).  Unknown keys flatten
    generically — new counters show up without touching this module.
    """
    out = _Samples()
    _walk(out, _sanitize(prefix), snapshot, {})
    return out.render()


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-metrics/1.0"

    def do_GET(self) -> None:  # noqa: N802  (http.server API)
        if self.path.split("?", 1)[0] == "/healthz":
            self._reply(200, "ok\n", "text/plain")
            return
        if self.path.split("?", 1)[0] not in ("/metrics", "/"):
            self._reply(404, "not found\n", "text/plain")
            return
        try:
            snap = self.server.snapshot_fn()      # type: ignore[attr-defined]
            body = render_prometheus(snap, self.server.prefix)  # type: ignore
        except Exception as e:                     # surface, don't crash
            self._reply(500, f"snapshot failed: {e!r}\n", "text/plain")
            return
        self._reply(200, body, "text/plain; version=0.0.4; charset=utf-8")

    def _reply(self, code: int, body: str, ctype: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def log_message(self, fmt, *args) -> None:   # quiet by default
        pass


class MetricsServer:
    """Background HTTP exposition server.

    Args:
      snapshot_fn: zero-arg callable returning the current snapshot dict
        (it is called per scrape, so it must be cheap and thread-safe —
        both snapshot paths in ``repro.serving`` are).
      port: TCP port; ``0`` binds an ephemeral port (read ``.port`` after
        construction).
      host: bind address, loopback by default.
      prefix: metric-name prefix.
    """

    def __init__(self, snapshot_fn: Callable[[], dict], port: int = 0,
                 host: str = "127.0.0.1", prefix: str = "repro"):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.snapshot_fn = snapshot_fn       # type: ignore[attr-defined]
        self._httpd.prefix = prefix                 # type: ignore[attr-defined]
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def start(self) -> "MetricsServer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-http", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        if self._thread is None:
            return
        self._httpd.shutdown()
        self._thread.join(timeout=timeout)
        self._httpd.server_close()
        self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
