"""Bounded metric series: exact while small, streaming histogram forever.

`ServingMetrics` used to append every latency/queue-depth observation to a
plain Python list — unbounded memory on a week-long server.  A
:class:`BoundedSeries` keeps the same ``percentile()`` answers with capped
memory:

  * below ``exact_cap`` samples the raw values are retained and every
    quantile is **exact** (nearest-rank, identical to the old lists);
  * past the cap the raw values are released and only fixed log-spaced
    bucket counts remain.  With bucket ``growth=1.25`` a quantile is then
    answered from the geometric midpoint of its bucket — relative error at
    most ``sqrt(growth) - 1`` (≈ 11.8%), independent of stream length.

Every observation is binned on record (O(1) via a log-index), so the bucket
counts — what the Prometheus endpoint exports as a cumulative histogram —
are populated in both modes.  Memory is O(exact_cap + n_buckets) always.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = ["BoundedSeries"]


class BoundedSeries:
    """Bounded stream summary answering count/sum/min/max/percentile.

    Not internally locked: `ServingMetrics` guards all its series with its
    own (leaf) lock, and a second lock per observation would be pure
    overhead.  Standalone concurrent use needs external synchronisation.

    Args:
      exact_cap: number of raw samples kept before collapsing to buckets.
      lo / hi: bucket range.  Values below ``lo`` land in the first bucket,
        above ``hi`` in a ``+Inf`` overflow bucket.  Defaults cover 1 µs to
        10 000 s — every duration this repo records — and also serve
        dimensionless series (queue depth) acceptably.
      growth: geometric bucket width; bounds post-cap quantile error at
        ``sqrt(growth) - 1``.
    """

    __slots__ = ("exact_cap", "lo", "growth", "_log_lo", "_log_growth",
                 "_nb", "_counts", "_exact", "count", "total", "vmin", "vmax")

    def __init__(self, exact_cap: int = 4096, lo: float = 1e-6,
                 hi: float = 1e4, growth: float = 1.25):
        if exact_cap < 0:
            raise ValueError(f"exact_cap must be >= 0, got {exact_cap}")
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError(f"bad bucket spec lo={lo} hi={hi} growth={growth}")
        self.exact_cap = exact_cap
        self.lo = lo
        self.growth = growth
        self._log_lo = math.log(lo)
        self._log_growth = math.log(growth)
        # buckets: (-inf, lo], (lo, lo*g], ..., (last, +inf) — the final
        # slot is the +Inf overflow bucket
        self._nb = int(math.ceil((math.log(hi) - self._log_lo)
                                 / self._log_growth)) + 2
        self._counts = [0] * self._nb
        self._exact: Optional[List[float]] = []
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # ------------------------------------------------------------------ #
    def _bucket_index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.floor((math.log(v) - self._log_lo) / self._log_growth)) + 1
        return min(i, self._nb - 1)

    def _bucket_upper(self, i: int) -> float:
        """Upper edge of bucket ``i`` (``inf`` for the overflow bucket)."""
        if i >= self._nb - 1:
            return math.inf
        return math.exp(self._log_lo + i * self._log_growth)

    def add(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        # bin on record so the histogram is populated in both modes
        self._counts[self._bucket_index(v)] += 1
        if self._exact is not None:
            self._exact.append(v)
            if len(self._exact) > self.exact_cap:
                self._exact = None      # collapse: buckets already hold all

    def extend(self, vs) -> None:
        for v in vs:
            self.add(v)

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:
        return self.count > 0

    @property
    def exact(self) -> bool:
        """True while quantiles are exact (raw samples still retained)."""
        return self._exact is not None

    def values(self) -> Optional[List[float]]:
        """Raw observations in arrival order, or None once collapsed."""
        return None if self._exact is None else list(self._exact)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile; exact below ``exact_cap`` (identical to
        ``repro.serving.metrics.percentile`` on the raw list), within the
        documented bucket error after.  Returns 0.0 on an empty series."""
        if not self.count:
            return 0.0
        q = min(100.0, max(0.0, float(q)))
        # same nearest-index rank as the legacy list percentile, so snapshots
        # are bit-identical to the unbounded implementation while exact
        rank = min(self.count,
                   max(0, int(round(q / 100.0 * (self.count - 1)))) + 1)
        if self._exact is not None:
            return sorted(self._exact)[rank - 1]
        cum = 0
        for i, c in enumerate(self._counts):
            cum += c
            if cum >= rank:
                hi = self._bucket_upper(i)
                lo = self._bucket_upper(i - 1) if i > 0 else self.vmin
                if math.isinf(hi):      # overflow bucket: best guess is max
                    rep = self.vmax
                else:                   # geometric midpoint of the bucket
                    rep = math.sqrt(max(lo, self.lo * 1e-12) * hi)
                return min(self.vmax, max(self.vmin, rep))
        return self.vmax

    def buckets(self) -> Iterator[Tuple[float, int]]:
        """Cumulative ``(upper_edge, count)`` pairs, Prometheus-style
        (last edge is ``inf``; counts are cumulative)."""
        cum = 0
        for i, c in enumerate(self._counts):
            cum += c
            yield self._bucket_upper(i), cum

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "mean": self.mean(),
            "exact": self.exact,
        }

    def __repr__(self) -> str:
        return (f"BoundedSeries(count={self.count}, mean={self.mean():.6g}, "
                f"exact={self.exact})")
