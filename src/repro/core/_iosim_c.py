"""Optional C accelerator for the Algorithm-1 I/O simulator and CR moves.

Compiled on first use with the system C compiler into a cache dir and loaded
via ctypes.  ``repro.core.iosim.simulate`` and ``repro.core.reorder`` use it
transparently when available; the pure-Python implementations remain the
reference oracles (cross-checked in tests/test_iosim.py).

Semantics mirrored exactly from the Python paths:
  * capacity = M - 1 neuron-value slots (one slot reserved for the streamed
    connection triple);
  * read-I/O per miss; write-I/O on evicting a dirty value that is needed
    again or belongs to an output neuron ("efficient eviction policy");
  * MIN = Belady via a lazy max-heap on next-use (computed internally),
    LRU via a lazy min-heap on stamps, RR via a slot ring;
  * propose = the paper's windowed left/right move (randomness stays in
    Python so both paths generate identical proposals).
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import subprocess
import tempfile
from typing import Optional

import numpy as np

_SRC = r"""
#include <stdint.h>
#include <stdlib.h>
#include <string.h>

#define INF INT64_MAX

typedef struct { int64_t key; int64_t val; } heapent;

static void heap_push(heapent *h, int64_t *sz, int64_t key, int64_t val) {
    int64_t i = (*sz)++;
    h[i].key = key; h[i].val = val;
    while (i > 0) {
        int64_t p = (i - 1) >> 1;
        if (h[p].key <= h[i].key) break;
        heapent tmp = h[p]; h[p] = h[i]; h[i] = tmp;
        i = p;
    }
}

static heapent heap_pop(heapent *h, int64_t *sz) {
    heapent top = h[0];
    h[0] = h[--(*sz)];
    int64_t i = 0;
    for (;;) {
        int64_t l = 2 * i + 1, r = l + 1, m = i;
        if (l < *sz && h[l].key < h[m].key) m = l;
        if (r < *sz && h[r].key < h[m].key) m = r;
        if (m == i) break;
        heapent tmp = h[m]; h[m] = h[i]; h[i] = tmp;
        i = m;
    }
    return top;
}

/* policy: 0 = MIN, 1 = LRU, 2 = RR.  Returns 0 ok, -1 alloc failure.
   out[0] = reads (misses only), out[1] = writes (evictions + final flush). */
int simulate(const int64_t *trace, int64_t T, int64_t n, int64_t capacity,
             const uint8_t *is_output, int policy, int64_t *out)
{
    uint8_t *in_cache = calloc(n, 1);
    uint8_t *dirty = calloc(n, 1);
    int64_t *remaining = calloc(n, sizeof(int64_t));
    int64_t *aux = malloc(n * sizeof(int64_t));       /* cur_next_use / stamp */
    heapent *heap = malloc((2 * T + 16) * sizeof(heapent));
    int64_t *nxt = NULL, *slots = NULL, *slot_of = NULL, *last = NULL;
    if (!in_cache || !dirty || !remaining || !aux || !heap) goto fail;
    for (int64_t t = 0; t < T; t++) remaining[trace[t]]++;
    for (int64_t v = 0; v < n; v++) aux[v] = INF;

    if (policy == 0) {
        nxt = malloc(T * sizeof(int64_t));
        last = malloc(n * sizeof(int64_t));
        if (!nxt || !last) goto fail;
        for (int64_t v = 0; v < n; v++) last[v] = INF;
        for (int64_t t = T - 1; t >= 0; t--) {
            nxt[t] = last[trace[t]];
            last[trace[t]] = t;
        }
    }

    int64_t reads = 0, writes = 0, cached = 0, hsz = 0;
    int64_t clock = 0, rr_ptr = 0, next_free = 0;

    if (policy == 2) {
        slots = malloc(capacity * sizeof(int64_t));
        slot_of = malloc(n * sizeof(int64_t));
        if (!slots || !slot_of) goto fail;
        for (int64_t i = 0; i < capacity; i++) slots[i] = -1;
    }

    for (int64_t t = 0; t < T; t++) {
        int64_t v = trace[t];
        clock++;
        if (in_cache[v]) {
            if (policy == 0) { aux[v] = nxt[t]; heap_push(heap, &hsz, -nxt[t], v); }
            else if (policy == 1) { aux[v] = clock; heap_push(heap, &hsz, clock, v); }
        } else {
            if (cached >= capacity) {
                int64_t u = -1;
                if (policy == 0) {
                    for (;;) {
                        heapent e = heap_pop(heap, &hsz);
                        if (in_cache[e.val] && aux[e.val] == -e.key) { u = e.val; break; }
                    }
                } else if (policy == 1) {
                    for (;;) {
                        heapent e = heap_pop(heap, &hsz);
                        if (in_cache[e.val] && aux[e.val] == e.key) { u = e.val; break; }
                    }
                } else {
                    for (;;) {
                        int64_t cand = slots[rr_ptr];
                        int64_t ptr = rr_ptr;
                        rr_ptr = (rr_ptr + 1) % capacity;
                        if (cand >= 0 && in_cache[cand]) {
                            u = cand;
                            slots[ptr] = v; slot_of[v] = ptr;
                            break;
                        }
                    }
                }
                if (dirty[u] && (remaining[u] > 0 || is_output[u])) {
                    writes++; dirty[u] = 0;
                }
                in_cache[u] = 0; cached--;
            } else if (policy == 2) {
                int64_t s = next_free++;
                slots[s] = v; slot_of[v] = s;
            }
            reads++;
            in_cache[v] = 1; cached++;
            if (policy == 0) { aux[v] = nxt[t]; heap_push(heap, &hsz, -nxt[t], v); }
            else if (policy == 1) { aux[v] = clock; heap_push(heap, &hsz, clock, v); }
        }
        remaining[v]--;
        if (t & 1) dirty[v] = 1;
    }
    for (int64_t v = 0; v < n; v++)
        if (in_cache[v] && dirty[v] && is_output[v]) writes++;

    out[0] = reads; out[1] = writes;
    free(in_cache); free(dirty); free(remaining); free(aux); free(heap);
    free(nxt); free(last); free(slots); free(slot_of);
    return 0;
fail:
    free(in_cache); free(dirty); free(remaining); free(aux); free(heap);
    free(nxt); free(last); free(slots); free(slot_of);
    return -1;
}

/* MIN-policy segment executor for the incremental (windowed) evaluator.
   Runs Algorithm-1 accounting over trace_seg[0..L) with explicit per-access
   next-use keys nxt_seg[], starting from the given cache state; the state
   arrays (in_cache, dirty, remaining) are mutated in place so the caller can
   chain segments.  The Belady heap is rebuilt from (cached_ids, cached_nu) —
   decision-equivalent to a heap carried across the boundary, because
   decisions only ever depend on the valid entries.
   Records one (t_off, victim_key, runner_key, victim, runner) row per
   eviction into ev_out (caller allocates >= 5*L).
   out[0] += reads, out[1] += writes, out[2] = rows written.
   Returns 0 ok, -1 alloc failure. */
int resume_min_segment(const int64_t *trace_seg, const int64_t *nxt_seg,
                       int64_t L, int64_t n, int64_t capacity,
                       const uint8_t *is_output,
                       uint8_t *in_cache, uint8_t *dirty, int64_t *remaining,
                       const int64_t *cached_ids, const int64_t *cached_nu,
                       int64_t n_cached, int64_t *ev_out, int64_t *out)
{
    int64_t *aux = malloc(n * sizeof(int64_t));
    heapent *heap = malloc((L + n_cached + 16) * sizeof(heapent));
    if (!aux || !heap) { free(aux); free(heap); return -1; }
    for (int64_t v = 0; v < n; v++) aux[v] = INF;
    int64_t hsz = 0;
    int64_t cached = 0;
    for (int64_t i = 0; i < n_cached; i++) {
        int64_t v = cached_ids[i];
        aux[v] = cached_nu[i];
        heap_push(heap, &hsz, -cached_nu[i], v);
        cached++;
    }
    int64_t reads = 0, writes = 0, n_ev = 0;
    for (int64_t t = 0; t < L; t++) {
        int64_t v = trace_seg[t];
        int64_t nu = nxt_seg[t];
        if (in_cache[v]) {
            aux[v] = nu;
            heap_push(heap, &hsz, -nu, v);
        } else {
            if (cached >= capacity) {
                int64_t u;
                int64_t negnu;
                for (;;) {
                    heapent e = heap_pop(heap, &hsz);
                    if (in_cache[e.val] && aux[e.val] == -e.key) {
                        u = e.val; negnu = e.key; break;
                    }
                }
                if (dirty[u] && (remaining[u] > 0 || is_output[u])) {
                    writes++; dirty[u] = 0;
                }
                in_cache[u] = 0; cached--;
                while (hsz > 0 &&
                       !(in_cache[heap[0].val] && aux[heap[0].val] == -heap[0].key))
                    heap_pop(heap, &hsz);
                ev_out[5 * n_ev] = t;
                ev_out[5 * n_ev + 1] = -negnu;
                ev_out[5 * n_ev + 2] = hsz > 0 ? -heap[0].key : -1;
                ev_out[5 * n_ev + 3] = u;
                ev_out[5 * n_ev + 4] = hsz > 0 ? heap[0].val : -1;
                n_ev++;
            }
            reads++;
            in_cache[v] = 1; cached++;
            aux[v] = nu;
            heap_push(heap, &hsz, -nu, v);
        }
        remaining[v]--;
        if (t & 1) dirty[v] = 1;  /* caller aligns segments to even t */
    }
    out[0] += reads; out[1] += writes; out[2] = n_ev;
    free(aux); free(heap);
    return 0;
}

/* One windowed CR move (paper IV.A), in place on order[].
   dir: 0 = left, 1 = right.  Window = positions [i, min(i+w, W-1)].
   span > 0 caps how far any connection may travel: the anchor scan stops
   after span steps and inserts there.  Stopping the scan early is always
   topologically safe — the move crossed only conflict-free connections. */
void propose_move(int64_t *order, int64_t W, const int32_t *src,
                  const int32_t *dst, int64_t i, int64_t w, int dir,
                  int64_t span)
{
    int64_t j = i + w; if (j > W - 1) j = W - 1;
    if (dir == 0) {
        for (int64_t k = i; k <= j; k++) {
            int64_t e = order[k];
            int32_t a = src[e];
            int64_t p = k - 1;
            while (p >= 0 && (span <= 0 || k - p <= span)) {
                int64_t f = order[p];
                if (src[f] == a || dst[f] == a) break;
                p--;
            }
            if (p + 1 != k) {
                memmove(order + p + 2, order + p + 1, (k - p - 1) * sizeof(int64_t));
                order[p + 1] = e;
            }
        }
    } else {
        for (int64_t k = j; k >= i; k--) {
            int64_t e = order[k];
            int32_t b = dst[e];
            int64_t p = k + 1;
            while (p < W && (span <= 0 || p - k <= span)) {
                int64_t f = order[p];
                if (dst[f] == b || src[f] == b) break;
                p++;
            }
            if (p - 1 != k) {
                memmove(order + k, order + k + 1, (p - 1 - k) * sizeof(int64_t));
                order[p - 1] = e;
            }
        }
    }
}
"""

_lib: Optional[ctypes.CDLL] = None
_tried = False
_POLICY_ID = {"min": 0, "lru": 1, "rr": 2}


def _cache_dir() -> str:
    d = os.environ.get("REPRO_CACHE", os.path.join(tempfile.gettempdir(), "repro_cache"))
    os.makedirs(d, exist_ok=True)
    return d


def _build() -> Optional[ctypes.CDLL]:
    tag = hashlib.sha256(_SRC.encode()).hexdigest()[:16]
    so = os.path.join(_cache_dir(), f"iosim_{tag}.so")
    if not os.path.exists(so):
        csrc = os.path.join(_cache_dir(), f"iosim_{tag}.c")
        with open(csrc, "w") as f:
            f.write(_SRC)
        cc = os.environ.get("CC", "cc")
        tmp = so + f".tmp{os.getpid()}"
        try:
            subprocess.run(
                [cc, "-O3", "-shared", "-fPIC", "-o", tmp, csrc],
                check=True, capture_output=True, timeout=120,
            )
            os.replace(tmp, so)  # atomic: concurrent builders race safely
        except Exception:
            return None
    try:
        lib = ctypes.CDLL(so)
    except OSError:
        return None
    i64p = ctypes.POINTER(ctypes.c_int64)
    i32p = ctypes.POINTER(ctypes.c_int32)
    u8p = ctypes.POINTER(ctypes.c_uint8)
    lib.simulate.restype = ctypes.c_int
    lib.simulate.argtypes = [i64p, ctypes.c_int64, ctypes.c_int64, ctypes.c_int64,
                             u8p, ctypes.c_int, i64p]
    lib.propose_move.restype = None
    lib.propose_move.argtypes = [i64p, ctypes.c_int64, i32p, i32p,
                                 ctypes.c_int64, ctypes.c_int64, ctypes.c_int,
                                 ctypes.c_int64]
    lib.resume_min_segment.restype = ctypes.c_int
    lib.resume_min_segment.argtypes = [
        i64p, i64p, ctypes.c_int64, ctypes.c_int64, ctypes.c_int64, u8p,
        u8p, u8p, i64p, i64p, i64p, ctypes.c_int64, i64p, i64p]
    return lib


def available() -> bool:
    global _lib, _tried
    if not _tried:
        _tried = True
        if os.environ.get("REPRO_NO_C_SIM"):
            _lib = None
        else:
            _lib = _build()
    return _lib is not None


def simulate_c(trace: np.ndarray, n: int, capacity: int,
               is_output: np.ndarray, policy: str):
    """Returns (miss_reads, evict_writes) or None if the accelerator is unavailable."""
    if not available():
        return None
    trace = np.ascontiguousarray(trace, dtype=np.int64)
    is_out = np.ascontiguousarray(is_output.astype(np.uint8))
    out = np.zeros(2, dtype=np.int64)
    i64p = ctypes.POINTER(ctypes.c_int64)
    u8p = ctypes.POINTER(ctypes.c_uint8)
    rc = _lib.simulate(
        trace.ctypes.data_as(i64p), len(trace), n, capacity,
        is_out.ctypes.data_as(u8p), _POLICY_ID[policy],
        out.ctypes.data_as(i64p),
    )
    if rc != 0:
        return None
    return int(out[0]), int(out[1])


def resume_min_segment_c(trace_seg: np.ndarray, nxt_seg: np.ndarray,
                         n: int, capacity: int, is_output: np.ndarray,
                         in_cache: np.ndarray, dirty: np.ndarray,
                         remaining: np.ndarray, cached_ids: np.ndarray,
                         cached_nu: np.ndarray, ev_out: np.ndarray,
                         out: np.ndarray) -> bool:
    """Run one MIN segment in C; mutates state arrays in place.

    ``out`` is int64[3]: reads are ADDED to out[0], writes to out[1], and
    out[2] is set to the number of eviction rows written to ``ev_out``.
    Returns False if the accelerator is unavailable (caller falls back)."""
    if not available():
        return False
    i64p = ctypes.POINTER(ctypes.c_int64)
    u8p = ctypes.POINTER(ctypes.c_uint8)
    rc = _lib.resume_min_segment(
        trace_seg.ctypes.data_as(i64p), nxt_seg.ctypes.data_as(i64p),
        len(trace_seg), n, capacity,
        is_output.ctypes.data_as(u8p),
        in_cache.ctypes.data_as(u8p), dirty.ctypes.data_as(u8p),
        remaining.ctypes.data_as(i64p),
        cached_ids.ctypes.data_as(i64p), cached_nu.ctypes.data_as(i64p),
        len(cached_ids), ev_out.ctypes.data_as(i64p),
        out.ctypes.data_as(i64p),
    )
    return rc == 0


def propose_move_c(order: np.ndarray, src: np.ndarray, dst: np.ndarray,
                   i: int, w: int, direction: int,
                   max_move_span: int = 0) -> bool:
    """In-place windowed move on ``order`` (int64).  Returns False if
    unavailable.  ``max_move_span`` > 0 caps the travel distance of each
    moved connection (0 = the paper's unbounded scan)."""
    if not available():
        return False
    assert order.dtype == np.int64 and order.flags.c_contiguous
    i64p = ctypes.POINTER(ctypes.c_int64)
    i32p = ctypes.POINTER(ctypes.c_int32)
    _lib.propose_move(
        order.ctypes.data_as(i64p), len(order),
        np.ascontiguousarray(src, np.int32).ctypes.data_as(i32p),
        np.ascontiguousarray(dst, np.int32).ctypes.data_as(i32p),
        i, w, direction, max_move_span,
    )
    return True
