"""Exact I/O simulator for Algorithm 1 (paper §II) under MIN / LRU / RR eviction.

Cost model (paper §II):
  * every connection triple is streamed through fast memory: 1 read-I/O each,
    deleted for free after use (M ≥ 3 reserves one slot for it, so *neuron
    values* occupy at most M-1 slots — cf. the Theorem 2 proof);
  * a neuron-value access that misses fast memory costs 1 read-I/O
    (first access to a non-input neuron reads its bias, first access to an
    input neuron reads the input value, later misses re-read the stored value);
  * evicting a value costs 1 write-I/O iff the eviction must preserve it:
    the value is dirty (slow memory does not hold the current value) AND
    (it will be used again OR it belongs to an output neuron).  Everything
    else is a free deletion — this is the paper's "efficient eviction policy";
  * at the end of the computation every output value must reside in slow
    memory (dirty cached outputs are flushed, 1 write-I/O each).

Policies:
  * MIN  — Belady: evict the value referenced farthest in the future, preferring
           values never referenced again (paper: trivially implementable offline
           once the connection order is fixed).
  * LRU  — least-recently-used.
  * RR   — round-robin pointer over the M-1 slots.

The simulator is granularity-agnostic: a "value" can be a scalar (paper-faithful)
or an activation tile (the TPU block reformulation in ``core/blocksparse.py``).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from .graph import FFNN

INF = np.iinfo(np.int64).max


@dataclasses.dataclass(frozen=True)
class IOStats:
    reads: int
    writes: int

    @property
    def total(self) -> int:
        return self.reads + self.writes


def _build_trace(net: FFNN, order: np.ndarray):
    """Neuron-access trace of Algorithm 1: (src_0, dst_0, src_1, dst_1, ...)."""
    order = np.asarray(order, dtype=np.int64)
    src = net.src[order].astype(np.int64)
    dst = net.dst[order].astype(np.int64)
    trace = np.empty(2 * len(order), dtype=np.int64)
    trace[0::2] = src
    trace[1::2] = dst
    return trace


def _next_use(trace: np.ndarray, n_neurons: int) -> np.ndarray:
    """next_use[t] = next position > t at which trace[t] is accessed (INF if none).

    Vectorized: stable-sort positions by value; within each value group the next
    occurrence is simply the following sorted position.
    """
    T = len(trace)
    order = np.argsort(trace, kind="stable")
    sorted_vals = trace[order]
    nxt_sorted = np.full(T, INF, dtype=np.int64)
    if T > 1:
        same = sorted_vals[:-1] == sorted_vals[1:]
        nxt_sorted[:-1][same] = order[1:][same]
    nxt = np.empty(T, dtype=np.int64)
    nxt[order] = nxt_sorted
    return nxt


def simulate(
    net: FFNN,
    order: np.ndarray,
    M: int,
    policy: str = "min",
    validate_order: bool = False,
    force_python: bool = False,
) -> IOStats:
    """Count exact read/write I/Os of Algorithm 1 for ``order`` with memory ``M``.

    Uses the C accelerator (``_iosim_c``) when available unless
    ``force_python=True``; both paths implement identical semantics and the
    test suite cross-checks them.
    """
    if M < 3:
        raise ValueError("the model requires M >= 3")
    if validate_order and not net.is_topological_connection_order(order):
        raise ValueError("not a topological connection order")
    policy = policy.lower()
    if policy not in ("min", "lru", "rr"):
        raise ValueError(f"unknown eviction policy {policy!r}")

    if not force_python:
        fast = _simulate_fast(net, order, M, policy)
        if fast is not None:
            return fast

    trace_np = _build_trace(net, order)
    T = len(trace_np)
    capacity = M - 1  # one slot stays free for the streamed connection
    n = net.N

    # --- per-neuron state (plain Python lists: ~5x faster scalar access) ------
    trace = trace_np.tolist()
    in_cache = bytearray(n)
    dirty = bytearray(n)
    remaining_uses = np.bincount(trace_np, minlength=n).tolist()
    is_output = net.is_output
    is_output_l = is_output.astype(np.int8).tolist()

    nxt = _next_use(trace_np, n).tolist() if policy == "min" else None
    cur_next_use = [INF] * n if policy == "min" else None

    reads = int(net.W)  # every connection is read exactly once
    writes = 0
    cached = 0

    heappush, heappop = heapq.heappush, heapq.heappop

    if policy == "min":
        heap: list = []  # (-next_use, neuron), lazy invalidation
        for t in range(T):
            v = trace[t]
            if in_cache[v]:
                cur_next_use[v] = nxt[t]
                heappush(heap, (-nxt[t], v))
            else:
                if cached >= capacity:
                    while True:
                        negnu, u = heappop(heap)
                        if in_cache[u] and cur_next_use[u] == -negnu:
                            break
                    if dirty[u] and (remaining_uses[u] > 0 or is_output_l[u]):
                        writes += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cached -= 1
                reads += 1
                in_cache[v] = 1
                cached += 1
                cur_next_use[v] = nxt[t]
                heappush(heap, (-nxt[t], v))
            remaining_uses[v] -= 1
            if t & 1:  # dst access: partial sum updated in fast memory
                dirty[v] = 1
    elif policy == "lru":
        lru_clock = 0
        lru_stamp = [0] * n
        lru_heap: list = []
        for t in range(T):
            v = trace[t]
            lru_clock += 1
            if in_cache[v]:
                lru_stamp[v] = lru_clock
                heappush(lru_heap, (lru_clock, v))
            else:
                if cached >= capacity:
                    while True:
                        stamp, u = heappop(lru_heap)
                        if in_cache[u] and lru_stamp[u] == stamp:
                            break
                    if dirty[u] and (remaining_uses[u] > 0 or is_output_l[u]):
                        writes += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cached -= 1
                reads += 1
                in_cache[v] = 1
                cached += 1
                lru_stamp[v] = lru_clock
                heappush(lru_heap, (lru_clock, v))
            remaining_uses[v] -= 1
            if t & 1:
                dirty[v] = 1
    else:  # rr
        rr_slots = [-1] * capacity
        slot_of = [-1] * n
        rr_ptr = 0
        free_slots = list(range(capacity - 1, -1, -1))
        for t in range(T):
            v = trace[t]
            if not in_cache[v]:
                if cached >= capacity:
                    while True:
                        u = rr_slots[rr_ptr]
                        ptr = rr_ptr
                        rr_ptr = (rr_ptr + 1) % capacity
                        if u >= 0 and in_cache[u]:
                            break
                    if dirty[u] and (remaining_uses[u] > 0 or is_output_l[u]):
                        writes += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cached -= 1
                    rr_slots[ptr] = v
                    slot_of[v] = ptr
                else:
                    s = free_slots.pop()
                    rr_slots[s] = v
                    slot_of[v] = s
                reads += 1
                in_cache[v] = 1
                cached += 1
            remaining_uses[v] -= 1
            if t & 1:
                dirty[v] = 1

    # flush: outputs must reside in slow memory.  Outputs evicted dirty already
    # paid their write inside the eviction branch above.
    in_cache_np = np.frombuffer(bytes(in_cache), dtype=np.int8).astype(bool)
    dirty_np = np.frombuffer(bytes(dirty), dtype=np.int8).astype(bool)
    writes += int((in_cache_np & dirty_np & is_output).sum())
    # output neurons that never appear in the trace (no in/out connections):
    # their bias is read and the activated value written, 1 I/O each.
    untouched = is_output & (np.bincount(trace_np, minlength=n) == 0)
    reads += int(untouched.sum())
    writes += int(untouched.sum())

    return IOStats(reads=reads, writes=writes)


def _simulate_fast(net: FFNN, order: np.ndarray, M: int, policy: str) -> Optional[IOStats]:
    """C-accelerated path; returns None when the accelerator is unavailable."""
    from . import _iosim_c

    if not _iosim_c.available():
        return None
    trace = _build_trace(net, order)
    res = _iosim_c.simulate_c(trace, net.N, M - 1, net.is_output, policy)
    if res is None:
        return None
    miss_reads, evict_writes = res
    reads = int(net.W) + miss_reads
    writes = evict_writes
    untouched = net.is_output & (np.bincount(trace, minlength=net.N) == 0)
    reads += int(untouched.sum())
    writes += int(untouched.sum())
    return IOStats(reads=reads, writes=writes)


def simulate_curve(
    net: FFNN,
    order: np.ndarray,
    Ms: np.ndarray,
    policy: str = "min",
) -> np.ndarray:
    """Total I/Os for a sweep of memory sizes (paper Fig. 3/5)."""
    return np.array([simulate(net, order, int(m), policy).total for m in Ms])


def trace_length(net: FFNN) -> int:
    return 2 * net.W
