"""Exact I/O simulator for Algorithm 1 (paper §II) under MIN / LRU / RR eviction.

Cost model (paper §II):
  * every connection triple is streamed through fast memory: 1 read-I/O each,
    deleted for free after use (M ≥ 3 reserves one slot for it, so *neuron
    values* occupy at most M-1 slots — cf. the Theorem 2 proof);
  * a neuron-value access that misses fast memory costs 1 read-I/O
    (first access to a non-input neuron reads its bias, first access to an
    input neuron reads the input value, later misses re-read the stored value);
  * evicting a value costs 1 write-I/O iff the eviction must preserve it:
    the value is dirty (slow memory does not hold the current value) AND
    (it will be used again OR it belongs to an output neuron).  Everything
    else is a free deletion — this is the paper's "efficient eviction policy";
  * at the end of the computation every output value must reside in slow
    memory (dirty cached outputs are flushed, 1 write-I/O each).

Policies:
  * MIN  — Belady: evict the value referenced farthest in the future, preferring
           values never referenced again (paper: trivially implementable offline
           once the connection order is fixed).
  * LRU  — least-recently-used.
  * RR   — round-robin pointer over the M-1 slots.

The simulator is granularity-agnostic: a "value" can be a scalar (paper-faithful)
or an activation tile (the TPU block reformulation in ``core/blocksparse.py``).
"""

from __future__ import annotations

import dataclasses
import heapq
from bisect import bisect_left, bisect_right
from typing import List, Optional, Tuple

import numpy as np

from .graph import FFNN

INF = np.iinfo(np.int64).max


@dataclasses.dataclass(frozen=True)
class IOStats:
    reads: int
    writes: int

    @property
    def total(self) -> int:
        return self.reads + self.writes


def _build_trace(net: FFNN, order: np.ndarray):
    """Neuron-access trace of Algorithm 1: (src_0, dst_0, src_1, dst_1, ...)."""
    order = np.asarray(order, dtype=np.int64)
    src = net.src[order].astype(np.int64)
    dst = net.dst[order].astype(np.int64)
    trace = np.empty(2 * len(order), dtype=np.int64)
    trace[0::2] = src
    trace[1::2] = dst
    return trace


def _next_use(trace: np.ndarray, n_neurons: int) -> np.ndarray:
    """next_use[t] = next position > t at which trace[t] is accessed (INF if none).

    Vectorized: stable-sort positions by value; within each value group the next
    occurrence is simply the following sorted position.
    """
    T = len(trace)
    order = np.argsort(trace, kind="stable")
    sorted_vals = trace[order]
    nxt_sorted = np.full(T, INF, dtype=np.int64)
    if T > 1:
        same = sorted_vals[:-1] == sorted_vals[1:]
        nxt_sorted[:-1][same] = order[1:][same]
    nxt = np.empty(T, dtype=np.int64)
    nxt[order] = nxt_sorted
    return nxt


def _prev_use(trace: np.ndarray, n_neurons: int) -> np.ndarray:
    """prev_use[t] = last position < t at which trace[t] is accessed (-1 if none)."""
    T = len(trace)
    order = np.argsort(trace, kind="stable")
    sorted_vals = trace[order]
    prv_sorted = np.full(T, -1, dtype=np.int64)
    if T > 1:
        same = sorted_vals[:-1] == sorted_vals[1:]
        prv_sorted[1:][same] = order[:-1][same]
    prv = np.empty(T, dtype=np.int64)
    prv[order] = prv_sorted
    return prv


def simulate(
    net: FFNN,
    order: np.ndarray,
    M: int,
    policy: str = "min",
    validate_order: bool = False,
    force_python: bool = False,
) -> IOStats:
    """Count exact read/write I/Os of Algorithm 1 for ``order`` with memory ``M``.

    Uses the C accelerator (``_iosim_c``) when available unless
    ``force_python=True``; both paths implement identical semantics and the
    test suite cross-checks them.
    """
    if M < 3:
        raise ValueError("the model requires M >= 3")
    if validate_order and not net.is_topological_connection_order(order):
        raise ValueError("not a topological connection order")
    policy = policy.lower()
    if policy not in ("min", "lru", "rr"):
        raise ValueError(f"unknown eviction policy {policy!r}")

    if not force_python:
        fast = _simulate_fast(net, order, M, policy)
        if fast is not None:
            return fast

    trace_np = _build_trace(net, order)
    T = len(trace_np)
    capacity = M - 1  # one slot stays free for the streamed connection
    n = net.N

    # --- per-neuron state (plain Python lists: ~5x faster scalar access) ------
    trace = trace_np.tolist()
    in_cache = bytearray(n)
    dirty = bytearray(n)
    remaining_uses = np.bincount(trace_np, minlength=n).tolist()
    is_output = net.is_output
    is_output_l = is_output.astype(np.int8).tolist()

    nxt = _next_use(trace_np, n).tolist() if policy == "min" else None
    cur_next_use = [INF] * n if policy == "min" else None

    reads = int(net.W)  # every connection is read exactly once
    writes = 0
    cached = 0

    heappush, heappop = heapq.heappush, heapq.heappop

    if policy == "min":
        heap: list = []  # (-next_use, neuron), lazy invalidation
        for t in range(T):
            v = trace[t]
            if in_cache[v]:
                cur_next_use[v] = nxt[t]
                heappush(heap, (-nxt[t], v))
            else:
                if cached >= capacity:
                    while True:
                        negnu, u = heappop(heap)
                        if in_cache[u] and cur_next_use[u] == -negnu:
                            break
                    if dirty[u] and (remaining_uses[u] > 0 or is_output_l[u]):
                        writes += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cached -= 1
                reads += 1
                in_cache[v] = 1
                cached += 1
                cur_next_use[v] = nxt[t]
                heappush(heap, (-nxt[t], v))
            remaining_uses[v] -= 1
            if t & 1:  # dst access: partial sum updated in fast memory
                dirty[v] = 1
    elif policy == "lru":
        lru_clock = 0
        lru_stamp = [0] * n
        lru_heap: list = []
        for t in range(T):
            v = trace[t]
            lru_clock += 1
            if in_cache[v]:
                lru_stamp[v] = lru_clock
                heappush(lru_heap, (lru_clock, v))
            else:
                if cached >= capacity:
                    while True:
                        stamp, u = heappop(lru_heap)
                        if in_cache[u] and lru_stamp[u] == stamp:
                            break
                    if dirty[u] and (remaining_uses[u] > 0 or is_output_l[u]):
                        writes += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cached -= 1
                reads += 1
                in_cache[v] = 1
                cached += 1
                lru_stamp[v] = lru_clock
                heappush(lru_heap, (lru_clock, v))
            remaining_uses[v] -= 1
            if t & 1:
                dirty[v] = 1
    else:  # rr
        rr_slots = [-1] * capacity
        slot_of = [-1] * n
        rr_ptr = 0
        free_slots = list(range(capacity - 1, -1, -1))
        for t in range(T):
            v = trace[t]
            if not in_cache[v]:
                if cached >= capacity:
                    while True:
                        u = rr_slots[rr_ptr]
                        ptr = rr_ptr
                        rr_ptr = (rr_ptr + 1) % capacity
                        if u >= 0 and in_cache[u]:
                            break
                    if dirty[u] and (remaining_uses[u] > 0 or is_output_l[u]):
                        writes += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cached -= 1
                    rr_slots[ptr] = v
                    slot_of[v] = ptr
                else:
                    s = free_slots.pop()
                    rr_slots[s] = v
                    slot_of[v] = s
                reads += 1
                in_cache[v] = 1
                cached += 1
            remaining_uses[v] -= 1
            if t & 1:
                dirty[v] = 1

    # flush: outputs must reside in slow memory.  Outputs evicted dirty already
    # paid their write inside the eviction branch above.
    in_cache_np = np.frombuffer(bytes(in_cache), dtype=np.int8).astype(bool)
    dirty_np = np.frombuffer(bytes(dirty), dtype=np.int8).astype(bool)
    writes += int((in_cache_np & dirty_np & is_output).sum())
    # output neurons that never appear in the trace (no in/out connections):
    # their bias is read and the activated value written, 1 I/O each.
    untouched = is_output & (np.bincount(trace_np, minlength=n) == 0)
    reads += int(untouched.sum())
    writes += int(untouched.sum())

    return IOStats(reads=reads, writes=writes)


def _simulate_fast(net: FFNN, order: np.ndarray, M: int, policy: str) -> Optional[IOStats]:
    """C-accelerated path; returns None when the accelerator is unavailable."""
    from . import _iosim_c

    if not _iosim_c.available():
        return None
    trace = _build_trace(net, order)
    res = _iosim_c.simulate_c(trace, net.N, M - 1, net.is_output, policy)
    if res is None:
        return None
    miss_reads, evict_writes = res
    reads = int(net.W) + miss_reads
    writes = evict_writes
    untouched = net.is_output & (np.bincount(trace, minlength=net.N) == 0)
    reads += int(untouched.sum())
    writes += int(untouched.sum())
    return IOStats(reads=reads, writes=writes)


class IncrementalSimulator:
    """Exact windowed/incremental re-evaluation of the I/O cost under MIN.

    The annealer (``core.reorder``) evaluates thousands of proposals, each a
    *local* permutation of the current order; a full ``simulate()`` per
    proposal is O(W).  This evaluator keeps the baseline simulation's state
    checkpointed and, per proposal, re-simulates only the part of the trace
    the move can actually affect:

      1. diff the candidate against the baseline order -> window [lo, hi];
      2. restart point R: pre-window, the only Belady inputs that change are
         the next-use keys of window-touched neurons, and those keys stay
         inside the window's trace span.  An eviction decision can only flip
         where BOTH the victim's key and the runner-up's key point into the
         window (keys before it still win, keys past it still lose, whatever
         the permutation).  The baseline run records (victim key, runner-up
         key) per eviction, so R = the first such "dangerous" eviction —
         usually the window start itself;
      3. resume the MIN simulation from the latest checkpoint <= R, reading
         next-use values through a window-aware accessor;
      4. stop as soon as the resumed cache state reconverges with a baseline
         checkpoint past the window (capacity is M-1 tiles, so reconvergence
         is typically immediate) and splice the baseline's suffix cost.

    The returned totals are *exactly* ``simulate(net, cand, M, "min").total``
    — validated in tests — at O(window + affected-suffix) cost instead of
    O(W).  ``commit()`` adopts the last proposed order by splicing the
    baseline structures (trace, next-use chains, access positions,
    checkpoints, eviction records) in O(window) plus O(#checkpoints).  The
    re-simulated segments run through the C accelerator (``_iosim_c``) when
    available, with the pure-Python runner as the reference fallback.

    Only the MIN policy is supported: LRU/RR recency state does not admit
    the same cheap convergence argument.  ``connection_reordering`` falls
    back to full simulation for those policies.
    """

    def __init__(self, net: FFNN, order: np.ndarray, M: int,
                 policy: str = "min", stride: Optional[int] = None):
        if M < 3:
            raise ValueError("the model requires M >= 3")
        if policy.lower() != "min":
            raise ValueError("IncrementalSimulator supports only the MIN policy")
        self.net = net
        self.M = M
        self.capacity = M - 1
        T = 2 * net.W
        if stride is None:
            stride = max(32, (T // 256) & ~1)
        if stride % 2:
            raise ValueError("stride must be even (trace parity)")
        self.stride = stride
        self.heavy_stride = stride * 16
        self._is_out_np = np.ascontiguousarray(net.is_output.astype(np.uint8))
        self._is_output_l = net.is_output.astype(np.int8).tolist()
        self._untouched: Optional[int] = None
        self._pending = None
        from . import _iosim_c
        self._c = _iosim_c
        self._use_c = _iosim_c.available()
        self._rebuild(np.ascontiguousarray(order, dtype=np.int64))

    # -- public API ---------------------------------------------------------
    @property
    def total(self) -> int:
        """Total I/Os of the current baseline order."""
        return self._total

    def propose(self, cand: np.ndarray) -> int:
        """Exact total I/Os of candidate order ``cand`` (not adopted)."""
        cand = np.ascontiguousarray(cand, dtype=np.int64)
        diff = np.nonzero(cand != self.order)[0]
        if len(diff) == 0:
            self._pending = None
            return self._total
        lo, hi = int(diff[0]), int(diff[-1])
        t_lo, t_hi_end = 2 * lo, 2 * hi + 2
        win = cand[lo:hi + 1]
        wtr = np.empty(2 * len(win), dtype=np.int64)
        wtr[0::2] = self.net.src[win]
        wtr[1::2] = self.net.dst[win]
        wtr_l = wtr.tolist()
        # window structures, vectorized: per-neuron access positions, the
        # in-window next-use chain (candidate coordinates), the first access
        # past the window per neuron ("after"), and the last pre-window
        # access per neuron (whose next-use key must be overridden).  The
        # old window holds the same neuron multiset, so its sorted grouping
        # aligns with the candidate's; that turns both boundary lookups into
        # plain gathers from next_use/prev_use.  The python loop below runs
        # once per *distinct* window neuron, not per access.
        L = len(wtr)
        wn = _next_use(wtr, self.net.N)
        wnxt = np.where(wn == INF, np.int64(0), wn + np.int64(t_lo))
        su = np.argsort(wtr, kind="stable")
        sv = wtr[su]
        cuts = np.nonzero(sv[1:] != sv[:-1])[0] + 1
        grp_starts = np.concatenate([[0], cuts])
        grp_ends = np.concatenate([cuts, [L]])
        pos_glob = su + t_lo
        old_tr = self.trace[t_lo:t_hi_end]
        osu = np.argsort(old_tr, kind="stable")
        osv = old_tr[osu]
        ocuts = np.nonzero(osv[1:] != osv[:-1])[0] + 1
        ostarts = np.concatenate([[0], ocuts])
        oends = np.concatenate([ocuts, [L]])
        after_vals = self.next_use[osu[oends - 1] + t_lo]
        ov_pos = self.prev_use[osu[ostarts] + t_lo]   # -1 where none
        ov_val = pos_glob[grp_starts]                 # first candidate access
        wnxt[pos_glob[grp_ends - 1] - t_lo] = after_vals
        win_pos: dict = {}
        for a, b in zip(grp_starts.tolist(), grp_ends.tolist()):
            win_pos[int(sv[a])] = pos_glob[a:b].tolist()
        # danger-based restart point (see class docstring, step 2)
        R = t_lo
        if len(self._ev_t):
            m = int(np.searchsorted(self._ev_t, t_lo))
            if m:
                k1, k2 = self._ev_k1[:m], self._ev_k2[:m]
                danger = ((k1 >= t_lo) & (k1 < t_hi_end)
                          & (k2 >= t_lo) & (k2 < t_hi_end))
                hits = np.nonzero(danger)[0]
                if len(hits):
                    R = int(self._ev_t[hits[0]])
        ki = bisect_right(self._ckpt_times, R) - 1
        runner = self._run_min_c if self._use_c else self._run_min
        total, new_ckpts, ev_rows, conv_at, dr, dw = runner(
            ki, t_lo, t_hi_end, wtr, wnxt, win_pos, ov_pos, ov_val)
        self._pending = (cand, t_lo, t_hi_end, wtr, wtr_l, win_pos,
                         ki, new_ckpts, ev_rows, conv_at, dr, dw, total,
                         (pos_glob, sv, grp_starts, grp_ends, after_vals,
                          ov_pos))
        return total

    def commit(self) -> None:
        """Adopt the last proposed order as the new baseline (O(window))."""
        if self._pending is None:
            return
        (cand, t_lo, t_hi_end, wtr, wtr_l, win_pos,
         ki, new_ckpts, ev_rows, conv_at, dr, dw, total,
         grp) = self._pending
        pos_glob, sv, grp_starts, grp_ends, after_vals, ov_pos = grp
        self._pending = None
        self.order = cand
        # 1. splice the trace
        self.trace[t_lo:t_hi_end] = wtr
        self.trace_l[t_lo:t_hi_end] = wtr_l
        # 2. splice per-neuron access positions (same count per neuron: the
        #    window holds the same connections, permuted)
        ap, astart = self.acc_pos_l, self.acc_start_l
        for v, lst in win_pos.items():
            s, e = astart[v], astart[v + 1]
            i0 = bisect_left(ap, t_lo, s, e)
            i1 = bisect_left(ap, t_hi_end, s, e)
            ap[i0:i1] = lst
        # 3. re-chain next-use / prev-use through the window, vectorized
        #    over the sorted (neuron, position) grouping from propose()
        nxt_np, prv_np = self.next_use, self.prev_use
        same = sv[:-1] == sv[1:]
        aidx = pos_glob[:-1][same]
        bidx = pos_glob[1:][same]
        nxt_np[aidx] = bidx
        prv_np[bidx] = aidx
        last_pos = pos_glob[grp_ends - 1]
        first_pos = pos_glob[grp_starts]
        nxt_np[last_pos] = after_vals
        fin = after_vals != INF
        prv_np[after_vals[fin]] = last_pos[fin]
        prv_np[first_pos] = ov_pos
        live = ov_pos >= 0
        nxt_np[ov_pos[live]] = first_pos[live]
        if not self._use_c:
            # keep the list mirror the pure-Python runner reads
            nl = self.next_use_l
            for i, val in zip(aidx.tolist(), bidx.tolist()):
                nl[i] = val
            for i, val in zip(last_pos.tolist(), after_vals.tolist()):
                nl[i] = val
            for i, val in zip(ov_pos[live].tolist(), first_pos[live].tolist()):
                nl[i] = val
        # 3. splice light checkpoints: prefix (valid: decisions before the
        #    restart point are provably identical) + those recorded during
        #    the resumed run + the baseline tail past the convergence point
        #    with cumulative counters shifted by the run's read/write delta
        t0 = self._ckpts[ki][0]
        if conv_at is not None:
            kp = bisect_left(self._ckpt_times, conv_at)
            tail = [(t, c, d, cr + dr, cw + dw)
                    for (t, c, d, cr, cw) in self._ckpts[kp:]]
            self._ckpts = self._ckpts[:ki + 1] + new_ckpts + tail
        else:
            self._ckpts = self._ckpts[:ki + 1] + new_ckpts
        self._ckpt_times = [c[0] for c in self._ckpts]
        self._ckpt_index = {t: i for i, t in enumerate(self._ckpt_times)}
        # 4. recompute heavy checkpoints invalidated by the window
        n = self.net.N
        for th in sorted(self._heavy):
            if t_lo < th < t_hi_end:
                tprev = max(t for t in self._heavy if t <= t_lo)
                rem = self._heavy[tprev].copy()
                rem -= np.bincount(self.trace[tprev:th],
                                   minlength=n).astype(rem.dtype)
                self._heavy[th] = rem
        # 5. eviction records: prefix keys that pointed into the permuted
        #    window are stale (the neuron's next access moved) — recompute
        #    from the spliced access positions (key at an eviction == first
        #    access of the neuron past the eviction time); then splice
        i0 = int(np.searchsorted(self._ev_t, t0))
        for karr, varr in ((self._ev_k1, self._ev_v1),
                           (self._ev_k2, self._ev_v2)):
            stale = np.nonzero((karr[:i0] >= t_lo) & (karr[:i0] < t_hi_end))[0]
            for j in stale.tolist():
                v = int(varr[j])
                s, e = astart[v], astart[v + 1]
                i = bisect_left(ap, int(self._ev_t[j]), s, e)
                karr[j] = ap[i] if i < e else INF
        parts = [np.stack([self._ev_t[:i0], self._ev_k1[:i0],
                           self._ev_k2[:i0], self._ev_v1[:i0],
                           self._ev_v2[:i0]], axis=1)]
        parts.extend(ev_rows)
        if conv_at is not None:
            ic = int(np.searchsorted(self._ev_t, conv_at))
            parts.append(np.stack([self._ev_t[ic:], self._ev_k1[ic:],
                                   self._ev_k2[ic:], self._ev_v1[ic:],
                                   self._ev_v2[ic:]], axis=1))
        self._set_ev(np.concatenate(parts, axis=0))
        self._total = total

    # -- internals ----------------------------------------------------------
    def _set_ev(self, ev: np.ndarray) -> None:
        ev = np.asarray(ev, dtype=np.int64).reshape(-1, 5)
        self._ev_t = np.ascontiguousarray(ev[:, 0])
        self._ev_k1 = np.ascontiguousarray(ev[:, 1])
        self._ev_k2 = np.ascontiguousarray(ev[:, 2])
        self._ev_v1 = np.ascontiguousarray(ev[:, 3])
        self._ev_v2 = np.ascontiguousarray(ev[:, 4])

    def _first_base_at_or_after(self, v: int, t: int) -> int:
        ap, astart = self.acc_pos_l, self.acc_start_l
        s, e = astart[v], astart[v + 1]
        i = bisect_left(ap, t, s, e)
        return ap[i] if i < e else INF

    def _record_ckpt(self, t: int, in_cache: np.ndarray, dirty: np.ndarray,
                     r: int, w: int):
        cset = tuple(int(v) for v in np.nonzero(in_cache)[0])
        dset = frozenset(int(v) for v in np.nonzero(in_cache & dirty)[0])
        return (t, cset, dset, int(r), int(w))

    def _rebuild(self, order: np.ndarray) -> None:
        """Full baseline MIN simulation with checkpoint recording (O(W))."""
        net = self.net
        n = net.N
        self.order = order
        trace = _build_trace(net, order)
        self.trace = trace
        T = len(trace)
        self.T = T
        self.trace_l = trace.tolist()
        self.next_use = _next_use(trace, n)
        self.next_use_l = self.next_use.tolist()
        self.prev_use = _prev_use(trace, n)
        idx = np.argsort(trace, kind="stable")
        counts = np.bincount(trace, minlength=n)
        starts = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=starts[1:])
        self.acc_pos_l = idx.tolist()
        self.acc_start_l = starts.tolist()
        if self._untouched is None:
            self._untouched = int((net.is_output & (counts == 0)).sum())

        in_cache = np.zeros(n, dtype=np.uint8)
        dirty = np.zeros(n, dtype=np.uint8)
        remaining = counts.astype(np.int64)
        out = np.zeros(3, dtype=np.int64)
        ckpts: List[Tuple] = []
        heavy = {}
        ev_parts: List[np.ndarray] = []
        stride = self.stride
        if self._use_c:
            t = 0
            while t < T:
                if t % self.heavy_stride == 0:
                    heavy[t] = remaining.copy()
                ckpts.append(self._record_ckpt(t, in_cache, dirty,
                                               out[0], out[1]))
                cached_ids = np.nonzero(in_cache)[0].astype(np.int64)
                cached_nu = np.array(
                    [self._first_base_at_or_after(int(v), t)
                     for v in cached_ids], dtype=np.int64)
                t_next = min(T, t + stride)
                seg = trace[t:t_next]
                ev_out = np.empty(5 * len(seg), dtype=np.int64)
                ok = self._c.resume_min_segment_c(
                    seg, self.next_use[t:t_next], n, self.capacity,
                    self._is_out_np, in_cache, dirty, remaining,
                    cached_ids, cached_nu, ev_out, out)
                if not ok:  # accelerator died mid-flight: start over in python
                    self._use_c = False
                    self._rebuild(order)
                    return
                rows = ev_out[:5 * int(out[2])].reshape(-1, 5).copy()
                rows[:, 0] += t
                ev_parts.append(rows)
                t = t_next
            reads, writes = int(out[0]), int(out[1])
            flush = int((in_cache.astype(bool) & dirty.astype(bool)
                         & net.is_output).sum())
            ev = (np.concatenate(ev_parts, axis=0) if ev_parts
                  else np.empty((0, 5), dtype=np.int64))
        else:
            reads, writes, flush, ckpts, heavy, ev = self._rebuild_py(
                counts.tolist())
        self._ckpts = ckpts
        self._ckpt_times = [c[0] for c in ckpts]
        self._ckpt_index = {t: i for i, t in enumerate(self._ckpt_times)}
        self._heavy = heavy
        self._set_ev(ev)
        u = self._untouched
        self._total = int(net.W + reads + u + writes + flush + u)

    def _rebuild_py(self, remaining: list):
        """Pure-Python baseline pass (reference path, no C accelerator)."""
        net = self.net
        n = net.N
        T = self.T
        trace_l = self.trace_l
        nxt = self.next_use_l
        is_out = self._is_output_l
        capacity = self.capacity
        stride = self.stride
        in_cache = bytearray(n)
        dirty = bytearray(n)
        cur_next_use = [INF] * n
        cache_set: set = set()
        heap: list = []
        heappush, heappop = heapq.heappush, heapq.heappop
        reads = writes = cached = 0
        ckpts: List[Tuple] = []
        heavy = {}
        ev_rec: List[Tuple[int, int, int, int, int]] = []
        for t in range(T):
            if t % stride == 0:
                cset = tuple(cache_set)
                dset = frozenset(v for v in cset if dirty[v])
                ckpts.append((t, cset, dset, reads, writes))
                if t % self.heavy_stride == 0:
                    heavy[t] = np.array(remaining, dtype=np.int64)
            v = trace_l[t]
            if in_cache[v]:
                cur_next_use[v] = nxt[t]
                heappush(heap, (-nxt[t], v))
            else:
                if cached >= capacity:
                    while True:
                        negnu, u = heappop(heap)
                        if in_cache[u] and cur_next_use[u] == -negnu:
                            break
                    if dirty[u] and (remaining[u] > 0 or is_out[u]):
                        writes += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cache_set.discard(u)
                    cached -= 1
                    # runner-up key: discard stale heap tops, then peek
                    while heap:
                        negnu2, u2 = heap[0]
                        if in_cache[u2] and cur_next_use[u2] == -negnu2:
                            break
                        heappop(heap)
                    if heap:
                        ev_rec.append((t, -negnu, -heap[0][0], u, heap[0][1]))
                    else:
                        ev_rec.append((t, -negnu, -1, u, -1))
                reads += 1
                in_cache[v] = 1
                cache_set.add(v)
                cached += 1
                cur_next_use[v] = nxt[t]
                heappush(heap, (-nxt[t], v))
            remaining[v] -= 1
            if t & 1:
                dirty[v] = 1
        flush = sum(1 for v in cache_set if dirty[v] and is_out[v])
        ev = (np.array(ev_rec, dtype=np.int64).reshape(-1, 5) if ev_rec
              else np.empty((0, 5), dtype=np.int64))
        return reads, writes, flush, ckpts, heavy, ev

    def _remaining_at(self, t0: int) -> np.ndarray:
        """Per-neuron remaining-use counts entering trace position t0."""
        th = (t0 // self.heavy_stride) * self.heavy_stride
        while th not in self._heavy:
            th -= self.heavy_stride
        rem = self._heavy[th].copy()
        if th < t0:
            rem -= np.bincount(self.trace[th:t0],
                               minlength=self.net.N).astype(rem.dtype)
        return rem

    def _first_cand_at_or_after(self, v: int, t: int, t_lo: int,
                                t_hi_end: int, win_pos: dict) -> int:
        """First access of ``v`` at-or-after ``t`` under the candidate order
        (``t`` must be <= t_lo or >= t_hi_end — never inside the window)."""
        if t >= t_hi_end:
            return self._first_base_at_or_after(v, t)
        p = self._first_base_at_or_after(v, t)
        if p < t_lo:
            return p
        lst = win_pos.get(v)
        if lst is not None:
            return lst[0]
        return p  # >= t_hi_end (window positions only exist for win neurons)

    # -- C-accelerated resumed run -----------------------------------------
    def _run_min_c(self, ki: int, t_lo: int, t_hi_end: int,
                   wtr: np.ndarray, wnxt: np.ndarray, win_pos: dict,
                   ov_pos: np.ndarray, ov_val: np.ndarray):
        net = self.net
        n = net.N
        T = self.T
        t0, cached0, dirty0, r0, w0 = self._ckpts[ki]
        in_cache = np.zeros(n, dtype=np.uint8)
        dirty = np.zeros(n, dtype=np.uint8)
        if cached0:
            in_cache[list(cached0)] = 1
        if dirty0:
            dirty[list(dirty0)] = 1
        remaining = self._remaining_at(t0)
        out = np.zeros(3, dtype=np.int64)
        out[0], out[1] = r0, w0
        new_ckpts: List[Tuple] = []
        ev_rows: List[np.ndarray] = []

        def run_seg(trace_seg, nxt_seg, seg_start):
            if not len(trace_seg):
                return True
            cached_ids = np.nonzero(in_cache)[0].astype(np.int64)
            cached_nu = np.array(
                [self._first_cand_at_or_after(int(v), seg_start, t_lo,
                                              t_hi_end, win_pos)
                 for v in cached_ids], dtype=np.int64)
            ev_out = np.empty(5 * len(trace_seg), dtype=np.int64)
            ok = self._c.resume_min_segment_c(
                np.ascontiguousarray(trace_seg), np.ascontiguousarray(nxt_seg),
                n, self.capacity, self._is_out_np, in_cache, dirty,
                remaining, cached_ids, cached_nu, ev_out, out)
            if ok:
                rows = ev_out[:5 * int(out[2])].reshape(-1, 5).copy()
                rows[:, 0] += seg_start
                ev_rows.append(rows)
            return ok

        # pre-window segment: the last pre-window access of each window
        # neuron has a next-use key pointing into the window — redirect it
        # to the neuron's first candidate window position
        ok = True
        if t0 < t_lo:
            nxt_seg = self.next_use[t0:t_lo].copy()
            live = ov_pos >= t0
            nxt_seg[ov_pos[live] - t0] = ov_val[live]
            ok = run_seg(self.trace[t0:t_lo], nxt_seg, t0)
            if ok:
                new_ckpts.append(self._record_ckpt(t_lo, in_cache, dirty,
                                                   out[0], out[1]))
        # the window itself
        if ok:
            ok = run_seg(wtr, wnxt, t_lo)
        # post-window chunks, ending at baseline checkpoint times so the
        # convergence comparison can splice the baseline suffix cost
        if ok:
            times = self._ckpt_times
            j = bisect_right(times, t_hi_end)
            t = t_hi_end
            while t < T:
                ci = self._ckpt_index.get(t)
                if ci is not None and t >= t_hi_end and t > t0:
                    _, bc, bd, br, bw = self._ckpts[ci]
                    if len(bc) == int(in_cache.sum()) and \
                            all(in_cache[u] for u in bc) and \
                            all(bool(dirty[u]) == (u in bd) for u in bc):
                        total = self._total + int(out[0] - br) + \
                            int(out[1] - bw)
                        return (total, new_ckpts, ev_rows, t,
                                int(out[0] - br), int(out[1] - bw))
                new_ckpts.append(self._record_ckpt(t, in_cache, dirty,
                                                   out[0], out[1]))
                t_next = times[j] if j < len(times) else T
                j += 1
                if t_next <= t:
                    continue
                ok = run_seg(self.trace[t:t_next], self.next_use[t:t_next], t)
                if not ok:
                    break
                t = t_next
        if not ok:  # accelerator failure: fall back to the reference runner
            self._use_c = False
            self.next_use_l = self.next_use.tolist()  # refresh the mirror
            return self._run_min(ki, t_lo, t_hi_end, wtr, wnxt, win_pos)
        flush = int((in_cache.astype(bool) & dirty.astype(bool)
                     & net.is_output).sum())
        u_ = self._untouched
        total = int(net.W + out[0] + u_ + out[1] + flush + u_)
        return (total, new_ckpts, ev_rows, None,
                int(out[0] - r0), int(out[1] - w0))

    # -- pure-Python resumed run (reference path) ---------------------------
    def _run_min(self, ki: int, t_lo: int, t_hi_end: int,
                 wtr: np.ndarray, wnxt_np: np.ndarray, win_pos: dict,
                 ov_pos: Optional[np.ndarray] = None,
                 ov_val: Optional[np.ndarray] = None):
        """Resume the MIN simulation from checkpoint ``ki`` under the
        candidate trace; returns (total, new_ckpts, ev_rows, converged_at,
        dr, dw).  Pre-window next-use overrides are resolved lazily here, so
        ``ov_pos``/``ov_val`` are accepted for signature parity and unused."""
        net = self.net
        n = net.N
        T = self.T
        stride = self.stride
        t0, cached0, dirty0, r0, w0 = self._ckpts[ki]
        r, w = r0, w0
        trace_l = self.trace_l
        next_use_l = self.next_use_l
        ap, astart = self.acc_pos_l, self.acc_start_l
        is_out = self._is_output_l
        capacity = self.capacity
        ckpts = self._ckpts
        ckpt_index = self._ckpt_index
        wtr_l = wtr.tolist()
        wnxt = wnxt_np.tolist()

        def nxt_after(t: int, v: int) -> int:
            """Next access of ``v`` strictly after ``t`` under the candidate
            order; only called for t < t_lo."""
            nu = next_use_l[t] if t >= 0 and trace_l[t] == v else -1
            if nu >= 0:
                if nu < t_lo or v not in win_pos:
                    return nu
                return win_pos[v][0]
            s, e = astart[v], astart[v + 1]
            i = bisect_right(ap, t, s, e)
            if i < e and ap[i] < t_lo:
                return ap[i]
            lst = win_pos.get(v)
            if lst is not None:
                return lst[0]
            i = bisect_left(ap, t_hi_end, s, e)
            return ap[i] if i < e else INF

        in_cache = bytearray(n)
        dirty = bytearray(n)
        for v in cached0:
            in_cache[v] = 1
        for v in dirty0:
            dirty[v] = 1
        cache_set = set(cached0)
        cached = len(cached0)
        remaining = self._remaining_at(t0).tolist()
        cur_next_use = [INF] * n
        heap: list = []
        heappush, heappop = heapq.heappush, heapq.heappop
        for v in cached0:
            nu = self._first_cand_at_or_after(v, t0, t_lo, t_hi_end, win_pos)
            cur_next_use[v] = nu
            heappush(heap, (-nu, v))

        new_ckpts: List[Tuple] = []
        ev_rec: List[Tuple[int, int, int, int, int]] = []
        t = t0
        while t < T:
            if t % stride == 0 and t > t0:
                if t >= t_hi_end:
                    ci = ckpt_index.get(t)
                    if ci is not None:
                        _, bc, bd, br, bw = ckpts[ci]
                        if len(bc) == cached and \
                                all(in_cache[u] for u in bc) and \
                                all((dirty[u] == 1) == (u in bd) for u in bc):
                            # cache state reconverged with the baseline: the
                            # remaining suffix costs exactly what it cost there
                            total = self._total + (r - br) + (w - bw)
                            ev = (np.array(ev_rec, np.int64).reshape(-1, 5)
                                  if ev_rec else np.empty((0, 5), np.int64))
                            return total, new_ckpts, [ev], t, r - br, w - bw
                cset = tuple(cache_set)
                dset = frozenset(u for u in cset if dirty[u])
                new_ckpts.append((t, cset, dset, r, w))
            if t >= t_hi_end:
                v = trace_l[t]
                nu = next_use_l[t]
            elif t >= t_lo:
                v = wtr_l[t - t_lo]
                nu = wnxt[t - t_lo]
            else:
                v = trace_l[t]
                nu = nxt_after(t, v)
            if in_cache[v]:
                cur_next_use[v] = nu
                heappush(heap, (-nu, v))
            else:
                if cached >= capacity:
                    while True:
                        negnu, u = heappop(heap)
                        if in_cache[u] and cur_next_use[u] == -negnu:
                            break
                    if dirty[u] and (remaining[u] > 0 or is_out[u]):
                        w += 1
                        dirty[u] = 0
                    in_cache[u] = 0
                    cache_set.discard(u)
                    cached -= 1
                    while heap:
                        negnu2, u2 = heap[0]
                        if in_cache[u2] and cur_next_use[u2] == -negnu2:
                            break
                        heappop(heap)
                    if heap:
                        ev_rec.append((t, -negnu, -heap[0][0], u, heap[0][1]))
                    else:
                        ev_rec.append((t, -negnu, -1, u, -1))
                r += 1
                in_cache[v] = 1
                cache_set.add(v)
                cached += 1
                cur_next_use[v] = nu
                heappush(heap, (-nu, v))
            remaining[v] -= 1
            if t & 1:
                dirty[v] = 1
            t += 1
        flush = sum(1 for u in cache_set if dirty[u] and is_out[u])
        u_ = self._untouched
        total = int(net.W + r + u_ + w + flush + u_)
        ev = (np.array(ev_rec, np.int64).reshape(-1, 5) if ev_rec
              else np.empty((0, 5), np.int64))
        return total, new_ckpts, [ev], None, r - r0, w - w0


def simulate_curve(
    net: FFNN,
    order: np.ndarray,
    Ms: np.ndarray,
    policy: str = "min",
) -> np.ndarray:
    """Total I/Os for a sweep of memory sizes (paper Fig. 3/5)."""
    return np.array([simulate(net, order, int(m), policy).total for m in Ms])


def trace_length(net: FFNN) -> int:
    return 2 * net.W
