"""FFNN-as-DAG representation (paper §II).

An FFNN is a weighted DAG given as a list of connection triples ``(i, j, w_ij)``
plus one value per vertex: the input value for input neurons and the bias for
non-input neurons.  Inference (Algorithm 1) processes the connections in a
*topological order of the connections* — whenever the output neuron of ``e_i``
is the input neuron of ``e_j`` we must have ``i < j``.

This module holds the graph container, topological-order utilities (including
the 2-optimal Theorem-1 order and the layer-by-layer order the paper compares
against), a reference forward pass used to check that reordering preserves the
computed function, and the random generator from Appendix A.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

Activation = Callable[[np.ndarray], np.ndarray]


def relu(x: np.ndarray) -> np.ndarray:
    return np.maximum(x, 0.0)


@dataclasses.dataclass
class FFNN:
    """Sparse FFNN given as connection triples over a DAG.

    Attributes:
      n_neurons: total number of neurons N (inputs + hidden + outputs).
      src, dst:  int32 arrays of shape [W] — connection endpoints.
      weight:    float32 array of shape [W].
      is_input:  bool [N] — input neurons (their ``bias`` slot holds the input value
                 during a concrete forward pass; for I/O analysis only the count I matters).
      is_output: bool [N] — output neurons (their values must be written back).
      bias:      float32 [N] — bias for non-input neurons, input value for inputs.
    """

    n_neurons: int
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray
    is_input: np.ndarray
    is_output: np.ndarray
    bias: np.ndarray

    # ---- size aliases matching the paper's notation -------------------------
    @property
    def N(self) -> int:
        return int(self.n_neurons)

    @property
    def W(self) -> int:
        return int(len(self.src))

    @property
    def I(self) -> int:  # noqa: E743 — paper notation
        return int(self.is_input.sum())

    @property
    def S(self) -> int:
        return int(self.is_output.sum())

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.weight = np.asarray(self.weight, dtype=np.float32)
        self.is_input = np.asarray(self.is_input, dtype=bool)
        self.is_output = np.asarray(self.is_output, dtype=bool)
        self.bias = np.asarray(self.bias, dtype=np.float32)

    # ---- structure ----------------------------------------------------------
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.N).astype(np.int64)

    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.N).astype(np.int64)

    def neuron_topo_order(self) -> np.ndarray:
        """Kahn topological order of the neurons; raises on cycles."""
        indeg = self.in_degree()
        # adjacency in CSR-ish form
        order_by_src = np.argsort(self.src, kind="stable")
        sorted_src = self.src[order_by_src]
        starts = np.searchsorted(sorted_src, np.arange(self.N))
        ends = np.searchsorted(sorted_src, np.arange(self.N) + 1)
        out = np.empty(self.N, dtype=np.int64)
        head = 0
        stack = list(np.flatnonzero(indeg == 0))
        k = 0
        while stack:
            n = stack.pop()
            out[k] = n
            k += 1
            for e in order_by_src[starts[n]:ends[n]]:
                d = int(self.dst[e])
                indeg[d] -= 1
                if indeg[d] == 0:
                    stack.append(d)
        if k != self.N:
            raise ValueError("graph has a cycle — not an FFNN DAG")
        head = k  # noqa: F841  (kept for symmetry/debuggability)
        return out

    def validate(self) -> None:
        if (self.is_input & self.is_output).any():
            raise ValueError("a neuron cannot be both input and output")
        if self.in_degree()[self.is_input].sum() != 0:
            raise ValueError("input neurons must have no incoming connections")
        self.neuron_topo_order()  # raises on cycles

    # ---- topological orders of the connections ------------------------------
    def is_topological_connection_order(self, order: np.ndarray) -> bool:
        """Check: for connections e_i before e_j, dst(e_i) == src(e_j) ⇒ i < j."""
        order = np.asarray(order)
        if sorted(order.tolist()) != list(range(self.W)):
            return False
        # position of each connection in the order
        pos = np.empty(self.W, dtype=np.int64)
        pos[order] = np.arange(self.W)
        # for each neuron: latest position at which it is produced (appears as dst)
        # must precede the earliest position at which it is consumed (appears as src).
        last_prod = np.full(self.N, -1, dtype=np.int64)
        np.maximum.at(last_prod, self.dst, pos)
        first_cons = np.full(self.N, np.iinfo(np.int64).max, dtype=np.int64)
        np.minimum.at(first_cons, self.src, pos)
        return bool(np.all(last_prod < first_cons))

    def theorem1_order(self) -> np.ndarray:
        """The 2-optimal order from the proof of Theorem 1.

        Fix a topological order of the non-input neurons and reorder the
        connections so their *output* neurons appear in that order — the order
        is partitioned into one contiguous interval per non-input neuron.

        We use the (layer, id) topological order, which for layered nets is
        exactly the paper's initial order (Appendix A: "we order the
        connections layer-by-layer with respect to their output neuron").
        """
        layer = self.layers_longest_path()
        topo_pos = layer * (self.N + 1) + np.arange(self.N)
        return np.argsort(topo_pos[self.dst], kind="stable")

    def layer_order(self, layer_of: Optional[np.ndarray] = None) -> np.ndarray:
        """Layer-after-layer order (the 'standard' matrix-vector order, §II.A).

        Sorts connections by the layer of their output neuron; within a layer by
        *source* neuron — the column-major access of a matrix-vector product.
        """
        if layer_of is None:
            layer_of = self.layers_longest_path()
        return np.lexsort((self.src, layer_of[self.dst]))

    def layers_longest_path(self) -> np.ndarray:
        """Layer index = longest path from any input (0 for inputs)."""
        topo = self.neuron_topo_order()
        layer = np.zeros(self.N, dtype=np.int64)
        pos = np.empty(self.N, dtype=np.int64)
        pos[topo] = np.arange(self.N)
        order = np.argsort(pos[self.src], kind="stable")
        for e in order:
            s, d = int(self.src[e]), int(self.dst[e])
            if layer[s] + 1 > layer[d]:
                layer[d] = layer[s] + 1
        return layer

    # ---- reference execution -------------------------------------------------
    def forward(
        self,
        x: Optional[np.ndarray] = None,
        order: Optional[np.ndarray] = None,
        activation: Activation = relu,
    ) -> np.ndarray:
        """Reference forward pass following Algorithm 1's update rule.

        ``x`` (shape [I]) overrides the stored input values.  Returns the values
        of the output neurons (in increasing neuron-id order).  Processing in any
        topological connection order yields the same result — used by tests to
        show CR preserves the function.
        """
        vals = self.bias.astype(np.float64).copy()
        if x is not None:
            vals[self.is_input] = np.asarray(x, dtype=np.float64)
        if order is None:
            order = self.theorem1_order()
        remaining = self.in_degree()
        # inputs and in-degree-0 non-inputs are complete from the start
        complete = remaining == 0
        act = activation
        for e in order:
            s, d = int(self.src[e]), int(self.dst[e])
            if not complete[s]:
                raise ValueError("order is not topological: consumed incomplete neuron")
            vals[d] += self.weight[e] * vals[s]
            remaining[d] -= 1
            if remaining[d] == 0:
                vals[d] = act(np.asarray(vals[d]))
                complete[d] = True
        return vals[self.is_output].astype(np.float32)


def drop_isolated(net: FFNN) -> FFNN:
    """Remove neurons with no connections at all (dead units from pruning).

    Theorem 1 assumes a *connected* FFNN; block-magnitude pruning can leave
    tiles with neither incoming nor outgoing blocks.  The kernel still
    bias-patches them (they are dead code); the I/O analysis drops them."""
    deg = net.in_degree() + net.out_degree()
    keep = (deg > 0) | net.is_output
    if keep.all():
        return net
    new_id = np.cumsum(keep) - 1
    return FFNN(
        n_neurons=int(keep.sum()),
        src=new_id[net.src], dst=new_id[net.dst], weight=net.weight,
        is_input=net.is_input[keep], is_output=net.is_output[keep],
        bias=net.bias[keep],
    )


def partition_columns_balanced(loads: Sequence[int], parts: int) -> np.ndarray:
    """Assign columns to ``parts`` equal-count groups, balancing total load.

    The sharded engine partitions each layer's block-columns (output tiles)
    across the ``model`` axis of a device mesh.  ``shard_map`` needs every
    shard to hold the *same number* of columns (uniform per-device shapes),
    but throughput is governed by the heaviest shard's *load* (SparseNN:
    load balance across partitions, not total traffic, bounds end-to-end
    speed) — so within the equal-count constraint we balance the summed
    per-column loads (nnz blocks) with greedy LPT: columns in decreasing
    load order, each to the least-loaded shard that still has capacity.

    Returns ``assign`` (int64 [n_cols]) with values in [0, parts).
    Deterministic: ties break on column id, then shard id.  Raises unless
    ``n_cols`` is divisible by ``parts``.
    """
    loads = np.asarray(loads, dtype=np.int64)
    n = len(loads)
    if parts < 1:
        raise ValueError(f"parts must be >= 1, got {parts}")
    if n % parts:
        raise ValueError(
            f"cannot split {n} block-columns into {parts} equal shards; "
            "column count must be divisible by the model-axis size"
        )
    cap = n // parts
    assign = np.empty(n, dtype=np.int64)
    shard_load = np.zeros(parts, dtype=np.int64)
    shard_fill = np.zeros(parts, dtype=np.int64)
    # decreasing load, increasing column id on ties (stable sort of -loads)
    for c in np.argsort(-loads, kind="stable"):
        open_ = np.flatnonzero(shard_fill < cap)
        s = open_[np.argmin(shard_load[open_])]
        assign[c] = s
        shard_load[s] += loads[c]
        shard_fill[s] += 1
    return assign


# ------------------------------------------------------------------------------
# Constructors
# ------------------------------------------------------------------------------


def from_layer_sizes(
    sizes: Sequence[int],
    masks: Sequence[np.ndarray],
    weights: Optional[Sequence[np.ndarray]] = None,
    biases: Optional[Sequence[np.ndarray]] = None,
    seed: int = 0,
) -> FFNN:
    """Build a layered FFNN from per-layer-pair boolean masks.

    ``masks[k]`` has shape (sizes[k], sizes[k+1]) — True where a connection exists.
    """
    rng = np.random.default_rng(seed)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    n = int(offsets[-1])
    src_l, dst_l, w_l = [], [], []
    for k, mask in enumerate(masks):
        assert mask.shape == (sizes[k], sizes[k + 1])
        i, j = np.nonzero(mask)
        src_l.append(i + offsets[k])
        dst_l.append(j + offsets[k + 1])
        if weights is not None:
            w_l.append(weights[k][i, j])
        else:
            w_l.append(rng.standard_normal(len(i)) / np.sqrt(max(1, sizes[k])))
    src = np.concatenate(src_l) if src_l else np.zeros(0, np.int32)
    dst = np.concatenate(dst_l) if dst_l else np.zeros(0, np.int32)
    w = np.concatenate(w_l) if w_l else np.zeros(0, np.float32)
    is_input = np.zeros(n, bool)
    is_input[: sizes[0]] = True
    is_output = np.zeros(n, bool)
    is_output[offsets[-2]:] = True
    bias = rng.standard_normal(n).astype(np.float32) * 0.1
    if biases is not None:
        for k, b in enumerate(biases):
            bias[offsets[k + 1]: offsets[k + 2]] = b
    bias[is_input] = rng.standard_normal(int(is_input.sum())).astype(np.float32)
    return FFNN(n, src, dst, w, is_input, is_output, bias)


def random_ffnn(width: int, depth: int, density: float, seed: int = 0) -> FFNN:
    """Random sparse MLP per Appendix A.

    ``depth`` hidden+input layers of ``width`` neurons each, plus one output
    neuron.  For each non-output neuron draw k ~ U{1, max(1, ceil(2·p·next − 1))}
    outgoing connections to random neurons of the next layer.
    """
    rng = np.random.default_rng(seed)
    sizes = [width] * depth + [1]
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    n = int(offsets[-1])
    src_l, dst_l = [], []
    for k in range(len(sizes) - 1):
        nxt = sizes[k + 1]
        kmax = max(1, int(np.ceil(2.0 * density * nxt - 1)))
        for u in range(sizes[k]):
            kk = int(rng.integers(1, kmax + 1))
            kk = min(kk, nxt)
            targets = rng.choice(nxt, size=kk, replace=False)
            src_l.append(np.full(kk, offsets[k] + u, dtype=np.int64))
            dst_l.append(targets + offsets[k + 1])
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    w = (rng.standard_normal(len(src)) / np.sqrt(width)).astype(np.float32)
    is_input = np.zeros(n, bool)
    is_input[:width] = True
    is_output = np.zeros(n, bool)
    is_output[-1] = True
    bias = (rng.standard_normal(n) * 0.1).astype(np.float32)
    net = FFNN(n, src, dst, w, is_input, is_output, bias)
    return net


def from_dense_weights(
    weights: Sequence[np.ndarray],
    density: float,
    seed: int = 0,
) -> FFNN:
    """Magnitude-prune a stack of dense layer weights to ``density`` and wrap as FFNN.

    This is the paper's BERT experiment path: take W1 (1024×4096), W2 (4096×1024),
    keep the largest-|w| fraction per matrix, build the sparse DAG.
    """
    masks, sizes = [], [weights[0].shape[0]]
    for wmat in weights:
        sizes.append(wmat.shape[1])
        k = max(1, int(round(density * wmat.size)))
        thresh = np.partition(np.abs(wmat).ravel(), -k)[-k]
        masks.append(np.abs(wmat) >= thresh)
    return from_layer_sizes(sizes, masks, weights=list(weights), seed=seed)
