"""Block-granular reformulation of the paper's I/O model for the TPU hierarchy.

The paper's model is scalar; a TPU moves 128-aligned tiles between HBM and VMEM
and multiplies them on a 128x128 MXU.  Everything in the paper survives the
substitution {neuron value -> activation tile, connection -> nonzero weight
block, fast memory of M words -> VMEM budget of M tiles}:

  * a sparse layer weight matrix becomes a BSR matrix; each nonzero block
    (bi, bj) is a "connection" from input tile bi to output tile bj;
  * stacking layers gives a *block DAG* — an FFNN in the paper's exact sense
    whose "neurons" are activation tiles; `to_block_ffnn` builds it;
  * `FFNN.theorem1_order` on the block DAG is the 2-optimal schedule (grouped
    by output tile: each output tile is VMEM-resident for one contiguous grid
    interval, so partial sums never spill — writes = #output tiles);
  * `core.reorder.connection_reordering` on the block DAG is Connection
    Reordering of the *kernel grid schedule*, with the exact simulated tile
    traffic (``core.iosim.simulate``) as objective;
  * the resulting order is exported as flat schedule arrays for the Pallas
    kernel (`kernels/bsr_matmul.py`) via `schedule_arrays`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .graph import FFNN
from .iosim import simulate


@dataclasses.dataclass
class BSRLayer:
    """One block-sparse layer: y = act(x @ W + b) with W in BSR form."""

    n_in: int                  # input features
    n_out: int                 # output features
    block_m: int               # input-tile size (rows of W blocks)
    block_n: int               # output-tile size (cols of W blocks)
    rows: np.ndarray           # int32 [nnz_blocks] input-tile index
    cols: np.ndarray           # int32 [nnz_blocks] output-tile index
    blocks: np.ndarray         # float32 [nnz_blocks, block_m, block_n]
    bias: np.ndarray           # float32 [n_out]

    @property
    def grid_in(self) -> int:
        return self.n_in // self.block_m

    @property
    def grid_out(self) -> int:
        return self.n_out // self.block_n

    @property
    def nnz_blocks(self) -> int:
        return int(len(self.rows))

    def to_dense(self) -> np.ndarray:
        w = np.zeros((self.n_in, self.n_out), dtype=self.blocks.dtype)
        bm, bn = self.block_m, self.block_n
        for r, c, b in zip(self.rows, self.cols, self.blocks):
            w[r * bm:(r + 1) * bm, c * bn:(c + 1) * bn] = b
        return w


def to_bsr(
    w: np.ndarray,
    block_m: int = 128,
    block_n: int = 128,
    density: Optional[float] = None,
    bias: Optional[np.ndarray] = None,
) -> BSRLayer:
    """Cluster an (optionally already-sparse) dense matrix into BSR blocks.

    If ``density`` is given, keep the top fraction of blocks by Frobenius mass
    (block-magnitude pruning — the block-granular analogue of the paper's
    magnitude pruning); otherwise keep all blocks with any nonzero.
    """
    n_in, n_out = w.shape
    if n_in % block_m or n_out % block_n:
        raise ValueError("matrix dims must be multiples of the block size")
    gi, go = n_in // block_m, n_out // block_n
    tiles = w.reshape(gi, block_m, go, block_n).transpose(0, 2, 1, 3)
    mass = np.sqrt((tiles.astype(np.float64) ** 2).sum(axis=(2, 3)))
    if density is not None:
        k = max(1, int(round(density * gi * go)))
        thresh = np.partition(mass.ravel(), -k)[-k]
        mask = mass >= thresh
    else:
        mask = mass > 0
    rows, cols = np.nonzero(mask)
    blocks = tiles[rows, cols].astype(np.float32)
    if bias is None:
        bias = np.zeros(n_out, dtype=np.float32)
    return BSRLayer(
        n_in=n_in, n_out=n_out, block_m=block_m, block_n=block_n,
        rows=rows.astype(np.int32), cols=cols.astype(np.int32),
        blocks=blocks, bias=np.asarray(bias, dtype=np.float32),
    )


@dataclasses.dataclass
class BlockFFNN:
    """A stack of BSR layers viewed as the paper's FFNN over activation tiles."""

    layers: List[BSRLayer]
    net: FFNN                    # block DAG: neurons = tiles, connections = blocks
    conn_layer: np.ndarray       # [Wb] which layer each block-connection belongs to
    conn_block: np.ndarray       # [Wb] index into that layer's rows/cols/blocks


def to_block_ffnn(layers: Sequence[BSRLayer]) -> BlockFFNN:
    """Build the block DAG.  Tile numbering: layer-0 input tiles first, then each
    layer's output tiles."""
    for a, b in zip(layers[:-1], layers[1:]):
        if a.n_out != b.n_in or a.block_n != b.block_m:
            raise ValueError("layer tile shapes must chain")
    offsets = [0, layers[0].grid_in]
    for l in layers:
        offsets.append(offsets[-1] + l.grid_out)
    n = offsets[-1]
    src_l, dst_l, lay_l, blk_l = [], [], [], []
    for k, l in enumerate(layers):
        src_l.append(l.rows.astype(np.int64) + offsets[k])
        dst_l.append(l.cols.astype(np.int64) + offsets[k + 1])
        lay_l.append(np.full(l.nnz_blocks, k, dtype=np.int32))
        blk_l.append(np.arange(l.nnz_blocks, dtype=np.int64))
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    is_input = np.zeros(n, bool)
    is_input[: layers[0].grid_in] = True
    is_output = np.zeros(n, bool)
    is_output[offsets[-2]:] = True
    net = FFNN(
        n_neurons=n, src=src, dst=dst,
        weight=np.ones(len(src), dtype=np.float32),
        is_input=is_input, is_output=is_output,
        bias=np.zeros(n, dtype=np.float32),
    )
    return BlockFFNN(
        layers=list(layers), net=net,
        conn_layer=np.concatenate(lay_l),
        conn_block=np.concatenate(blk_l),
    )


def schedule_arrays(bffnn: BlockFFNN, order: np.ndarray, layer: int):
    """Export a (possibly reordered) block schedule for one layer's Pallas kernel.

    Returns (perm, row_ids, col_ids, first_visit, last_visit):
      * perm        — permutation of the layer's block storage into schedule order,
      * row/col ids — input/output tile per grid step,
      * first/last  — 1 where the grid step is the first/last visiting its output
                      tile (first -> initialize accumulator with zeros; last ->
                      the tile's value is final after this step).
    The Theorem-1 order makes every output tile's visits contiguous, which is
    what lets the kernel keep the accumulator in VMEM between steps.
    """
    sel = np.asarray(order)[bffnn.conn_layer[np.asarray(order)] == layer]
    blk = bffnn.conn_block[sel]
    lay = bffnn.layers[layer]
    rows = lay.rows[blk].astype(np.int32)
    cols = lay.cols[blk].astype(np.int32)
    nsteps = len(blk)
    first = np.zeros(nsteps, dtype=np.int32)
    last = np.zeros(nsteps, dtype=np.int32)
    seen: dict = {}
    for t, c in enumerate(cols):
        if int(c) not in seen:
            first[t] = 1
        seen[int(c)] = t
    for c, t in seen.items():
        last[t] = 1
    # a correct schedule for the revisit-kernel requires contiguous visits
    return blk.astype(np.int32), rows, cols, first, last


def regroup_by_output(net: FFNN, order: np.ndarray) -> np.ndarray:
    """Stable-regroup a connection order by output neuron, ranking groups by
    their *last* appearance; the internal order within groups is preserved
    (keeps CR's input-locality gains kernel-compatible).

    Ranking by last appearance keeps the result topological: for any edge
    B -> A, every B-incoming connection precedes the consuming connection in
    the input order, so last(B) < last(A) and group B lands wholly before
    group A — i.e. the group sequence is a topological order of the neurons,
    which is exactly the Theorem-1 family."""
    order = np.asarray(order)
    dst = net.dst[order]
    last_seen: dict = {}
    for idx, d in enumerate(dst):
        last_seen[int(d)] = idx
    group_rank = np.array([last_seen[int(d)] for d in dst])
    return order[np.argsort(group_rank, kind="stable")]


def is_contiguous_by_output(cols: np.ndarray) -> bool:
    """True iff every output tile's visits form one contiguous run."""
    seen = set()
    prev = None
    for c in cols:
        c = int(c)
        if c != prev and c in seen:
            return False
        seen.add(c)
        prev = c
    return True


def simulated_tile_traffic(bffnn: BlockFFNN, order: np.ndarray, M_tiles: int,
                           policy: str = "min"):
    """Exact simulated HBM<->VMEM tile transfers for a block schedule — the
    paper's I/O count at tile granularity (used as the CR objective and in
    the §Perf kernel-schedule hillclimb)."""
    return simulate(bffnn.net, order, M_tiles, policy)
