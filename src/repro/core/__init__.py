"""Core library: the paper's contribution (I/O model, bounds, CR, CG, block form).

Paper: "A Theory of I/O-Efficient Sparse Neural Network Inference"
(Gleinig, Ben-Nun, Hoefler — ETH Zürich, 2023).
"""

from .graph import FFNN, from_dense_weights, from_layer_sizes, random_ffnn, relu
from .iosim import IOStats, simulate, simulate_curve
from .bounds import Bounds, theorem1_bounds
from .reorder import ReorderResult, connection_reordering, propose
from .compact_growth import CompactGrown, bandwidth, bandwidth_order, generate
from .blocksparse import (
    BSRLayer,
    BlockFFNN,
    is_contiguous_by_output,
    regroup_by_output,
    schedule_arrays,
    simulated_tile_traffic,
    to_block_ffnn,
    to_bsr,
)

__all__ = [
    "FFNN", "from_dense_weights", "from_layer_sizes", "random_ffnn", "relu",
    "IOStats", "simulate", "simulate_curve",
    "Bounds", "theorem1_bounds",
    "ReorderResult", "connection_reordering", "propose",
    "CompactGrown", "bandwidth", "bandwidth_order", "generate",
    "BSRLayer", "BlockFFNN", "is_contiguous_by_output", "schedule_arrays",
    "simulated_tile_traffic", "to_block_ffnn", "to_bsr",
]
