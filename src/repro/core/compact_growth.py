"""Compact Growth (paper §V) — constructive generation of I/O-optimal FFNNs.

The pebble/bag construction (Theorem 2): starting from an empty FFNN and an
empty bag (= fast memory), apply steps of four types
  1) add a gray or black pebble (<= M-2 pebbles present): read a neuron,
  2) draw a connection black -> gray: one multiply-accumulate,
  3) turn gray -> black: apply the activation,
  4) remove a black pebble: delete from fast memory,
and the resulting FFNN admits inference with exactly N + W reads and S writes
for memory size M — and *every* FFNN admitting that is constructible this way.

``generate`` implements the randomized generator of Appendix B; the returned
``order`` is the connection order induced by the construction, which achieves
the lower bound when simulated with M >= M_g.  ``bandwidth_order`` implements
Corollary 1: any FFNN of bandwidth k is compact-growable with M = k + 2.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .graph import FFNN


@dataclasses.dataclass
class CompactGrown:
    net: FFNN
    order: np.ndarray   # connection order induced by the construction
    M_g: int            # memory size the net was grown for


def generate(
    M_g: int,
    n_iters: int = 1000,
    in_degree: int = 5,
    seed: int = 0,
) -> CompactGrown:
    """Appendix-B generator.

    Start with M_g - 2 computed (black) input pebbles in the bag.  Each of the
    ``n_iters`` iterations: add a new neuron (gray pebble), draw incoming
    connections from ``in_degree`` random bag members, remove the last of those
    members from the bag.  Finally add one output neuron connected from all
    remaining bag members.
    """
    if M_g < 3:
        raise ValueError("M_g >= 3 required")
    rng = np.random.default_rng(seed)
    n_inputs = M_g - 2
    bag = list(range(n_inputs))          # black pebbles (computed neurons)
    src_l, dst_l = [], []
    next_id = n_inputs
    for _ in range(n_iters):
        new = next_id
        next_id += 1
        k = min(in_degree, len(bag))
        picks = rng.choice(len(bag), size=k, replace=False)
        for p in picks:
            src_l.append(bag[p])
            dst_l.append(new)
        # remove the last of the chosen neurons from the bag, then the new
        # neuron (now fully computed -> black) joins the bag.
        evicted = bag[picks[-1]]
        bag.remove(evicted)
        bag.append(new)
    out = next_id
    next_id += 1
    for b in bag:
        src_l.append(b)
        dst_l.append(out)

    n = next_id
    src = np.array(src_l, dtype=np.int32)
    dst = np.array(dst_l, dtype=np.int32)
    w = (rng.standard_normal(len(src)) / np.sqrt(max(1, in_degree))).astype(np.float32)
    is_input = np.zeros(n, bool)
    is_input[:n_inputs] = True
    is_output = np.zeros(n, bool)
    is_output[out] = True
    bias = (rng.standard_normal(n) * 0.1).astype(np.float32)
    net = FFNN(n, src, dst, w, is_input, is_output, bias)
    # construction order == creation order of the connections
    order = np.arange(net.W, dtype=np.int64)
    return CompactGrown(net=net, order=order, M_g=M_g)


def bandwidth(net: FFNN, neuron_order: Optional[np.ndarray] = None) -> int:
    """Bandwidth w.r.t. a topological neuron order (default: Kahn order):
    max distance in the order between the endpoints of any connection."""
    if neuron_order is None:
        neuron_order = net.neuron_topo_order()
    pos = np.empty(net.N, dtype=np.int64)
    pos[neuron_order] = np.arange(net.N)
    if net.W == 0:
        return 0
    return int(np.max(pos[net.dst] - pos[net.src]))


def bandwidth_order(net: FFNN, neuron_order: Optional[np.ndarray] = None) -> Tuple[np.ndarray, int]:
    """Corollary 1: with M = bandwidth + 2, the order 'connections sorted by the
    position of their output neuron' achieves the lower bound.  Returns
    (connection_order, required_M)."""
    if neuron_order is None:
        neuron_order = net.neuron_topo_order()
    pos = np.empty(net.N, dtype=np.int64)
    pos[neuron_order] = np.arange(net.N)
    k = bandwidth(net, neuron_order)
    order = np.lexsort((pos[net.src], pos[net.dst]))
    return order.astype(np.int64), k + 2
