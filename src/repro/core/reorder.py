"""Connection Reordering (paper §IV) — simulated annealing over topological orders.

Neighbor moves (paper §IV.A): pick a random connection e_i and window size
w ~ U{0..ws-1}; the window is e_i..e_{min(i+w, W)}.  With prob. 0.5 move the
window's connections left, else right:

  * left:  starting from the *leftmost*, move each connection left until a
    connection with the same input neuron, or whose output neuron equals our
    input neuron, is found; insert right after it (or at the very beginning).
  * right: starting from the *rightmost*, move each connection right until a
    connection with the same output neuron, or whose input neuron equals our
    output neuron, is found; insert right before it (or at the very end).

Both moves preserve topological validity: moving left never crosses the
producer of the moved connection's input; moving right never crosses a
consumer of its output.

Update rule (§IV.B): always accept improvements; accept a non-improvement with
probability 2^{-(newIOs - oldIOs) * t^sigma} at iteration t.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from .graph import FFNN
from .iosim import IncrementalSimulator, IOStats, simulate


@dataclasses.dataclass
class ReorderResult:
    order: np.ndarray          # best order found
    ios: int                   # total I/Os of best order
    initial_ios: int
    history: np.ndarray        # accepted-order I/Os per iteration (len T+1)
    accepted: int
    proposed: int


def propose(
    order: List[int],
    src,
    dst,
    ws: int,
    rng: np.random.Generator,
    max_move_span: int = 0,
) -> List[int]:
    """One windowed left/right move; returns a new order (input not mutated).

    ``src``/``dst`` may be numpy arrays or plain lists; lists are ~4x faster
    for the scan loops below.
    """
    W = len(order)
    i = int(rng.integers(0, W))
    w = int(rng.integers(0, max(1, ws)))
    direction = 0 if rng.random() < 0.5 else 1
    return _apply_move(list(order), src, dst, i, w, direction, max_move_span)


def _apply_move(new: List[int], src, dst, i: int, w: int, direction: int,
                span: int = 0) -> List[int]:
    """Apply the windowed move in place on list ``new`` and return it.

    ``span`` > 0 caps how far any connection travels: the anchor scan stops
    after ``span`` positions and inserts there.  Cutting the scan short is
    always topologically safe — the shortened move crosses only connections
    already checked conflict-free (the full move's validity argument applies
    to every prefix of the scan).
    """
    W = len(new)
    j = min(i + w, W - 1)
    if direction == 0:
        # move window members left, starting from the leftmost (position i).
        # after each removal+reinsert, the window's remaining members shift
        # by at most the insertion; we track positions explicitly.
        for k in range(i, j + 1):
            pos = k  # current position of the connection to move
            e = new[pos]
            a = src[e]
            p = pos - 1
            lo = -1 if span <= 0 else max(-1, pos - span - 1)
            while p > lo:
                f = new[p]
                if src[f] == a or dst[f] == a:
                    break
                p -= 1
            # insert right after p
            if p + 1 != pos:
                new.pop(pos)
                new.insert(p + 1, e)
    else:
        # move window members right, starting from the rightmost (position j).
        for k in range(j, i - 1, -1):
            pos = k
            e = new[pos]
            b = dst[e]
            p = pos + 1
            hi = W if span <= 0 else min(W, pos + span + 1)
            while p < hi:
                f = new[p]
                if dst[f] == b or src[f] == b:
                    break
                p += 1
            # insert right before p
            if p - 1 != pos:
                new.pop(pos)
                new.insert(p - 1, e)
    return new


def connection_reordering(
    net: FFNN,
    order: np.ndarray,
    M: int,
    policy: str = "min",
    T: int = 20_000,
    sigma: float = 0.2,
    ws: Optional[int] = None,
    seed: int = 0,
    callback: Optional[Callable[[int, int, int], None]] = None,
    incremental: Optional[bool] = None,
    max_move_span: Optional[int] = None,
) -> ReorderResult:
    """Run Connection Reordering for ``T`` iterations.

    ``ws`` defaults to four times the average in-degree (paper §VI.A.1).
    ``callback(t, cur_ios, best_ios)`` is invoked every iteration if given.

    ``incremental`` selects the windowed delta evaluator
    (:class:`core.iosim.IncrementalSimulator`): each proposal is charged
    O(window + affected suffix) instead of a full O(W) re-simulation.  The
    delta totals are exact, so results are bit-identical to the full path
    for the same seed.  Default (None): on for the MIN policy, off for
    LRU/RR (whose recency state does not admit the cheap convergence
    splice).  Forcing ``incremental=True`` with a non-MIN policy raises.

    ``max_move_span`` (None/0 = the paper's unbounded scan) caps how far a
    proposal may carry any connection.  The paper's moves travel to the
    nearest dependency, which on 10k+-block DAGs makes the changed window —
    and hence the cost of even the *incremental* delta evaluation —
    arbitrarily large; a cap keeps every proposal's changed window (and its
    re-simulated suffix) O(ws + span).  Capped moves remain topologically
    valid (any prefix of the anchor scan is), so the result stays inside
    the Theorem-1 family after regrouping.
    """
    from . import _iosim_c

    if incremental is None:
        incremental = policy.lower() == "min"
    span = int(max_move_span or 0)
    if span < 0:
        raise ValueError(f"max_move_span must be >= 0, got {span}")
    rng = np.random.default_rng(seed)
    if ws is None:
        avg_in = net.W / max(1, net.N - net.I)
        ws = max(1, int(round(4 * avg_in)))
    use_c = _iosim_c.available()
    src32 = np.ascontiguousarray(net.src, dtype=np.int32)
    dst32 = np.ascontiguousarray(net.dst, dtype=np.int32)
    src_l = dst_l = None
    if not use_c:
        src_l, dst_l = net.src.tolist(), net.dst.tolist()

    cur = np.ascontiguousarray(order, dtype=np.int64).copy()
    inc_sim = IncrementalSimulator(net, cur, M, policy) if incremental else None
    cur_ios = inc_sim.total if inc_sim is not None \
        else simulate(net, cur, M, policy).total
    best = cur.copy()
    best_ios = cur_ios
    initial = cur_ios
    history = np.empty(T + 1, dtype=np.int64)
    history[0] = cur_ios
    accepted = 0
    W = net.W

    for t in range(1, T + 1):
        # identical proposal randomness on both paths
        i = int(rng.integers(0, W))
        w = int(rng.integers(0, max(1, ws)))
        direction = 0 if rng.random() < 0.5 else 1
        if use_c:
            cand = cur.copy()
            _iosim_c.propose_move_c(cand, src32, dst32, i, w, direction, span)
        else:
            cand = np.array(
                _apply_move(cur.tolist(), src_l, dst_l, i, w, direction,
                            span),
                dtype=np.int64,
            )
        ios = inc_sim.propose(cand) if inc_sim is not None \
            else simulate(net, cand, M, policy).total
        if ios < cur_ios:
            accept = True
        else:
            accept = bool(rng.random() < 2.0 ** (-(ios - cur_ios) * (t ** sigma)))
        if accept:
            cur, cur_ios = cand, ios
            accepted += 1
            if inc_sim is not None:
                inc_sim.commit()
            if ios < best_ios:
                best, best_ios = cand.copy(), ios
        history[t] = cur_ios
        if callback is not None:
            callback(t, cur_ios, best_ios)

    return ReorderResult(
        order=best,
        ios=int(best_ios),
        initial_ios=int(initial),
        history=history,
        accepted=accepted,
        proposed=T,
    )
