"""Theorem 1 bounds and the witness constructions of Propositions 1–2.

Theorem 1 (connected FFNN, M >= 3):
    W + N + S  <=  IOs(N, M)  <=  2 (W + N - I)
    W + N      <=  rIOs(N, M) <=  2 W + N - I
    S          <=  wIOs(N, M) <=  N - I
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .graph import FFNN, from_layer_sizes


@dataclasses.dataclass(frozen=True)
class Bounds:
    reads_lo: int
    reads_hi: int
    writes_lo: int
    writes_hi: int

    @property
    def total_lo(self) -> int:
        return self.reads_lo + self.writes_lo

    @property
    def total_hi(self) -> int:
        # Theorem 1 upper bound: 2 (W + N - I) = (2W + N - I) + (N - I)
        return self.reads_hi + self.writes_hi


def theorem1_bounds(net: FFNN) -> Bounds:
    W, N, I, S = net.W, net.N, net.I, net.S
    return Bounds(
        reads_lo=W + N,
        reads_hi=2 * W + N - I,
        writes_lo=S,
        writes_hi=N - I,
    )


# ------------------------------------------------------------------------------
# Witnesses (used by tests to check tightness, mirroring Lemmas 1-3 / Prop. 2)
# ------------------------------------------------------------------------------


def lemma1_net(M: int, depth: int = 4, seed: int = 0) -> FFNN:
    """Layered FFNN where consecutive layers fit in M-1 slots: attains the lower
    bound exactly (Lemma 1)."""
    width = max(1, (M - 1) // 2)
    sizes = [width] * depth
    rng = np.random.default_rng(seed)
    masks = [rng.random((sizes[k], sizes[k + 1])) < 0.5 for k in range(depth - 1)]
    for m in masks:  # keep connected: every row/col has an entry
        m[np.arange(m.shape[0]), np.arange(m.shape[0]) % m.shape[1]] = True
        m[np.arange(m.shape[1]) % m.shape[0], np.arange(m.shape[1])] = True
    return from_layer_sizes(sizes, masks, seed=seed)


def lemma2_net(n_inputs: int, seed: int = 0) -> FFNN:
    """Star: I inputs -> 1 output.  IOs = 2 (W + N - I) exactly (Lemma 2)."""
    mask = np.ones((n_inputs, 1), dtype=bool)
    return from_layer_sizes([n_inputs, 1], [mask], seed=seed)


def lemma3_net(n_inputs: int, hidden: int, n_outputs: int, seed: int = 0) -> FFNN:
    """I inputs, one hidden layer of h, S outputs with S >> h: wIOs ≈ N - I (Lemma 3)."""
    rng = np.random.default_rng(seed)
    m1 = rng.random((n_inputs, hidden)) < 0.5
    m1[:, 0] = True
    m1[0, :] = True
    m2 = rng.random((hidden, n_outputs)) < 0.5
    m2[:, 0] = True
    m2[0, :] = True
    return from_layer_sizes([n_inputs, hidden, n_outputs], [m1, m2], seed=seed)


def proposition2_net(M: int, c: int, seed: int = 0) -> FFNN:
    """2M parallel chains of length c between one input and one output neuron.

    Layer-after-layer inference needs >= M·c write-I/Os; chain-after-chain needs
    exactly 1 temporary-free schedule (S=1 write).  (Proposition 2.)
    """
    chains = 2 * M
    sizes = [1] + [chains] * c + [1]
    masks = []
    masks.append(np.ones((1, chains), dtype=bool))
    eye = np.eye(chains, dtype=bool)
    for _ in range(c - 1):
        masks.append(eye)
    masks.append(np.ones((chains, 1), dtype=bool))
    return from_layer_sizes(sizes, masks, seed=seed)


def chain_order(net: FFNN) -> np.ndarray:
    """Chain-after-chain connection order for ``proposition2_net`` (DFS from input)."""
    # depth-first topological order over connections: follow each chain to the end.
    order_by_src = np.argsort(net.src, kind="stable")
    sorted_src = net.src[order_by_src]
    starts = np.searchsorted(sorted_src, np.arange(net.N))
    ends = np.searchsorted(sorted_src, np.arange(net.N) + 1)
    remaining_in = net.in_degree()
    out: list = []
    # process one chain at a time: for each first-layer edge, walk the chain
    roots = np.flatnonzero(net.is_input)
    stack = []
    for r in roots:
        for e in order_by_src[starts[r]:ends[r]][::-1]:
            stack.append(int(e))
    seen_edge = np.zeros(net.W, dtype=bool)
    while stack:
        e = stack.pop()
        if seen_edge[e]:
            continue
        seen_edge[e] = True
        out.append(e)
        d = int(net.dst[e])
        remaining_in[d] -= 1
        if remaining_in[d] == 0:
            for e2 in order_by_src[starts[d]:ends[d]][::-1]:
                stack.append(int(e2))
    assert len(out) == net.W, "graph not fully reachable from inputs"
    return np.array(out, dtype=np.int64)
