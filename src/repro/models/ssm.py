"""Mamba2 — state-space duality (SSD) block, chunked (arXiv:2405.21060).

The SSD recurrence  h_t = a_t·h_{t-1} + (dt_t x_t) ⊗ B_t,  y_t = C_t·h_t + D·x_t
is computed in matrix form over chunks of length Q: a quadratic intra-chunk
term (attention-like, masked by the decay kernel) plus an inter-chunk state
carried by a lax.scan — O(S·Q) instead of O(S²), and O(1) per decode step.

Layout: d_inner = expand·d_model split into H = d_inner/P heads of dim P;
B, C are single-group [*, N] (G=1).  A short causal depthwise conv precedes
x, B, C as in the reference implementation.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init, rms_norm, split_keys
from .config import ModelConfig
from .sharding import dp, shard, tp


def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = split_keys(key, 4)
    conv_ch = di + 2 * N
    return {
        # in_proj packs [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), dtype=dtype),
        "conv": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    Bm = zxbcdt[..., 2 * di:2 * di + N]
    Cm = zxbcdt[..., 2 * di + N:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, x, Bm, Cm, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along S.  xbc: [B, S, Ch]; w: [Kw, Ch]."""
    Kw = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (Kw - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(Kw):
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def ssd_chunked(x, dt, A_log, Bm, Cm, cfg: ModelConfig,
                h0: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x: [B, S, H, P]; dt: [B, S, H] (post-softplus); Bm, Cm: [B, S, N].
    Returns (y [B, S, H, P], h_final [B, H, P, N]).
    """
    B, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    S_orig = S
    if S % Q:
        # pad with dt = 0 steps: decay a = exp(0) = 1 and zero input, so the
        # state passes through unchanged and padded outputs are discarded.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    a_log = (-jnp.exp(A_log)[None, None] * dt).astype(jnp.float32)   # [B, S, H]

    xc = x.reshape(B, nc, Q, H, Pd)
    dtc = dt.reshape(B, nc, Q, H)
    alc = a_log.reshape(B, nc, Q, H)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)

    L = jnp.cumsum(alc, axis=2)                                       # [B,nc,Q,H]
    Ltot = L[:, :, -1]                                                # [B,nc,H]

    # intra-chunk: scores[t,s] = (C_t·B_s) exp(L_t - L_s) dt_s for t >= s
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc,
                    preferred_element_type=jnp.float32)               # [B,nc,Q,Q]
    decay = L[:, :, :, None, :] - L[:, :, None, :, :]                 # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    scores = jnp.where(tri[None, None, :, :, None],
                       jnp.exp(decay) * cb[..., None], 0.0)
    scores = scores * dtc[:, :, None, :, :]                           # dt_s factor
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", scores,
                         xc.astype(jnp.float32))

    # per-chunk outgoing state: S_c = sum_s exp(Ltot - L_s) dt_s x_s B_s^T
    w_out = jnp.exp(Ltot[:, :, None] - L) * dtc                       # [B,nc,Q,H]
    chunk_state = jnp.einsum("bcqh,bcqhp,bcqn->bchpn",
                             w_out, xc.astype(jnp.float32), Bc.astype(jnp.float32))

    # inter-chunk scan over nc
    if h0 is None:
        h0 = jnp.zeros((B, H, Pd, N), jnp.float32)

    def body(h, inp):
        st, ltot = inp                                                # [B,H,P,N], [B,H]
        h_prev = h
        h = jnp.exp(ltot)[:, :, None, None] * h + st
        return h, h_prev

    (h_fin, h_prevs) = jax.lax.scan(
        body, h0, (chunk_state.transpose(1, 0, 2, 3, 4), Ltot.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                        # [B,nc,H,P,N]

    # inter-chunk contribution: y_t += C_t · (exp(L_t) h_prev)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc.astype(jnp.float32), jnp.exp(L), h_prevs)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)[:, :S_orig]
    return y.astype(x.dtype), h_fin


def ssm_block(params: Dict, u: jnp.ndarray, cfg: ModelConfig,
              cache: Optional[Dict] = None) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """One Mamba2 block.  u: [B, S, d].  With ``cache`` and S == 1: decode step.

    cache = {"conv": [B, Kw-1, Ch], "state": [B, H, P, N]}.
    """
    B, S, d = u.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,dn->bsn", u, params["in_proj"])
    zxbcdt = shard(zxbcdt, dp(), None, tp())
    z, xr, Bm, Cm, dtr = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xr, Bm, Cm], axis=-1)

    if cache is not None and S == 1:
        # ---- decode: O(1) state update --------------------------------------
        Kw = cfg.ssm_conv
        window = jnp.concatenate([cache["conv"], xbc], axis=1)       # [B,Kw,Ch]
        conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                              params["conv"].astype(jnp.float32))
        conv_out = jax.nn.silu(conv_out + params["conv_bias"].astype(jnp.float32))
        xr1 = conv_out[:, :di].reshape(B, H, Pd)
        Bm1 = conv_out[:, di:di + N]
        Cm1 = conv_out[:, di + N:]
        dt1 = jax.nn.softplus(dtr[:, 0].astype(jnp.float32)
                              + params["dt_bias"])                    # [B,H]
        a = jnp.exp(-jnp.exp(params["A_log"])[None] * dt1)            # [B,H]
        h = cache["state"]
        h = a[:, :, None, None] * h + jnp.einsum(
            "bh,bhp,bn->bhpn", dt1, xr1.astype(jnp.float32),
            Bm1.astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Cm1.astype(jnp.float32), h)
        y = y + params["D"][None, :, None] * xr1.astype(jnp.float32)
        y = y.reshape(B, 1, di)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = rms_norm(y.astype(u.dtype), params["norm"], cfg.norm_eps)
        out = jnp.einsum("bsn,nd->bsd", y, params["out_proj"])
        new_cache = {"conv": window[:, 1:], "state": h}
        return out, new_cache

    # ---- full sequence -------------------------------------------------------
    xbc = _causal_conv(xbc, params["conv"], params["conv_bias"])
    xr = xbc[..., :di].reshape(B, S, H, Pd)
    Bm = xbc[..., di:di + N]
    Cm = xbc[..., di + N:]
    dtf = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])
    y, h_fin = ssd_chunked(xr, dtf, params["A_log"], Bm, Cm, cfg)
    y = y.reshape(B, S, di)
    y = (y.astype(jnp.float32) + (params["D"][None, None, :, None]
                                  * xr.astype(jnp.float32)).reshape(B, S, di))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(u.dtype), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bsn,nd->bsd", y, params["out_proj"])
    new_cache = None
    if cache is not None:
        Kw = cfg.ssm_conv
        new_cache = {"conv": xbc_tail(u, params, cfg, Kw),
                     "state": h_fin}
    return shard(out, dp(), None, None), new_cache


def xbc_tail(u, params, cfg, Kw):
    """Last Kw-1 pre-conv features (for seeding a decode cache after prefill)."""
    di, N = cfg.d_inner, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,dn->bsn", u[:, -(Kw - 1):], params["in_proj"])
    _, xr, Bm, Cm, _ = _split_proj(cfg, zxbcdt)
    return jnp.concatenate([xr, Bm, Cm], axis=-1)


def make_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
        "state": jnp.zeros((batch, H, Pd, N), jnp.float32),
    }
