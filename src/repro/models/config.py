"""Model & shape configuration dataclasses + the architecture registry."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    modality: str = "text"       # text | vision_stub | audio_stub
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: Optional[int] = None
    activation: str = "swiglu"   # swiglu | squared_relu | gelu
    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "dense"      # dense (sort/scatter, pjit) | a2a (shard_map)
    # --- SSM (mamba2 / SSD) ------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # --- hybrid (zamba2): shared attention block every `attn_period` layers --
    attn_period: int = 0
    # --- enc-dec ------------------------------------------------------------
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    tgt_frac: int = 4            # train target length = seq_len // tgt_frac
    # --- numerics / training --------------------------------------------------
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    remat: bool = True
    microbatch: int = 1          # gradient-accumulation steps inside train_step
    attn_chunk: int = 512        # flash-attention query-chunk length
    # beyond-paper perf knobs (see EXPERIMENTS.md §Perf)
    fuse_qkv: bool = True
    bf16_reduce: bool = False   # TP partial sums cross chips in bf16 (not f32)
    kv_quant: bool = False      # int8 KV cache with per-(token,head) scales

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            per = (d * (2 * di + 2 * ns + self.ssm_heads)  # in_proj(z,x)+B,C,dt
                   + self.ssm_conv * (di + 2 * ns)          # depthwise conv
                   + di * d + 2 * self.ssm_heads + di)       # out_proj, A, D, norm
            return self.n_layers * per + v * d + (0 if self.tie_embeddings else v * d)
        att = d * (self.n_heads * self.hd) + d * (2 * self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        if self.activation == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            mlp = (self.n_experts + self.n_shared_experts) * mlp + d * self.n_experts
        if self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            per = (d * (2 * di + 2 * ns + self.ssm_heads)
                   + self.ssm_conv * (di + 2 * ns) + di * d + 2 * self.ssm_heads + di)
            shared = att + mlp  # one shared attention block
            return self.n_layers * per + shared + v * d * 2
        layers = self.n_layers if self.family != "encdec" \
            else (self.n_enc_layers + self.n_dec_layers)
        per = att + mlp
        if self.family == "encdec":
            per = per + att  # cross-attention in decoder (approx: count once avg)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return layers * per + emb

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        per_expert = 3 * d * f if self.activation == "swiglu" else 2 * d * f
        total = self.n_params()
        inactive = (self.n_experts - self.top_k) * per_expert * self.n_layers
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Sub-quadratic-attention rule: long_500k runs only for SSM/hybrid archs.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        names.append("long_500k")
    return tuple(names)


# --------------------------------------------------------------------------
# registry (populated by repro.configs)
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  — populates the registry

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    import repro.configs  # noqa: F401

    return dict(_REGISTRY)
