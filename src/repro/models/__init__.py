"""Model zoo: dense/MoE/SSM/hybrid decoder LMs + encoder-decoder."""

from . import encdec, lm
from .config import (
    LM_SHAPES,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
    get_config,
    list_configs,
)

__all__ = [
    "encdec", "lm", "LM_SHAPES", "ModelConfig", "ShapeConfig",
    "applicable_shapes", "get_config", "list_configs",
]
