"""Encoder-decoder transformer (seamless-m4t backbone).

The speech/text frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings [B, S_src, d].  The decoder is a standard causal
transformer with cross-attention; decode_step runs one target token against a
self-attention KV cache plus the precomputed cross-attention cache.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys
from .config import ModelConfig
from .layers import attention, init_attention, init_mlp, make_cache, mlp
from .lm import lm_loss_from_h, unembed_matrix
from .sharding import dp, shard, tp


def _init_enc_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg, dtype=dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ks = split_keys(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "self_attn": init_attention(ks[0], cfg, dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "cross_attn": init_attention(ks[1], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[2], cfg, dtype=dtype),
    }


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    ks = split_keys(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_dec_layers)
    return {
        "embed": dense_init(ks[2], (cfg.vocab, cfg.d_model), in_axis=1, dtype=dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "unembed": dense_init(ks[3], (cfg.d_model, cfg.vocab), dtype=dtype),
    }


def _maybe_remat(fn, cfg, train):
    return jax.checkpoint(fn) if (train and cfg.remat) else fn


def encode(params, cfg: ModelConfig, src_embeds, train=False):
    B, S = src_embeds.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = shard(src_embeds, dp(), None, None)

    def body(hh, p):
        a, _ = attention(p["attn"], rms_norm(hh, p["ln1"], cfg.norm_eps),
                         positions, cfg, causal=False)
        hh = hh + a
        hh = hh + mlp(p["mlp"], rms_norm(hh, p["ln2"], cfg.norm_eps), cfg)
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg, train), h, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _dec_block(p, h, positions, enc_out, cfg, self_cache=None, cross_cache=None):
    a, new_self = attention(p["self_attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                            positions, cfg, causal=True, cache=self_cache)
    h = h + a
    x, new_cross = attention(p["cross_attn"], rms_norm(h, p["ln_x"], cfg.norm_eps),
                             positions, cfg, causal=False, cache=cross_cache,
                             kv_from=enc_out, cross=True)
    h = h + x
    h = h + mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg)
    return h, new_self, new_cross


def decode_train(params, cfg: ModelConfig, enc_out, tgt_tokens, train=False):
    B, S = tgt_tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = jnp.take(params["embed"], tgt_tokens, axis=0)
    h = shard(h, dp(), None, None)

    def body(hh, p):
        hh, _, _ = _dec_block(p, hh, positions, enc_out, cfg)
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg, train), h, params["dec_layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch: Dict, mesh=None):
    """batch: {"src_embeds": [B,Ss,d], "tgt_tokens": [B,St], "labels": [B,St]}."""
    enc_out = encode(params, cfg, batch["src_embeds"], train=True)
    h = decode_train(params, cfg, enc_out, batch["tgt_tokens"], train=True)
    ce = lm_loss_from_h(params, cfg, h, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_dec_caches(params, cfg: ModelConfig, enc_out, window: int,
                    dtype=jnp.bfloat16):
    """Self caches (empty, `window` long) + cross caches (from enc_out)."""
    B = enc_out.shape[0]
    L = cfg.n_dec_layers
    one = make_cache(cfg, B, window, dtype)
    self_caches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape).copy(), one)

    K, hd = cfg.n_kv_heads, cfg.hd

    def one_cross(p):
        Skv = enc_out.shape[1]
        k = jnp.einsum("bsd,dn->bsn", enc_out, p["cross_attn"]["wk"]) \
            .reshape(B, Skv, K, hd)
        v = jnp.einsum("bsd,dn->bsn", enc_out, p["cross_attn"]["wv"]) \
            .reshape(B, Skv, K, hd)
        return {"k": k.astype(dtype), "v": v.astype(dtype)}

    cross = jax.vmap(one_cross)(params["dec_layers"])
    return {"self": self_caches, "cross": cross}


def decode_step(params, cfg: ModelConfig, tokens, caches, mesh=None):
    """tokens: [B, 1] target token; caches from make_dec_caches."""
    h = jnp.take(params["embed"], tokens, axis=0)
    B = h.shape[0]
    pos0 = caches["self"]["pos"][0]
    positions = jnp.broadcast_to(pos0[None, None], (B, 1))

    def body(carry, xs):
        hh = carry
        p, self_c, cross_c = xs
        hh, new_self, _ = _dec_block(p, hh, positions, None, cfg,
                                     self_cache=self_c, cross_cache=cross_c)
        return hh, new_self

    h, new_self = jax.lax.scan(
        body, h, (params["dec_layers"], caches["self"], caches["cross"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, unembed_matrix(params),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], {"self": new_self, "cross": caches["cross"]}
